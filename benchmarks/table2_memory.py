"""Paper Table 2 + §1-formula reproduction (analytic, exact).

Claims validated:
  * ResNet-50 full precision "97.5 MB" = 25.56M params x 4 B = 97.5 MiB.
  * ResNet-50 @ 2-bit weights + 8-bit activations = 7.4 MB (we get
    params 6.1 MiB + peak activations 1.5 MiB = 7.6 MiB; the 0.2 MiB gap
    is the activation working-set estimate).
  * multiplications reduced by ~two orders of magnitude (91-245x for
    K=4 across ResNet-18/34/50).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.memory import footprint_mb, lutq_layer_bits  # noqa: E402
from repro.models.resnet import (  # noqa: E402
    resnet_activation_elems,
    resnet_layer_sizes,
    resnet_mults,
)

ROWS = [
    # (label, weight K, act bits)
    ("fp32 / fp32", None, 32),
    ("5-bit pow2 / 32-bit (INQ cfg)", 32, 32),
    ("4-bit pow2 / 8-bit (LUT-Q)", 16, 8),
    ("2-bit pow2 / 8-bit (LUT-Q)", 4, 8),
]


def run(emit=print):
    results = []
    for depth in (18, 34, 50):
        sizes = resnet_layer_sizes(depth)
        n = sum(p for _, p in sizes)
        acts = resnet_activation_elems(depth)
        emit(f"# ResNet-{depth}: {n/1e6:.2f}M conv+fc params, "
             f"{acts/1e6:.2f}M peak act elems")
        for label, K, act_bits in ROWS:
            params_only = footprint_mb(sizes, weight_bits=None, K=K,
                                       act_elems=0, b_float=32)
            with_acts = footprint_mb(sizes, weight_bits=None, K=K,
                                     act_elems=acts, act_bits=act_bits)
            m = resnet_mults(depth, K=K if K and K <= 16 else None)
            emit(f"  {label:34s} params {params_only:7.2f} MiB | "
                 f"+acts {with_acts:7.2f} MiB | mults {m/1e9:.3f}G")
            results.append((depth, label, params_only, with_acts, m))
        emit("")
    # headline claims
    fp50 = footprint_mb(resnet_layer_sizes(50), weight_bits=None, K=None,
                        act_elems=0)
    q50 = footprint_mb(resnet_layer_sizes(50), weight_bits=2, K=4,
                       act_elems=resnet_activation_elems(50), act_bits=8)
    ratio = resnet_mults(50) / resnet_mults(50, K=4)
    emit(f"CLAIM fp32 ResNet-50 ~97.5 MB      -> {fp50:.1f} MiB")
    emit(f"CLAIM 2-bit+8-bit ResNet-50 ~7.4 MB -> {q50:.1f} MiB")
    emit(f"CLAIM mults down ~2 orders         -> {ratio:.0f}x (K=4)")
    assert abs(fp50 - 97.5) < 6.0
    assert abs(q50 - 7.4) < 0.6
    assert ratio > 50
    return results


if __name__ == "__main__":
    run()
