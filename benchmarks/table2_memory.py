"""Paper Table 2 + §1-formula reproduction (analytic, exact).

Claims validated:
  * ResNet-50 full precision "97.5 MB" = 25.56M params x 4 B = 97.5 MiB.
  * ResNet-50 @ 2-bit weights + 8-bit activations = 7.4 MB (we get
    params 6.1 MiB + peak activations 1.5 MiB = 7.6 MiB; the 0.2 MiB gap
    is the activation working-set estimate).
  * multiplications reduced by ~two orders of magnitude (91-245x for
    K=4 across ResNet-18/34/50).
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.memory import (  # noqa: E402
    footprint_mb,
    lutq_layer_bits,
    policy_footprint,
)
from repro.core.rules import QuantPolicy, QuantRule  # noqa: E402
from repro.core.spec import LUTQ_2BIT_POW2, LUTQ_4BIT_POW2  # noqa: E402
from repro.models.resnet import (  # noqa: E402
    resnet_activation_elems,
    resnet_layer_sizes,
    resnet_mults,
)

# Mixed-precision policy for the per-rule breakdown: the paper's actual
# experimental protocol — first (stem) and last (fc) layers stay fp,
# early stages 4-bit pow2, late stages 2-bit pow2.
RESNET_MIXED = QuantPolicy(
    rules=(QuantRule("stem", None, name="first-layer-fp"),
           QuantRule("fc", None, name="last-layer-fp"),
           QuantRule("s[01]*", LUTQ_4BIT_POW2, min_size=0,
                     name="early-4bit-pow2"),
           QuantRule("*", LUTQ_2BIT_POW2, min_size=0, name="late-2bit-pow2")),
    name="resnet_mixed")

ROWS = [
    # (label, weight K, act bits)
    ("fp32 / fp32", None, 32),
    ("5-bit pow2 / 32-bit (INQ cfg)", 32, 32),
    ("4-bit pow2 / 8-bit (LUT-Q)", 16, 8),
    ("2-bit pow2 / 8-bit (LUT-Q)", 4, 8),
]


def run(emit=print):
    results = []
    for depth in (18, 34, 50):
        sizes = resnet_layer_sizes(depth)
        n = sum(p for _, p in sizes)
        acts = resnet_activation_elems(depth)
        emit(f"# ResNet-{depth}: {n/1e6:.2f}M conv+fc params, "
             f"{acts/1e6:.2f}M peak act elems")
        for label, K, act_bits in ROWS:
            params_only = footprint_mb(sizes, weight_bits=None, K=K,
                                       act_elems=0, b_float=32)
            with_acts = footprint_mb(sizes, weight_bits=None, K=K,
                                     act_elems=acts, act_bits=act_bits)
            m = resnet_mults(depth, K=K if K and K <= 16 else None)
            emit(f"  {label:34s} params {params_only:7.2f} MiB | "
                 f"+acts {with_acts:7.2f} MiB | mults {m/1e9:.3f}G")
            results.append((depth, label, params_only, with_acts, m))
        emit("")
    # headline claims
    fp50 = footprint_mb(resnet_layer_sizes(50), weight_bits=None, K=None,
                        act_elems=0)
    q50 = footprint_mb(resnet_layer_sizes(50), weight_bits=2, K=4,
                       act_elems=resnet_activation_elems(50), act_bits=8)
    ratio = resnet_mults(50) / resnet_mults(50, K=4)
    emit(f"CLAIM fp32 ResNet-50 ~97.5 MB      -> {fp50:.1f} MiB")
    emit(f"CLAIM 2-bit+8-bit ResNet-50 ~7.4 MB -> {q50:.1f} MiB")
    emit(f"CLAIM mults down ~2 orders         -> {ratio:.0f}x (K=4)")
    assert abs(fp50 - 97.5) < 6.0
    assert abs(q50 - 7.4) < 0.6
    assert ratio > 50

    # per-rule bitwidth/memory breakdown under a mixed QuantPolicy
    emit(f"\n# ResNet-50 per-rule breakdown (policy {RESNET_MIXED.name!r})")
    rows = policy_footprint(resnet_layer_sizes(50), RESNET_MIXED)
    emit(f"  {'rule':20s} {'tensors':>7s} {'params':>12s} "
         f"{'bits/w':>6s} {'MiB':>8s}")
    for name, r in rows.items():
        bpw = "-" if r["bits_per_weight"] is None else str(r["bits_per_weight"])
        emit(f"  {name:20s} {r['n_tensors']:7d} {r['n_params']:12d} "
             f"{bpw:>6s} {r['mib']:8.3f}")
    assert rows["(total)"]["mib"] < fp50 / 4  # mixed policy still ~10x smaller
    return results


if __name__ == "__main__":
    run()
