"""Fig. 2 reproduction: simultaneous pruning + quantization sweep.

Paper: ResNet-20/CIFAR-10 can be pruned to ~70% and quantized to 2 bits
without significant accuracy loss. We sweep prune fraction x bitwidth on
the CPU-scale task and report the error-rate increase over fp32.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core.spec import QuantSpec  # noqa: E402

from cifar_table import train_one  # noqa: E402  (same harness)

PRUNES = [0.0, 0.5, 0.7]
BITS = [4, 2]


def run(emit=print, steps=240):
    base = train_one(None, steps=steps)
    emit(f"  fp32 baseline err {base:5.1f}%")
    rows = [("fp32", 0.0, base)]
    for bits in BITS:
        for p in PRUNES:
            t0 = time.time()
            err = train_one(QuantSpec(bits=bits), prune=p, steps=steps)
            emit(f"  {bits}-bit prune {int(p*100):2d}%: err {err:5.1f}% "
                 f"(delta {err-base:+.1f}%)  ({time.time()-t0:.0f}s)")
            rows.append((f"{bits}bit", p, err))
    return rows


if __name__ == "__main__":
    run()
