"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (kernel section) plus the
analytic Table 2 reproduction and the trainable CIFAR-style tables.
``--fast`` trims training steps (CI); default runs the full budget.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--skip-train]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args(argv)
    steps = 120 if args.fast else 240

    t0 = time.time()
    print("== Table 2: memory & multiplication reproduction (analytic) ==")
    import table2_memory
    table2_memory.run()

    print("\n== Kernel microbenchmarks (name,us_per_call,derived) ==")
    import kernel_bench
    kernel_bench.run()

    if not args.skip_train:
        print("\n== CIFAR-style quantization quality table (synthetic task) ==")
        import cifar_table
        cifar_table.run(steps=steps)

        print("\n== Fig 2: prune x quantize sweep ==")
        import fig2_prune
        fig2_prune.run(steps=steps)

    print("\n== Roofline (from dry-run artifacts, if present) ==")
    art = Path(__file__).resolve().parent / "artifacts/dryrun/pod16x16"
    if art.exists() and any(art.glob("*.json")):
        import roofline
        roofline.main(["--artifacts", str(art)])
    else:
        print("  (run `python -m repro.launch.dryrun` first)")

    print(f"\n[benchmarks] total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
