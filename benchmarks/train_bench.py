"""SPMD training benchmark: 1 vs 8 virtual host devices, compressed vs
uncompressed data-parallel gradients.

Forces an 8-way host platform (like ``shard_bench.py``), builds one
reduced arch's LUT-Q train state, and times the train step on a trivial
1x1 mesh and on the 2x4 ("data", "model") mesh, with and without the
error-feedback compressed gradient exchange. Emits ``BENCH_train.json``
at the repo root:

  * step ms per cell — on virtual CPU devices the sharded step pays
    collective-emulation overhead, so wall-clock is a structural record,
    not a speedup claim;
  * DP gradient-exchange wire bytes per device per step (the ring model
    ``2 (n-1)/n * payload``, computed from the actual trainable tree and
    the transform's actual wire dtypes — modeled, labeled as such): the
    compressed-collective claim is ``ef``/``ring`` < uncompressed;
  * per-device master bytes (the FSDP memory win) and a loss-parity bit
    (first-step solo vs 2x4 losses agree to reduction order), so the
    benchmark doubles as a smoke check.

Run: python benchmarks/train_bench.py [--quick]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.spec import QuantSpec  # noqa: E402
from repro.data.synthetic import MarkovLM  # noqa: E402
from repro.distributed.compress import (dp_grad_transform, dp_wire_bytes,  # noqa: E402
                                        trainable_pspecs)
from repro.launch import partition  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.reduce import reduced  # noqa: E402
from repro.optim.optimizers import adamw  # noqa: E402
from repro.optim.train_state import (init_train_state, make_train_step,  # noqa: E402
                                     state_flat)


def _cell(cfg, params, mesh, compress, *, batch, seq, steps):
    opt = adamw(1e-3)
    state = state_flat(init_train_state(params, opt,
                                        grad_compress=bool(compress)))
    sh = None
    if mesh is not None:
        sh = partition.train_shardings(cfg, mesh, batch=batch, seq=seq,
                                       grad_compress=bool(compress))
        state = partition.place_state(state, sh["state"])
    gt = (dp_grad_transform(mesh, mode=compress,
                            pspecs=None if sh is None
                            else trainable_pspecs(sh["state"]))
          if compress else None)
    step_fn = make_train_step(cfg, api.loss_fn, opt, grad_transform=gt,
                              shardings=sh)
    if mesh is None:
        step_fn = jax.jit(step_fn)
    lm = MarkovLM(cfg.vocab, seed=0)

    def make_batch(n):
        return {k: jnp.asarray(v) for k, v in lm.batch(0, n, batch, seq).items()}

    state, m0 = step_fn(state, make_batch(0))  # warm the trace
    loss0 = float(m0["loss"])  # first-step loss: the parity bit's input
    t0 = time.perf_counter()
    for n in range(1, steps + 1):
        state, m = step_fn(state, make_batch(n))
    jax.block_until_ready(m["loss"])
    wall = time.perf_counter() - t0
    return {"step_ms": 1e3 * wall / steps, "loss0": loss0}, state


def bench(arch: str, *, quick: bool = False):
    cfg = reduced(get_config(arch)).replace(
        vocab=64, act_bits=8,
        quant=QuantSpec(bits=4, kmeans_iters=1, min_size=4096,
                        constraint="pow2"))
    batch, seq = (4, 16) if quick else (8, 32)
    steps = 4 if quick else 12
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    params = api.quantize(params, cfg)

    trainable = state_flat(init_train_state(params, adamw(1e-3)))["trainable"]
    rec = {"arch": arch, "batch": batch, "seq": seq, "steps": steps,
           "devices": len(jax.devices()), "meshes": {}}
    losses = {}
    for name, dm in {"1x1": (1, 1), "2x4": (2, 4)}.items():
        mesh = make_host_mesh(*dm)
        dp = dm[0]
        cell = {"mesh": name, "step_ms": {}, "dp_wire_bytes_modeled": {}}
        for mode in (None, "ef", "ring"):
            if mode == "ring" and dp == 1:
                continue  # no data axis to ring over
            r, state = _cell(cfg, params, mesh, mode,
                             batch=batch, seq=seq, steps=steps)
            key = mode or "uncompressed"
            cell["step_ms"][key] = round(r["step_ms"], 2)
            cell["dp_wire_bytes_modeled"][key] = dp_wire_bytes(
                trainable, dp, mode)
            losses[(name, mode)] = r["loss0"]
            if mode is None:
                dev = mesh.devices.flat[0]
                cell["per_device_master_bytes"] = sum(
                    partition.device_nbytes(l, dev)
                    for l in jax.tree.leaves(state["trainable"],
                                             is_leaf=lambda x: x is None)
                    if l is not None and hasattr(l, "nbytes"))
        rec["meshes"][name] = cell
        print(f"[train_bench] {arch} mesh {name}: "
              + ", ".join(f"{k} {v} ms" for k, v in cell["step_ms"].items()))
    a, b = losses[("1x1", None)], losses[("2x4", None)]
    rec["loss_parity"] = bool(abs(a - b) / abs(a) < 1e-3)
    rec["compressed_bytes_ratio"] = (
        rec["meshes"]["2x4"]["dp_wire_bytes_modeled"]["ef"]
        / max(rec["meshes"]["2x4"]["dp_wire_bytes_modeled"]["uncompressed"], 1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=str(ROOT / "BENCH_train.json"))
    args = ap.parse_args(argv)

    if len(jax.devices()) < 8:
        print("[train_bench] fewer than 8 devices visible — was jax "
              "imported before XLA_FLAGS was set?", file=sys.stderr)
        return 1
    rec = bench(args.arch, quick=args.quick)
    Path(args.out).write_text(json.dumps(rec, indent=1))
    print(f"[train_bench] loss_parity={rec['loss_parity']} "
          f"compressed/uncompressed DP bytes "
          f"{rec['compressed_bytes_ratio']:.2f} -> {args.out}")
    return 0 if (rec["loss_parity"]
                 and rec["compressed_bytes_ratio"] < 1.0) else 2


if __name__ == "__main__":
    sys.exit(main())
