"""Kernel microbenchmarks: us_per_call for the Pallas kernels (interpret
mode on CPU — structural validation; real-TPU timing is a deploy step)
and their pure-jnp oracles (XLA:CPU compiled — the actual CPU perf
reference). Derived column: modeled TPU-v5e HBM-bound time from the
bytes each variant moves (the paper's memory-traffic claim).

The backend section times ``kernels.ops.lutq_dot`` end-to-end per
execution backend (decode vs fused vs packed4) on one serve-form
LutqState — first with the default tiles, then after a
``kernels.autotune`` search — and emits ``BENCH_kernels.json`` at the
repo root. Every record carries ``platform``/``interpret`` honestly
(interpret-mode numbers can never masquerade as TPU ones), the rep
count the median was taken over, and ``measured_over_model`` — the
measured/modeled ratio bench-smoke gates per backend so a timing-path
regression fails CI instead of drifting silently. The tuned tiles are
written alongside as a tuning-cache JSON artifact that
``launch/serve.py --autotune cache`` consumes directly.

Timing discipline (uniform across every row): one compile call plus
``warmup`` synced warmup calls are excluded, then each of ``reps``
timed calls is individually fenced with ``block_until_ready`` and the
median is reported (see ``kernels.autotune.measure_call``).
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.lutq import LutqState  # noqa: E402
from repro.kernels import autotune, ops  # noqa: E402
from repro.kernels.autotune import measure_call  # noqa: E402
from repro.kernels.ref import (  # noqa: E402
    kmeans_stats_ref,
    lutq_gemv_packed_ref,
    lutq_matmul_ref,
    pack4,
    pack4_kin,
)

HBM_BW = 819e9


def bench_backends(quick: bool = False, reps: int = 5, warmup: int = 2,
                   tune: bool = True):
    """Time lutq_dot per backend on one serve-form leaf.

    Returns {backend: {us, ms, weight_bytes, gbps, v5e_model_us,
    measured_over_model[, tuned_us, tuned_tile, tuned_over_default]}}:
    ``weight_bytes`` is the weight traffic each backend moves per call
    (f32 dense for decode after materialization, int8 indices for
    fused, packed nibbles for packed4) — the quantity the paper's
    memory-roofline argument is about; ``gbps`` the implied bandwidth at
    the measured time; ``v5e_model_us`` the analytic HBM-bound time at
    v5e bandwidth for those bytes; ``measured_over_model`` their ratio
    (the bench-smoke gate: ~1-10 on real TPU, O(1e2-1e4) in interpret
    mode). With ``tune=True`` the fused/packed4 rows are re-timed after
    an autotune search over the same shape; the default-tile timings
    are taken *first*, while the process tuning cache is still empty.
    """
    B = 8
    Kin, N = (512, 512) if quick else (2048, 2048)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, Kin), jnp.float32)
    a = jax.random.randint(key, (Kin, N), 0, 16, jnp.int8)
    d = jnp.sort(jax.random.normal(key, (16,)))
    serve = LutqState(w=None, d=d, a=a)
    packed = LutqState(w=None, d=d, a=pack4_kin(a))
    # pow2 leaf: sign+exponent dictionary plane + frozen int8 act pair
    from repro.core.lutq import pow2_encode
    d_p2 = jnp.sort(jnp.float32([-8, -2, -0.5, -0.125, 0.0, 0.03125, 0.0625,
                                 0.125, 0.25, 0.5, 1, 2, 4, 8, 16, 32]))
    shift = LutqState(w=None, d=pow2_encode(d_p2), a=a,
                      act=jnp.float32([0.03, 127.0]))

    cases = {
        "decode": (serve, Kin * N * 4),   # materialized f32 dense weights
        "fused": (serve, Kin * N),        # int8 assignments, decoded in VMEM
        "packed4": (packed, Kin * N // 2),  # 4-bit pairs stay packed in HBM
        "pow2": (shift, Kin * N + 16 + 8),  # int8 indices + int8 dict + act
    }
    out = {}
    for name, (state, wbytes) in cases.items():
        # state is a jit *argument* (not a closure capture): a captured
        # constant lets XLA fold the d[A] decode at compile time, which
        # would erase exactly the per-call decode cost being measured.
        fn = jax.jit(functools.partial(ops.lutq_dot, backend=name))
        us = measure_call(fn, x, state, reps=reps, warmup=warmup)
        model_us = wbytes / HBM_BW * 1e6
        out[name] = {
            "us": us,
            "ms": us / 1e3,
            "weight_bytes": wbytes,
            "gbps": wbytes / (us * 1e-6) / 1e9,
            "v5e_model_us": model_us,
            "measured_over_model": us / model_us,
        }
    if tune:
        # defaults are timed above with an empty cache; now search and
        # re-time through the same lutq_dot entry point, which consults
        # the freshly tuned tiles at trace time
        tc = ops.tuning_cache()
        for name in ("fused", "packed4", "pow2"):
            state = cases[name][0]
            _, tile, _ = autotune.tune(
                autotune.KERNEL_OF_BACKEND[name], M=B, N=N, Kin=Kin, K=16,
                backend=name, reps=max(reps - 2, 2), warmup=warmup, cache=tc)
            fn = jax.jit(functools.partial(ops.lutq_dot, backend=name))
            tuned_us = measure_call(fn, x, state, reps=reps, warmup=warmup)
            out[name]["tuned_tile"] = tile.to_json_dict()
            out[name]["tuned_us"] = tuned_us
            out[name]["tuned_over_default"] = tuned_us / out[name]["us"]
    return {"shape": {"B": B, "Kin": Kin, "N": N, "K": 16},
            "platform": autotune.platform(),
            "interpret": autotune.default_interpret(),
            "reps": reps, "warmup": warmup,
            "backends": out}


def run(emit=print, quick: bool = False, reps: int = 5, warmup: int = 2):
    rows = []
    key = jax.random.PRNGKey(0)
    B, Kin, N = (8, 512, 512) if quick else (8, 2048, 2048)
    x = jax.random.normal(key, (B, Kin), jnp.float32)
    a = jax.random.randint(key, (Kin, N), 0, 16, jnp.int8)
    packed = pack4(a)
    d = jnp.sort(jax.random.normal(key, (16,)))

    _time = functools.partial(measure_call, reps=reps, warmup=warmup)

    # modeled v5e HBM-bound decode times (weight bytes / bw)
    t_bf16 = Kin * N * 2 / HBM_BW * 1e6
    t_int8 = Kin * N * 1 / HBM_BW * 1e6
    t_pack4 = Kin * N / 2 / HBM_BW * 1e6

    us = _time(lambda: lutq_matmul_ref(x, a, d))
    rows.append(("lutq_matmul_ref_jnp", us, f"v5e_model_us={t_int8:.3f}"))
    us = _time(lambda: ops.lutq_matmul(x, a, d, bm=B, bn=256, bk=256,
                                       interpret=True))
    rows.append(("lutq_matmul_pallas_interp", us, f"v5e_model_us={t_int8:.3f}"))

    us = _time(lambda: lutq_gemv_packed_ref(x, packed, d))
    rows.append(("lutq_gemv_packed_ref_jnp", us, f"v5e_model_us={t_pack4:.3f}"))
    us = _time(lambda: ops.lutq_gemv_packed(x, packed, d, bn=256, bk=256,
                                            interpret=True))
    rows.append(("lutq_gemv_packed_pallas_interp", us,
                 f"v5e_model_us={t_pack4:.3f}"))
    rows.append(("bf16_weight_traffic_model", t_bf16,
                 f"pack4_speedup={t_bf16/t_pack4:.1f}x"))

    w = jax.random.normal(key, (1 << (15 if quick else 18),))
    d8 = jnp.sort(jax.random.normal(key, (16,)))
    us = _time(lambda: kmeans_stats_ref(w, d8))
    rows.append(("kmeans_stats_ref_jnp", us, f"K=16,N={w.size}"))
    us = _time(lambda: ops.kmeans_stats(w, d8, bn=8192, interpret=True))
    rows.append(("kmeans_stats_pallas_interp", us, f"K=16,N={w.size}"))

    # causal flash attention: block-skipped kernel vs dense oracle
    from repro.kernels.flash_attn import flash_attention_tpu
    from repro.nn.attention import dense_attention
    BH, S, D = 4, (128 if quick else 512), 64
    ks = jax.random.split(key, 3)
    q, kk, vv = (jax.random.normal(ks[i], (BH, S, D)) for i in range(3))
    us = _time(lambda: dense_attention(q[:, :, None], kk[:, :, None],
                                       vv[:, :, None], causal=True))
    rows.append(("causal_attn_dense_jnp", us, f"S={S},full_S2_flops"))
    if quick and autotune.default_interpret():
        # interpret-mode flash is a per-element Python emulation — even
        # at S=128 it dominates the whole smoke run by ~100x while
        # measuring nothing the S=512 full bench doesn't. Record the
        # skip explicitly instead of leaving a hole in the schema.
        rows.append(("causal_flash_pallas_interp", None,
                     f"S={S},skipped=interpret_quick"))
    else:
        us = _time(lambda: flash_attention_tpu(
            q, kk, vv, causal=True, interpret=autotune.default_interpret()))
        rows.append(("causal_flash_pallas_interp", us,
                     f"S={S},block_skipped=~S2/2_flops"))

    # paged decode attention: block-table kernel vs materializing gather
    # oracle, plus the bytes model CI gates (live pages < NB means the
    # kernel reads strictly fewer KV bytes per decode step)
    from repro.kernels.paged_attn import pages_read_per_step

    Bp, page, nbp, hkvp, dhp = 8, 16, (4 if quick else 16), 2, 64
    n_pages = 1 + Bp * nbp
    prng = np.random.RandomState(0)
    kp = prng.randn(n_pages, page, hkvp, dhp).astype(np.float32)
    vp = prng.randn(n_pages, page, hkvp, dhp).astype(np.float32)
    kp[0] = vp[0] = 0.0  # pinned trash page
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    qp = jnp.asarray(prng.randn(Bp, 1, hkvp * 2, dhp), jnp.float32)
    blk = jnp.asarray(1 + prng.permutation(Bp * nbp).reshape(Bp, nbp),
                      jnp.int32)
    cl_np = prng.randint(1, nbp * page + 1, (Bp,))
    cl = jnp.asarray(cl_np, jnp.int32)
    page_bytes = page * hkvp * dhp * 4 * 2  # K+V, f32 pool
    t_gather = nbp * page_bytes / HBM_BW * 1e6
    us = _time(lambda: ops.paged_attention(qp, kp, vp, blk, cl,
                                           backend="gather"))
    rows.append(("paged_attn_gather_jnp", us,
                 f"NB={nbp},v5e_model_us={t_gather:.3f}"))
    if autotune.default_interpret() and quick:
        # same honesty rule as flash above: interpret-mode Pallas is a
        # per-grid-step emulation that dwarfs the smoke budget without
        # measuring anything the full bench doesn't
        rows.append(("paged_attn_kernel_pallas_interp", None,
                     f"NB={nbp},skipped=interpret_quick"))
    else:
        us = _time(lambda: ops.paged_attention(
            qp, kp, vp, blk, cl, backend="kernel",
            interpret=autotune.default_interpret()))
        rows.append(("paged_attn_kernel_pallas_interp", us,
                     f"NB={nbp},walks_block_table"))
    # modeled bytes/step over the ragged cache lengths: the gather
    # oracle always streams NB pages, the kernel only the live span
    # (+1 trash page when any grid step is dead)
    mean_pages = float(np.mean(
        [pages_read_per_step(int(c), page, nbp) for c in cl_np]))
    ratio = mean_pages / nbp
    t_paged = mean_pages * page_bytes / HBM_BW * 1e6
    rows.append(("paged_attn_pages_read_model", t_paged,
                 f"mean_pages={mean_pages:.2f},"
                 f"pages_ratio_vs_gather={ratio:.3f}"))

    for name, us, derived in rows:
        emit(f"{name},{'skipped' if us is None else f'{us:.1f}'},{derived}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / CI smoke (interpret mode)")
    ap.add_argument("--json-out", default=str(ROOT / "BENCH_kernels.json"),
                    help="where to write the backend comparison record")
    ap.add_argument("--tuning-out",
                    default=str(ROOT / "BENCH_tuning_cache.json"),
                    help="where to write the tuned-tile cache artifact "
                         "(consumed by launch/serve.py --autotune cache)")
    ap.add_argument("--no-tune", action="store_true",
                    help="skip the autotune search / tuned columns")
    args = ap.parse_args(argv)

    reps, warmup = (3, 1) if args.quick else (5, 2)
    rows = run(quick=args.quick, reps=reps, warmup=warmup)
    rec = bench_backends(quick=args.quick, reps=reps, warmup=warmup,
                         tune=not args.no_tune)
    rec["kernels"] = [
        {"name": n,
         "us": None if us is None else round(us, 1),
         "skipped": us is None,
         "derived": d} for n, us, d in rows]
    dec, fus, pk = (rec["backends"][k] for k in ("decode", "fused", "packed4"))
    print(f"lutq_dot decode vs fused vs packed4 vs pow2 "
          f"(B={rec['shape']['B']}, {rec['shape']['Kin']}x{rec['shape']['N']}, "
          f"platform={rec['platform']}, interpret={rec['interpret']}, "
          f"median of {rec['reps']}):")
    for name in ("decode", "fused", "packed4", "pow2"):
        b = rec["backends"][name]
        tuned = ""
        if "tuned_us" in b:
            t = b["tuned_tile"]
            tuned = (f"   tuned {b['tuned_us']/1e3:.3f} ms "
                     f"({b['tuned_over_default']:.2f}x default, "
                     f"{t['bm']}x{t['bn']}x{t['bk']}/{t['strategy']})")
        print(f"  {name:8s} {b['ms']:10.3f} ms   "
              f"{b['gbps']:8.3f} GB/s weight traffic   "
              f"(v5e HBM-bound model {b['v5e_model_us']:.2f} us, "
              f"measured/model {b['measured_over_model']:.0f}x){tuned}")
    print(f"  weight-byte reduction: fused {dec['weight_bytes']/fus['weight_bytes']:.0f}x, "
          f"packed4 {dec['weight_bytes']/pk['weight_bytes']:.0f}x vs f32 decode")
    Path(args.json_out).write_text(json.dumps(rec, indent=1))
    print(f"wrote {args.json_out}")
    if not args.no_tune and len(ops.tuning_cache()):
        ops.tuning_cache().save(args.tuning_out)
        print(f"wrote {args.tuning_out} "
              f"({len(ops.tuning_cache())} tuned tiles)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
