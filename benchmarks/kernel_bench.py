"""Kernel microbenchmarks: us_per_call for the Pallas kernels (interpret
mode on CPU — structural validation; real-TPU timing is a deploy step)
and their pure-jnp oracles (XLA:CPU compiled — the actual CPU perf
reference). Derived column: modeled TPU-v5e HBM-bound time from the
bytes each variant moves (the paper's memory-traffic claim).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.kernels.ref import (  # noqa: E402
    kmeans_stats_ref,
    lutq_gemv_packed_ref,
    lutq_matmul_ref,
    pack4,
)

HBM_BW = 819e9


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(emit=print):
    rows = []
    key = jax.random.PRNGKey(0)
    B, Kin, N = 8, 2048, 2048
    x = jax.random.normal(key, (B, Kin), jnp.float32)
    a = jax.random.randint(key, (Kin, N), 0, 16, jnp.int8)
    packed = pack4(a)
    d = jnp.sort(jax.random.normal(key, (16,)))

    # modeled v5e HBM-bound decode times (weight bytes / bw)
    t_bf16 = Kin * N * 2 / HBM_BW * 1e6
    t_int8 = Kin * N * 1 / HBM_BW * 1e6
    t_pack4 = Kin * N / 2 / HBM_BW * 1e6

    us = _time(lambda: lutq_matmul_ref(x, a, d))
    rows.append(("lutq_matmul_ref_jnp", us, f"v5e_model_us={t_int8:.3f}"))
    us = _time(lambda: ops.lutq_matmul(x, a, d, bm=B, bn=256, bk=256,
                                       interpret=True))
    rows.append(("lutq_matmul_pallas_interp", us, f"v5e_model_us={t_int8:.3f}"))

    us = _time(lambda: lutq_gemv_packed_ref(x, packed, d))
    rows.append(("lutq_gemv_packed_ref_jnp", us, f"v5e_model_us={t_pack4:.3f}"))
    us = _time(lambda: ops.lutq_gemv_packed(x, packed, d, bn=256, bk=256,
                                            interpret=True))
    rows.append(("lutq_gemv_packed_pallas_interp", us,
                 f"v5e_model_us={t_pack4:.3f}"))
    rows.append(("bf16_weight_traffic_model", t_bf16,
                 f"pack4_speedup={t_bf16/t_pack4:.1f}x"))

    w = jax.random.normal(key, (1 << 18,))
    d8 = jnp.sort(jax.random.normal(key, (16,)))
    us = _time(lambda: kmeans_stats_ref(w, d8))
    rows.append(("kmeans_stats_ref_jnp", us, "K=16,N=262144"))
    us = _time(lambda: ops.kmeans_stats(w, d8, bn=8192, interpret=True))
    rows.append(("kmeans_stats_pallas_interp", us, "K=16,N=262144"))

    # causal flash attention: block-skipped kernel vs dense oracle
    from repro.kernels.flash_attn import flash_attention_tpu
    from repro.nn.attention import dense_attention
    BH, S, D = 4, 512, 64
    ks = jax.random.split(key, 3)
    q, kk, vv = (jax.random.normal(ks[i], (BH, S, D)) for i in range(3))
    us = _time(lambda: dense_attention(q[:, :, None], kk[:, :, None],
                                       vv[:, :, None], causal=True))
    rows.append(("causal_attn_dense_jnp", us, f"S={S},full_S2_flops"))
    us = _time(lambda: flash_attention_tpu(q, kk, vv, causal=True,
                                           interpret=True))
    rows.append(("causal_flash_pallas_interp", us,
                 f"S={S},block_skipped=~S2/2_flops"))

    for name, us, derived in rows:
        emit(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
