"""CIFAR-style quantization quality table (paper §2, CPU scale).

Trains the same reduced ResNet-20 on the deterministic synthetic shapes
task under the paper's configurations and reports the error-rate
ordering the paper observes on CIFAR-10:

    fp32  <=  LUT-Q 4-bit (quasi)  <=  fully multiplier-less 4-bit
          <=  LUT-Q 2-bit (quasi)  <=  fully multiplier-less 2-bit

"quasi" = pow2 weights + standard BN (paper's quasi multiplier-less);
"fully" = pow2 weights + ML-BN + 8-bit activations.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.policy import (  # noqa: E402
    kmeans_tree,
    merge_trainable,
    quantize_tree,
    split_trainable,
)
from repro.core.spec import QuantSpec  # noqa: E402
from repro.data.synthetic import class_batches, shapes_dataset  # noqa: E402
from repro.models.resnet import classify_loss, init_resnet20  # noqa: E402
from repro.optim.optimizers import adamw, cosine_schedule  # noqa: E402

WIDTHS = (8, 16, 32)
BLOCKS = 1
STEPS = 240
BATCH = 64


def train_one(spec, *, multiplier_less=False, act_bits=32, seed=0,
              steps=STEPS, prune=0.0):
    xs, ys = shapes_dataset(2048, seed=1)
    xt, yt = shapes_dataset(512, seed=2)
    params, stats = init_resnet20(jax.random.PRNGKey(seed), widths=WIDTHS,
                                  blocks=BLOCKS)
    if spec is not None:
        import dataclasses
        spec = dataclasses.replace(spec, prune_frac=prune, kmeans_iters=1,
                                   min_size=256)
        params = quantize_tree(params, spec)
    opt = adamw(cosine_schedule(2e-3, 20, steps))
    trainable, static = split_trainable(params)
    opt_state = opt.init(trainable)

    kw = dict(widths=WIDTHS, blocks=BLOCKS, multiplier_less=multiplier_less,
              act_bits=act_bits)

    @jax.jit
    def step(trainable, static, stats, opt_state, n, batch):
        def loss_fn(t):
            p = merge_trainable(t, static)
            return classify_loss(p, stats, batch, **kw)

        (loss, (new_stats, acc)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(trainable)
        trainable, opt_state = opt.update(g, opt_state, trainable, n)
        if spec is not None:
            merged = kmeans_tree(merge_trainable(trainable, static), spec)
            _, static = split_trainable(merged)
        # merge running stats
        stats = {**stats, **new_stats}
        return trainable, static, stats, opt_state, loss, acc

    it = class_batches(xs, ys, BATCH, seed=3)
    for n in range(steps):
        b = next(it)
        batch = {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}
        trainable, static, stats, opt_state, loss, acc = step(
            trainable, static, stats, opt_state, jnp.asarray(n), batch)

    params = merge_trainable(trainable, static)

    @jax.jit
    def evaluate(params, stats, x, y):
        from repro.models.resnet import resnet20_apply
        logits, _ = resnet20_apply(params, stats, x, training=False, **kw)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    accs = []
    for s in range(0, len(xt), 128):
        accs.append(float(evaluate(params, stats, jnp.asarray(xt[s:s+128]),
                                   jnp.asarray(yt[s:s+128]))))
    return 100.0 * (1.0 - float(np.mean(accs)))


CONFIGS = [
    ("fp32 baseline", None, False, 32),
    ("LUT-Q 4-bit pow2 (quasi ML)", QuantSpec(bits=4, constraint="pow2"), False, 32),
    ("LUT-Q 4-bit pow2 (fully ML)", QuantSpec(bits=4, constraint="pow2"), True, 8),
    ("LUT-Q 2-bit pow2 (quasi ML)", QuantSpec(bits=2, constraint="pow2"), False, 32),
    ("LUT-Q 2-bit pow2 (fully ML)", QuantSpec(bits=2, constraint="pow2"), True, 8),
    # the paper's "special cases": constrained dictionaries reproduce
    # TWN / BinaryConnect inside the same training loop
    ("ternary a*{-1,0,1} (TWN case)",
     QuantSpec(bits=2, constraint="ternary", fixed_scale=True), False, 32),
    ("binary {-1,1} (BinaryConnect)", QuantSpec(bits=1, constraint="binary"), False, 32),
]


def run(emit=print, steps=STEPS):
    rows = []
    for label, spec, ml, act in CONFIGS:
        t0 = time.time()
        err = train_one(spec, multiplier_less=ml, act_bits=act, steps=steps)
        emit(f"  {label:32s} err {err:5.1f}%  ({time.time()-t0:.0f}s)")
        rows.append((label, err))
    fp = rows[0][1]
    emit(f"  ordering check: fp32 {fp:.1f}% <= 4-bit quasi "
         f"{rows[1][1]:.1f}% (paper: 7.4 -> 7.6)")
    return rows


if __name__ == "__main__":
    run()
