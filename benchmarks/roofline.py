"""Roofline analysis: three terms per (arch x shape) cell on TPU v5e.

    compute    = FLOPs / (chips * 197e12)            [bf16 MXU peak]
    memory     = HBM bytes / (chips * 819e9)
    collective = ICI bytes / (chips * 50e9 per link)

Accounting methodology (documented in EXPERIMENTS.md §Roofline):
XLA's HloCostAnalysis counts while-loop bodies ONCE (calibrated in this
repo: a 10-iteration scan of matmuls reports 1x the matmul FLOPs), so
``compiled.cost_analysis()`` underreports any scanned program. The terms
below therefore come from a closed-form analytic model of the exact
program we lower (including its inefficiencies: full-S^2 masked causal
flash, remat recompute, capacity-factor padding, k-means passes), while
the compiled artifact supplies (a) per-device memory_analysis, (b) the
collective op inventory (type/count/per-trip bytes) used to cross-check
the analytic collective term, (c) compile evidence for every cell.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per the assignment;
the ratio MODEL_FLOPS / total_flops exposes remat/attention/dispatch
overhead ("how much compiled compute is useful").
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (per-chip effective)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config  # noqa: E402
from repro.models.api import SHAPES   # noqa: E402


# ---------------------------------------------------------------------------
# parameter accounting (matmul params only — what turns into FLOPs)
# ---------------------------------------------------------------------------

def param_groups(cfg) -> Dict[str, float]:
    D, F, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    dh = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    g: Dict[str, float] = {}
    if cfg.family == "ssm":  # rwkv6
        per_layer = 5 * D * D + D * 64 * 2          # r,k,v,g,o + decay lora
        per_layer += D * F + F * D + D * D          # channel mix
        g["layer"] = per_layer * L
    elif cfg.family == "hybrid":  # zamba2
        d_in = cfg.resolved_d_inner
        per_m = D * (2 * d_in + 2 * cfg.ssm_state + d_in // cfg.ssm_head_dim)
        per_m += d_in * D
        g["layer"] = per_m * L
        shared = D * (H + 2 * Hkv) * dh + H * dh * D + 2 * D * F + F * D
        g["shared_attn"] = shared  # params stored once, APPLIED L/attn_every x
    else:
        if cfg.use_mla:
            attn = (D * H * (cfg.qk_nope + cfg.qk_rope)
                    + D * (cfg.kv_lora + cfg.qk_rope)
                    + cfg.kv_lora * H * cfg.qk_nope
                    + cfg.kv_lora * H * cfg.v_head
                    + H * cfg.v_head * D)
        else:
            attn = D * (H + 2 * Hkv) * dh + H * dh * D
        if cfg.n_experts:
            moe = cfg.n_experts * 3 * D * F
            shared = 3 * D * (cfg.d_ff_shared or 0)
            mlp_total = moe + shared
            mlp_active = cfg.top_k * 3 * D * F + shared
        else:
            mlp_total = mlp_active = 3 * D * F
        n_moe_layers = L - cfg.first_dense
        dense_ff = 3 * D * (10944 if cfg.first_dense else F)  # dsv2 dense layer
        g["attn"] = attn * L
        g["mlp_total"] = mlp_total * n_moe_layers + (dense_ff * cfg.first_dense)
        g["mlp_active"] = mlp_active * n_moe_layers + (dense_ff * cfg.first_dense)
        if cfg.family == "encdec":
            enc = cfg.enc_layers * (D * (H + 2 * Hkv) * dh + H * dh * D + 3 * D * F)
            xattn = L * (D * (H + 2 * Hkv) * dh + H * dh * D)
            g["encoder"] = enc
            g["xattn"] = xattn
    g["embed"] = V * D * (1 if cfg.tie_embeddings else 2)
    return g


def active_params(cfg) -> float:
    g = param_groups(cfg)
    tot = sum(v for k, v in g.items() if k not in ("mlp_total", "mlp_active"))
    tot += g.get("mlp_active", g.get("mlp_total", 0.0))
    return tot


def all_params(cfg) -> float:
    g = param_groups(cfg)
    tot = sum(v for k, v in g.items() if k not in ("mlp_active",))
    return tot


# ---------------------------------------------------------------------------
# analytic FLOPs for the program we actually lower
# ---------------------------------------------------------------------------

def _attn_flops_fwd(cfg, B, S, Skv=None, useful=False):
    """QK^T + PV einsum flops. Our chunked-flash causal path computes the
    full S x Skv score matrix with masking -> count it all unless
    `useful` (causal halves it)."""
    Skv = Skv or S
    if cfg.window is not None:
        Skv_eff = min(cfg.window + cfg.attn_kv_block, Skv)
    else:
        Skv_eff = Skv
    dh = cfg.resolved_head_dim
    if cfg.use_mla:
        dh = cfg.qk_nope + cfg.qk_rope
        dv = cfg.v_head
    else:
        dv = dh
    f = 2 * B * cfg.n_heads * S * Skv_eff * (dh + dv)
    if useful and cfg.window is None:
        f *= 0.5
    return f


def forward_flops(cfg, B, S, *, useful=False):
    """One forward pass over B x S tokens."""
    T = B * S
    g = param_groups(cfg)
    f = 0.0
    if cfg.family == "ssm":
        f += 2 * T * g["layer"]
        # wkv recurrence: 3 * dk * dv mults per head-step (negligible)
        H = cfg.d_model // cfg.ssm_head_dim
        f += 2 * 3 * T * H * cfg.ssm_head_dim ** 2
    elif cfg.family == "hybrid":
        f += 2 * T * g["layer"]
        napp = cfg.n_layers // cfg.attn_every
        f += 2 * T * g["shared_attn"] * napp
        f += _attn_flops_fwd(cfg, B, S, useful=useful) * napp
        # SSD intra-chunk quadratic: per chunk L_c^2 terms
        d_in = cfg.resolved_d_inner
        H = d_in // cfg.ssm_head_dim
        Lc = cfg.ssm_chunk
        f += 2 * B * (S * Lc) * (H * cfg.ssm_head_dim + cfg.ssm_state) * cfg.n_layers
    else:
        mlp = g.get("mlp_active", g.get("mlp_total", 0.0))
        f += 2 * T * (g["attn"] + mlp) if "attn" in g else 0.0
        f += _attn_flops_fwd(cfg, B, S, useful=useful) * cfg.n_layers
        if cfg.n_experts and not useful:
            f += 2 * T * cfg.n_experts * cfg.n_layers  # router
            # capacity-factor padding: dispatched buffers are cf x tokens
            pad = max(cfg.capacity_factor - 1.0, 0.0)
            f += pad * 2 * T * cfg.top_k * 3 * cfg.d_model * cfg.d_ff * \
                (cfg.n_layers - cfg.first_dense)
        if cfg.family == "encdec":
            Ssrc = S
            f += 2 * B * Ssrc * g["encoder"] / max(cfg.enc_layers, 1) * cfg.enc_layers
            f += _attn_flops_fwd(cfg, B, Ssrc, useful=useful) * cfg.enc_layers
            f += 2 * T * g["xattn"] / max(cfg.n_layers, 1) * cfg.n_layers
            f += _attn_flops_fwd(cfg, B, S, Skv=Ssrc) * cfg.n_layers
    # logits
    f += 2 * T * cfg.d_model * cfg.vocab
    return f


# ---------------------------------------------------------------------------
# per-group quantization resolution (QuantPolicy-aware)
# ---------------------------------------------------------------------------

# Representative pytree path probed per param group: the policy's rules
# match real tree paths, so the analytic model resolves each accounting
# group through the same first-match-wins logic the tree walk uses.
GROUP_PROBE_PATH = {
    "attn": ("layers", "attn", "q", "kernel"),
    "mlp_total": ("layers", "mlp", "wi", "kernel"),
    "mlp_active": ("layers", "mlp", "wi", "kernel"),
    "embed": ("embed", "table"),
    "layer": ("layers", "mix", "r", "kernel"),
    "shared_attn": ("shared", "attn", "q", "kernel"),
    "encoder": ("encoder", "layers", "attn", "q", "kernel"),
    "xattn": ("layers", "xattn", "q", "kernel"),
}

# groups that alias storage already counted by another group
_NON_STORAGE_GROUPS = ("mlp_active",)


def group_spec(cfg, group: str):
    """QuantSpec governing a param group under cfg's policy (or None)."""
    from repro.models.api import resolved_policy
    policy = resolved_policy(cfg)
    if policy is None:
        return None
    if group in ("mlp_total", "mlp_active") and cfg.n_experts:
        # MoE archs: the bulk of this group lives under layers/moe/*
        path = ("layers", "moe", "wi")
    else:
        path = GROUP_PROBE_PATH.get(group, ("layers", "x", "kernel"))
    _, spec = policy.resolve(path, size=1 << 40)
    return spec


def group_bits(cfg) -> Dict[str, Optional[int]]:
    """Per-group index bitwidth (None = full precision) for reporting."""
    out = {}
    for g in param_groups(cfg):
        if g in _NON_STORAGE_GROUPS:
            continue
        spec = group_spec(cfg, g)
        out[g] = None if spec is None else spec.index_bits
    return out


def weight_store_bytes(cfg, *, pack: bool = False) -> float:
    """Served weight bytes, policy-resolved per group: bf16 when a group
    is fp/excluded, int8 indices when quantized, packed 4-bit when
    ``pack`` and the group's spec fits in 4 index bits. Dictionary bytes
    are counted per group: f32 entries normally, a 1-byte sign+exponent
    plane (plus the frozen 8-byte activation pair) for ``pow2`` groups."""
    total = 0.0
    for g, n in param_groups(cfg).items():
        if g in _NON_STORAGE_GROUPS:
            continue
        spec = group_spec(cfg, g)
        if spec is None:
            b = 2.0
        elif pack and spec.index_bits <= 4:
            b = 0.5
        else:
            b = 1.0
        total += n * b
        if spec is not None:
            total += spec.K * (1.0 if spec.backend == "pow2" else 4.0)
            if spec.backend == "pow2":
                total += 8.0  # frozen [scale, qmax] f32 pair
    return total


def shift_add_ops(cfg) -> Dict[str, float]:
    """Serving op budget split MAC vs multiplier-less, per decoded token.

    Groups whose spec runs the ``pow2`` backend count integer adds +
    bit-shifts (group-by-entry: I adds + K shifts per output) with fp
    multiplies only at the quant/epilogue boundary; every other group
    counts MACs. Drives the Table 2 multiplication-count reproduction at
    serving shapes (see ``repro.core.memory.affine_shift_ops``)."""
    adds = shifts = fp_mults = macs = 0.0
    for g, n in param_groups(cfg).items():
        if g in _NON_STORAGE_GROUPS:
            continue
        spec = group_spec(cfg, g)
        if spec is not None and spec.backend == "pow2":
            # per output neuron: I adds + K shifts + 1 fp mult. Group
            # counts are sum(I*O); approximate I by d_model (the input
            # dim of nearly every body matmul) to get total outputs.
            outs = n / cfg.d_model
            adds += n
            shifts += spec.K * outs
            fp_mults += outs
        else:
            macs += n
    return {"int_adds": adds, "bit_shifts": shifts,
            "fp_boundary_mults": fp_mults, "fp_macs": macs}


def kmeans_flops(cfg):
    """Step 4: K compares + K masked-sum passes per quantized weight,
    per-group K via the policy."""
    total = 0.0
    for g, n in param_groups(cfg).items():
        if g in _NON_STORAGE_GROUPS:
            continue
        spec = group_spec(cfg, g)
        if spec is not None:
            total += 2.0 * spec.K * n
    return total


def kmeans_hbm_bytes(cfg) -> float:
    """K masked f32 passes over the masters + assignment write, summed
    over quantized groups only."""
    total = 0.0
    for g, n in param_groups(cfg).items():
        if g in _NON_STORAGE_GROUPS:
            continue
        spec = group_spec(cfg, g)
        if spec is not None:
            total += (spec.K * 4 + 1) * n
    return total


def cell_flops(cfg, shape):
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        fwd = forward_flops(cfg, B, S)
        # remat scan: fwd + recompute + 2x bwd = 4x fwd; 'dots' policy
        # saves matmul outputs so the recompute pass is ~free -> 3x
        remat_factor = 3.0 if cfg.remat_policy == "dots" else 4.0
        total = remat_factor * fwd
        total += kmeans_flops(cfg)  # per-group K via the quant policy
        # optimizer elementwise ~ 10 flops/param (negligible, counted)
        total += 10.0 * all_params(cfg)
        useful = 6.0 * active_params(cfg) * B * S
        return total, useful
    if shape.kind == "prefill":
        fwd = forward_flops(cfg, B, S)
        return fwd, 2.0 * active_params(cfg) * B * S
    # decode: one token against S-cache
    T = B
    g = param_groups(cfg)
    if cfg.family == "ssm":
        f = 2 * T * g["layer"]
        H = cfg.d_model // cfg.ssm_head_dim
        f += 2 * 3 * T * H * cfg.ssm_head_dim ** 2
    elif cfg.family == "hybrid":
        napp = cfg.n_layers // cfg.attn_every
        f = 2 * T * (g["layer"] + g["shared_attn"] * napp)
        Skv = min(cfg.window, S) if cfg.window else S
        f += 2 * T * cfg.n_heads * Skv * 2 * cfg.resolved_head_dim * napp
    else:
        mlp = g.get("mlp_active", g.get("mlp_total", 0.0))
        f = 2 * T * (g.get("attn", 0.0) + mlp)
        Skv = min(cfg.window, S) if cfg.window else S
        if cfg.use_mla:
            # absorbed decode: scores+outputs against the rank-r latent
            f += 2 * T * cfg.n_heads * Skv * 2 * cfg.kv_lora * cfg.n_layers
        else:
            f += 2 * T * cfg.n_heads * Skv * 2 * cfg.resolved_head_dim * cfg.n_layers
        if cfg.family == "encdec":
            f += 2 * T * g["xattn"] + 2 * T * cfg.n_heads * S * 2 * \
                cfg.resolved_head_dim * cfg.n_layers
    f += 2 * T * cfg.d_model * cfg.vocab
    return f, 2.0 * active_params(cfg) * T


# ---------------------------------------------------------------------------
# analytic HBM traffic + collective bytes (per chip, per step)
# ---------------------------------------------------------------------------

def cell_traffic(cfg, shape, mesh_devices, model_par, data_par, microbatches):
    """Returns (hbm_bytes_per_chip, ici_bytes_per_chip)."""
    B, S = shape.global_batch, shape.seq_len
    Nall = all_params(cfg)
    D = cfg.d_model
    quant = cfg.quant is not None
    # stored weight bytes, resolved per param group through the policy
    # (fp groups at bf16, quantized at int8 indices)
    w_bytes = weight_store_bytes(cfg)
    chips = mesh_devices

    if shape.kind == "train":
        T = B * S
        # per chip shares
        w_gathered = w_bytes / model_par            # decoded per model-shard
        master = Nall * 4 / chips
        acts_layer = (T / (data_par * microbatches)) * D * 2  # bf16 boundary
        L = cfg.n_layers
        hbm = 0.0
        # weights touched fwd+recompute+bwd per microbatch
        hbm += 3 * microbatches * w_gathered
        # activations: write+read at layer boundaries x (fwd, recompute, bwd)
        hbm += 3 * 2 * acts_layer * L * microbatches
        # optimizer: read+write masters + opt state (m[,v])
        opt_mult = 3 if Nall < 5e10 else 2
        hbm += (1 + opt_mult) * 2 * master
        # kmeans: K masked passes over masters + assignment write
        if quant:
            hbm += kmeans_hbm_bytes(cfg) / chips
        # collectives: FSDP all-gather (fwd+bwd) + grad reduce-scatter
        shard = w_bytes / chips
        ici = 2 * microbatches * shard * (data_par - 1)
        ici += Nall * 4 / chips * (data_par - 1) / data_par * 2  # grad RS+AG f32
        # TP all-reduce on activations: 2/layer fwd + 2/layer bwd
        act_chip = (T / (data_par * microbatches)) * D * 2 / model_par
        ici += 4 * L * microbatches * act_chip * 2 * (model_par - 1) / model_par
        return hbm, ici

    if shape.kind == "prefill":
        T = B * S
        w = w_bytes / model_par
        acts = T * D * 2 / data_par
        kv = 2 * cfg.n_layers * T * cfg.n_kv_heads * cfg.resolved_head_dim * 2 / chips
        hbm = w + 2 * acts * cfg.n_layers + kv
        act_chip = acts / model_par
        ici = 2 * cfg.n_layers * act_chip * 2 * (model_par - 1) / model_par
        return hbm, ici

    # decode: weights + cache read once per token; pack_assignments
    # quarters the bytes of any group whose spec fits 4 index bits
    w = weight_store_bytes(cfg, pack=cfg.pack_assignments) / chips
    kv_bytes = 1.0 + 2.0 / cfg.resolved_head_dim if cfg.kv_cache_bits == 8 else 2.0
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.ssm_head_dim
        cache = cfg.n_layers * B * H * cfg.ssm_head_dim ** 2 * 4 / chips
    elif cfg.family == "hybrid":
        d_in = cfg.resolved_d_inner
        H = d_in // cfg.ssm_head_dim
        cache = cfg.n_layers * B * H * cfg.ssm_state * cfg.ssm_head_dim * 4 / chips
        napp = cfg.n_layers // cfg.attn_every
        cache += napp * B * S * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * kv_bytes / chips
    elif cfg.use_mla:
        cache = cfg.n_layers * B * S * (cfg.kv_lora + cfg.qk_rope) * 2 / chips
    else:
        Skv = min(cfg.window, S) if cfg.window else S
        cache = cfg.n_layers * B * Skv * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * kv_bytes / chips
    hbm = w + cache
    # decode TP all-reduces: per layer, activations are (B, 1, D)
    ici = 4 * cfg.n_layers * B * D * 2 / data_par / model_par * (model_par - 1) / model_par
    return hbm, ici


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------

def analyze_cell(arch: str, shape_name: str, artifact: Optional[dict],
                 *, chips=256, data_par=16, model_par=16) -> dict:
    cfg = get_config(arch)
    if artifact and artifact.get("overrides"):
        ov = {k: v for k, v in artifact["overrides"].items()
              if k != "microbatches"}
        if ov:
            cfg = cfg.replace(**ov)
    shape = SHAPES[shape_name]
    micro = artifact.get("microbatches", 8) if artifact else 8
    flops, useful = cell_flops(cfg, shape)
    hbm, ici = cell_traffic(cfg, shape, chips, model_par, data_par, micro)
    t_c = flops / (chips * PEAK_FLOPS)
    t_m = hbm / HBM_BW
    t_i = ici / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_i, "collective"))[1]
    bound = max(t_c, t_m, t_i)
    t_useful = useful / (chips * PEAK_FLOPS)
    rec = {
        "arch": arch, "shape": shape_name,
        # per-group index bitwidth under the config's QuantPolicy
        # (None = group stays full precision)
        "quant_bits_by_group": group_bits(cfg),
        "weight_store_gib": weight_store_bytes(cfg) / 2**30,
        "flops_total": flops, "model_flops": useful,
        "useful_ratio": useful / flops if flops else 0.0,
        "hbm_bytes_chip": hbm, "ici_bytes_chip": ici,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_i,
        "bound_s": bound,
        "dominant": dom,
        # projected MFU: useful-compute time over the binding constraint
        # (perfect-overlap assumption). For decode this is inherently low
        # — there the relevant score is the memory-roofline fraction.
        "mfu_proj": (t_useful / bound) if bound else 0.0,
        "mem_roofline_frac": (t_m / bound) if bound else 0.0,
        "roofline_fraction": (t_c / bound) if bound else 0.0,
    }
    if artifact and artifact.get("status") == "ok":
        rec["hlo_flops_module"] = artifact["cost"]["flops"]
        rec["temp_gib_dev"] = artifact["memory"]["temp_bytes"] / 2**30
        rec["hlo_collectives"] = artifact.get("collectives_count")
        rec["status"] = "ok"
    elif artifact:
        rec["status"] = artifact.get("status", "missing")
        rec["reason"] = artifact.get("reason", artifact.get("error", ""))[:90]
    else:
        rec["status"] = "missing"
    return rec


_FIX_HINTS = {
    "compute": "raise arithmetic efficiency: fuse decode into matmul "
               "(lutq_matmul kernel), cut causal-mask waste with "
               "block-skipped flash, drop remat recompute on cheap layers",
    "memory": "cut HBM traffic: packed 2/4-bit assignments "
              "(lutq_gemv_packed halves->quarters weight bytes), fuse "
              "k-means passes (kmeans_stats single-pass kernel)",
    "collective": "shrink/overlap collectives: int8 EF-compressed grad "
                  "reduce (2-4x fewer DP bytes), latency-hide FSDP "
                  "gathers under layer compute, 2D collective-matmul",
}


def kernel_bench_comparison(bench_path: Path):
    """Measured lutq_dot backend times (BENCH_kernels.json, written by
    kernel_bench.py) against the analytic HBM roofline for the weight
    bytes each backend moves. In interpret mode the absolute times are
    emulation artifacts — the byte ratios (decode : fused : packed4 =
    4 : 1 : 0.5 for K=16) are the roofline claim being tracked; on real
    TPU the measured/model ratio becomes the roofline fraction.
    """
    if not bench_path.exists():
        return None
    rec = json.loads(bench_path.read_text())
    lines = [f"kernel backends measured vs modeled "
             f"({bench_path.name}, platform={rec.get('platform', '?')}, "
             f"interpret={rec.get('interpret')}, "
             f"median of {rec.get('reps', '?')}):"]
    base = rec["backends"].get("decode", {}).get("weight_bytes")
    for name, b in rec["backends"].items():
        ratio = base / b["weight_bytes"] if base else float("nan")
        # measured_over_model is the roofline fraction on real TPU (and
        # the bench-smoke gate everywhere); older records predate it
        mom = b.get("measured_over_model",
                    b["us"] / b["v5e_model_us"] if "us" in b else float("nan"))
        tuned = ""
        if "tuned_us" in b:
            t = b.get("tuned_tile", {})
            tuned = (f" | tuned {b['tuned_us']/1e3:.3f} ms "
                     f"({b.get('tuned_over_default', float('nan')):.2f}x, "
                     f"{t.get('bm')}x{t.get('bn')}x{t.get('bk')}"
                     f"/{t.get('strategy')})")
        lines.append(
            f"  {name:8s} measured {b['ms']:9.3f} ms | weight bytes "
            f"{b['weight_bytes']/2**20:7.2f} MiB ({ratio:.1f}x less than f32) "
            f"| v5e HBM-bound {b['v5e_model_us']:.2f} us "
            f"| measured/model {mom:.0f}x{tuned}")
    skipped = [k["name"] for k in rec.get("kernels", [])
               if k.get("skipped")]
    if skipped:
        lines.append(f"  (skipped rows: {', '.join(skipped)})")
    return "\n".join(lines)


def main(argv=None):
    root = Path(__file__).resolve().parent
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default=str(root / "artifacts/dryrun/pod16x16"))
    ap.add_argument("--json-out", default=str(root / "artifacts/roofline.json"))
    ap.add_argument("--kernel-bench", default=str(root.parent / "BENCH_kernels.json"),
                    help="BENCH_kernels.json from kernel_bench.py (measured "
                         "fused-vs-decode times to compare with the model)")
    args = ap.parse_args(argv)
    art_dir = Path(args.artifacts)

    from repro.configs import list_archs
    rows = []
    for arch in list_archs():
        for shape_name in SHAPES:
            f = art_dir / f"{arch}__{shape_name}.json"
            artifact = json.loads(f.read_text()) if f.exists() else None
            if artifact and artifact.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape_name,
                             "status": "skipped",
                             "reason": artifact["reason"][:70]})
                continue
            rows.append(analyze_cell(arch, shape_name, artifact))

    hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dominant':>10s} {'MFU%':>6s} {'useful%':>8s} "
           f"{'temp GiB':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} SKIP: {r['reason']}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']*1e3:8.1f}m {r['t_memory_s']*1e3:8.1f}m "
              f"{r['t_collective_s']*1e3:8.1f}m {r['dominant']:>10s} "
              f"{r['mfu_proj']*100:5.1f}% "
              f"{r['useful_ratio']*100:7.1f}% "
              f"{r.get('temp_gib_dev', float('nan')):8.1f}")
    print("\nquantization layout (index bits per param group; fp = full precision):")
    seen = set()
    for r in rows:
        if r["arch"] in seen or "quant_bits_by_group" not in r:
            continue
        seen.add(r["arch"])
        bits = ", ".join(f"{g}={'fp' if b is None else b}"
                         for g, b in r["quant_bits_by_group"].items())
        print(f"  {r['arch']:24s} {bits} "
              f"({r['weight_store_gib']:.1f} GiB served)")
    Path(args.json_out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.json_out).write_text(json.dumps(rows, indent=1, default=float))
    cmp = kernel_bench_comparison(Path(args.kernel_bench))
    if cmp:
        print("\n" + cmp)
    print(f"\nfix hints by dominant term:")
    for k, v in _FIX_HINTS.items():
        print(f"  {k}: {v}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
