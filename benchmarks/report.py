"""Render EXPERIMENTS.md §Dry-run and §Roofline markdown tables from the
dry-run artifacts + roofline model. Narrative sections are maintained by
hand in EXPERIMENTS.md; this prints the data tables to splice in.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent
sys.path.insert(0, str(ROOT.parent / "src"))
sys.path.insert(0, str(ROOT))

from repro.configs import list_archs  # noqa: E402
from repro.models.api import SHAPES   # noqa: E402

import roofline  # noqa: E402


def dryrun_table(mesh_tag: str) -> str:
    d = ROOT / "artifacts/dryrun" / mesh_tag
    lines = [
        f"| arch | shape | kind | status | compile s | args GiB/dev | temp GiB/dev "
        f"| AG | AR | RS | A2A | CP |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        for shape in SHAPES:
            f = d / f"{arch}__{shape}.json"
            if not f.exists():
                continue
            r = json.loads(f.read_text())
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | - | SKIP (full attn) "
                             f"| | | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | ERROR | | | | | | | | |")
                continue
            m = r["memory"]
            c = r["collectives_count"]
            lines.append(
                f"| {arch} | {shape} | {r['kind']} | ok | {r['compile_s']} "
                f"| {m['argument_bytes']/2**30:.2f} | {m['temp_bytes']/2**30:.2f} "
                f"| {c['all-gather']} | {c['all-reduce']} | {c['reduce-scatter']} "
                f"| {c['all-to-all']} | {c['collective-permute']} |")
    return "\n".join(lines)


def roofline_table() -> str:
    d = ROOT / "artifacts/dryrun/pod16x16"
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "proj. MFU | useful ratio | one-line fix |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs():
        for shape in SHAPES:
            f = d / f"{arch}__{shape}.json"
            artifact = json.loads(f.read_text()) if f.exists() else None
            if artifact and artifact.get("status") == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | — | "
                             f"skipped: full quadratic attention |")
                continue
            r = roofline.analyze_cell(arch, shape, artifact)
            hint = roofline._FIX_HINTS[r["dominant"]].split(":")[1].split(",")[0].strip()
            lines.append(
                f"| {arch} | {shape} | {r['t_compute_s']*1e3:.1f} ms "
                f"| {r['t_memory_s']*1e3:.1f} ms | {r['t_collective_s']*1e3:.1f} ms "
                f"| **{r['dominant']}** | {r['mfu_proj']*100:.1f}% "
                f"| {r['useful_ratio']*100:.1f}% | {hint} |")
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### single pod (16x16)\n")
        print(dryrun_table("pod16x16"))
        print("\n### multi-pod (2x16x16)\n")
        print(dryrun_table("pod2x16x16"))
    if which in ("all", "roofline"):
        print("\n### roofline (single pod)\n")
        print(roofline_table())
