"""Sharded-serving benchmark: 1 vs 8 virtual host devices.

Forces an 8-way host platform (like ``launch/dryrun.py``), builds one
reduced arch in the LUT-Q deployment form, and serves the same static
batch twice: on a trivial 1x1 mesh and on the 2x4 ("data", "model")
mesh. Emits ``BENCH_shard.json`` at the repo root:

  * per-device weight bytes (quantized + dense split) — the tensor-
    parallel memory win this PR is about: index shards divide by the
    model axis while the dictionaries replicate for free;
  * decode ms/token + prefill ms per mesh — on virtual CPU devices the
    sharded path pays collective-emulation overhead, so wall-clock is a
    structural record, not a speedup claim (the memory column is the
    claim; real-TPU timing is a deploy step);
  * a token-parity bit so the benchmark doubles as a smoke check.

Run: python benchmarks/shard_bench.py [--quick]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.spec import QuantSpec  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.launch.serve import device_footprint  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.reduce import reduced  # noqa: E402
from repro.runtime.serving import generate  # noqa: E402


def bench(arch: str, *, quick: bool = False, backend: str = "fused"):
    cfg = reduced(get_config(arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8,
        kernel_backend=backend)

    B, Pl = (4, 16) if quick else (8, 32)
    steps = 8 if quick else 24
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, Pl), 0,
                                          cfg.vocab)}

    rec = {"arch": arch, "backend": backend, "batch": B, "prompt": Pl,
           "steps": steps, "devices": len(jax.devices()), "meshes": {}}
    outputs = {}
    for name, (d, m) in {"1x1": (1, 1), "2x4": (2, 4)}.items():
        mesh = make_host_mesh(d, m)
        placed, _ = api.serve_state(jax.random.PRNGKey(0), cfg, mesh=mesh)
        # warm the jit caches, then time a fresh run
        generate(placed, cfg, batch, steps=2, mesh=mesh)
        t0 = time.perf_counter()
        toks, stats = generate(placed, cfg, batch, steps=steps, mesh=mesh,
                               return_stats=True)
        wall = time.perf_counter() - t0
        outputs[name] = jax.device_get(toks)
        qb, fb = device_footprint(placed, mesh.devices.flat[0])
        rec["meshes"][name] = {
            "mesh": f"{d}x{m}",
            "per_device_quantized_bytes": qb,
            "per_device_dense_bytes": fb,
            "decode_ms_per_token": 1e3 * stats["t_decode_s"] / max(steps - 1, 1),
            "prefill_ms": 1e3 * stats["t_prefill_s"],
            "wall_s": wall,
        }
        print(f"[shard_bench] {arch} mesh {d}x{m}: "
              f"{qb/2**10:.1f} KiB quantized/device, "
              f"{rec['meshes'][name]['decode_ms_per_token']:.2f} ms/tok")
    rec["token_identical"] = bool((outputs["1x1"] == outputs["2x4"]).all())
    rec["per_device_bytes_ratio"] = (
        rec["meshes"]["2x4"]["per_device_quantized_bytes"]
        / max(rec["meshes"]["1x1"]["per_device_quantized_bytes"], 1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--backend", default="fused")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=str(ROOT / "BENCH_shard.json"))
    args = ap.parse_args(argv)

    if len(jax.devices()) < 8:
        print("[shard_bench] fewer than 8 devices visible — was jax "
              "imported before XLA_FLAGS was set?", file=sys.stderr)
        return 1
    rec = bench(args.arch, quick=args.quick, backend=args.backend)
    Path(args.out).write_text(json.dumps(rec, indent=1))
    print(f"[shard_bench] token_identical={rec['token_identical']} "
          f"per-device bytes ratio {rec['per_device_bytes_ratio']:.2f} "
          f"-> {args.out}")
    return 0 if rec["token_identical"] else 2


if __name__ == "__main__":
    sys.exit(main())
