"""Serving-discipline benchmark: static batching vs continuous batching
on ragged Poisson arrivals.

Both disciplines serve the SAME deterministic workload (ragged prompt
lengths, ragged max_new, Poisson arrival times) on the SAME quantized
weights and jit traces:

  * static: requests are grouped in arrival order into lock-step batches
    of ``--max-batch``; a batch launches once its last member has
    arrived, prompts are right-padded to the batch max (the per-stream
    ``lengths`` path in ``serving.generate``), and every stream decodes
    ``max(max_new)`` steps — the padding + straggler waste this PR's
    engine exists to eliminate;
  * continuous: the slot-pool engine (``runtime.engine.Engine``) admits
    each request as it arrives and a slot frees, and retires it the
    step it finishes.

Time is discrete-event: a virtual clock advances by the *measured* wall
time of each compute call, and arrival gaps advance it for free — so
queueing dynamics are Poisson while compute cost is real. A warmup pass
over the same workload compiles every (shape, length) trace first;
goodput counts requested tokens only (static over-generation is waste,
not goodput).

    python benchmarks/engine_bench.py --quick   # CI smoke; writes
                                                # BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.policy import serve_view  # noqa: E402
from repro.core.spec import QuantSpec  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.reduce import reduced  # noqa: E402
from repro.runtime.engine import Engine, synthetic_requests  # noqa: E402
from repro.runtime.serving import generate  # noqa: E402


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def serve_static(params, cfg, reqs, *, capacity, max_len):
    """Lock-step batches of ``capacity`` in arrival order (virtual clock)."""
    clock = 0.0
    lat, n_good = [], 0
    batches = [reqs[i:i + capacity] for i in range(0, len(reqs), capacity)]
    for group in batches:
        clock = max(clock, max(r["arrival_s"] for r in group))
        lens = [len(r["tokens"]) for r in group]
        steps = max(r["max_new"] for r in group)
        P = max(lens)
        toks = np.zeros((len(group), P), np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = r["tokens"]
        t0 = time.perf_counter()
        generate(params, cfg, {"tokens": jnp.asarray(toks)}, steps=steps,
                 lengths=lens, max_len=max_len)
        clock += time.perf_counter() - t0
        for r in group:
            lat.append(clock - r["arrival_s"])
            n_good += r["max_new"]
    return {
        "discipline": "static",
        "batches": len(batches),
        "makespan_s": clock,
        "goodput_tok_s": n_good / max(clock, 1e-9),
        "p50_latency_s": _pctl(lat, 50),
        "p95_latency_s": _pctl(lat, 95),
    }


def warm_engine_traces(params, cfg, *, capacity, max_len, bucket, vocab):
    """Compile every admission-group shape the engine can hit: with a
    fixed prefill bucket the group width is constant, so the trace set
    is just the group sizes 1..capacity (plus the shared decode step)."""
    eng = Engine(params, cfg, capacity=capacity, max_len=max_len,
                 prefill_bucket=bucket)
    rng = np.random.default_rng(0)
    for m in range(1, capacity + 1):
        for _ in range(m):
            eng.submit(rng.integers(0, vocab, size=(bucket,)).astype(np.int32),
                       max_new=2)
        eng.run()


def serve_continuous(params, cfg, reqs, *, capacity, max_len, bucket=1):
    """Slot-pool engine fed by the arrival process (virtual clock)."""
    eng = Engine(params, cfg, capacity=capacity, max_len=max_len,
                 prefill_bucket=bucket)
    pending = deque(reqs)
    arrival = {}
    clock = 0.0
    lat, n_good = [], 0
    while pending or not eng.idle:
        while pending and pending[0]["arrival_s"] <= clock:
            r = dict(pending.popleft())
            t_arr = r.pop("arrival_s")
            rid = eng.submit(**r)
            arrival[rid] = t_arr
        if eng.idle and pending:
            clock = pending[0]["arrival_s"]  # idle until the next arrival
            continue
        t0 = time.perf_counter()
        retired = eng.step()
        clock += time.perf_counter() - t0
        for res in retired:
            lat.append(clock - arrival[res["rid"]])
            n_good += res["n_new"]
    return {
        "discipline": "continuous",
        "decode_steps": eng.stats()["decode_steps"],
        "makespan_s": clock,
        "goodput_tok_s": n_good / max(clock, 1e-9),
        "p50_latency_s": _pctl(lat, 50),
        "p95_latency_s": _pctl(lat, 95),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload / CI smoke")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="0 = 12 with --quick else 24")
    # decode-heavy by default: serving is decode-dominated (the LUT-Q
    # roofline term), and decode steps are where the disciplines differ
    # (static runs max(max_new) for the whole batch; the ragged spread
    # of max_new in [gen/4, gen] is the straggler waste)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests per virtual "
                         "second (0 = 6x the static service rate, i.e. "
                         "an overloaded queue, so goodput measures "
                         "service capacity rather than offered load)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=str(ROOT / "BENCH_engine.json"))
    args = ap.parse_args(argv)

    n = args.requests or (12 if args.quick else 24)
    cfg = reduced(get_config(args.arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    sparams = serve_view(api.quantize(params, cfg, axes),
                         policy=api.resolved_policy(cfg))
    max_len = args.prompt_len + args.gen

    reqs = synthetic_requests(cfg, n, max_prompt=args.prompt_len,
                              max_new=args.gen, seed=args.seed,
                              rate=args.rate or 1.0)
    # warmup: compile every (batch, length) trace both disciplines hit;
    # the engine admits at a fixed bucket width so its trace set is
    # closed (group sizes 1..capacity) regardless of arrival dynamics
    bucket = args.prompt_len
    serve_static(sparams, cfg, reqs, capacity=args.max_batch, max_len=max_len)
    warm_engine_traces(sparams, cfg, capacity=args.max_batch,
                       max_len=max_len, bucket=bucket, vocab=cfg.vocab)
    serve_continuous(sparams, cfg, reqs, capacity=args.max_batch,
                     max_len=max_len, bucket=bucket)

    if not args.rate:
        # calibrate offered load from the static path's *service*
        # capacity (a warm burst with every arrival at t=0 — no
        # arrival-limited feedback), then offer 2x that as a Poisson
        # process: an overloaded queue, so goodput compares service
        # capacity (padding + straggler waste) instead of echoing the
        # offered load back
        burst = [dict(r, arrival_s=0.0) for r in reqs]
        calib = serve_static(sparams, cfg, burst, capacity=args.max_batch,
                             max_len=max_len)
        mean_new = float(np.mean([r["max_new"] for r in reqs]))
        rate = 2.0 * calib["goodput_tok_s"] / max(mean_new, 1.0)
        reqs = synthetic_requests(cfg, n, max_prompt=args.prompt_len,
                                  max_new=args.gen, seed=args.seed, rate=rate)
    # best-of-3: single-call CPU wall times jitter far more than the
    # ~1.2x structural gap; the min-makespan run is the least-noise
    # estimate of each discipline's true service cost
    static = min((serve_static(sparams, cfg, reqs, capacity=args.max_batch,
                               max_len=max_len) for _ in range(3)),
                 key=lambda r: r["makespan_s"])
    cont = min((serve_continuous(sparams, cfg, reqs, capacity=args.max_batch,
                                 max_len=max_len, bucket=bucket)
                for _ in range(3)),
               key=lambda r: r["makespan_s"])

    rec = {
        "workload": {
            "arch": cfg.name, "requests": n, "max_batch": args.max_batch,
            "prompt_len": args.prompt_len, "gen": args.gen,
            "seed": args.seed, "quick": bool(args.quick),
            "total_requested_tokens": int(sum(r["max_new"] for r in reqs)),
        },
        "static": static,
        "continuous": cont,
        "speedup_goodput": cont["goodput_tok_s"] / max(static["goodput_tok_s"],
                                                       1e-9),
        "p95_latency_ratio": static["p95_latency_s"] / max(
            cont["p95_latency_s"], 1e-9),
    }
    for row in (static, cont):
        print(f"{row['discipline']:>10s}: goodput {row['goodput_tok_s']:8.1f} "
              f"tok/s | makespan {row['makespan_s']:6.2f} s | "
              f"latency p50 {row['p50_latency_s']*1e3:7.0f} ms "
              f"p95 {row['p95_latency_s']*1e3:7.0f} ms")
    print(f"continuous/static goodput: {rec['speedup_goodput']:.2f}x | "
          f"static/continuous p95 latency: {rec['p95_latency_ratio']:.2f}x")
    Path(args.json_out).write_text(json.dumps(rec, indent=1))
    print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
