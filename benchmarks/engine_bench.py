"""Serving-discipline benchmark: static batching vs continuous batching
on ragged Poisson arrivals.

Both disciplines serve the SAME deterministic workload (ragged prompt
lengths, ragged max_new, Poisson arrival times) on the SAME quantized
weights and jit traces:

  * static: requests are grouped in arrival order into lock-step batches
    of ``--max-batch``; a batch launches once its last member has
    arrived, prompts are right-padded to the batch max (the per-stream
    ``lengths`` path in ``serving.generate``), and every stream decodes
    ``max(max_new)`` steps — the padding + straggler waste this PR's
    engine exists to eliminate;
  * continuous: the slot-pool engine (``runtime.engine.Engine``) admits
    each request as it arrives and a slot frees, and retires it the
    step it finishes.

Time is discrete-event: a virtual clock advances by the *measured* wall
time of each compute call, and arrival gaps advance it for free — so
queueing dynamics are Poisson while compute cost is real. A warmup pass
over the same workload compiles every (shape, length) trace first;
goodput counts requested tokens only (static over-generation is waste,
not goodput).

    python benchmarks/engine_bench.py --quick   # CI smoke; writes
                                                # BENCH_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.lutq import LutqState  # noqa: E402
from repro.core.policy import serve_view  # noqa: E402
from repro.core.rules import QuantPolicy, QuantRule  # noqa: E402
from repro.core.spec import QuantSpec  # noqa: E402
from repro.models import api  # noqa: E402
from repro.models.reduce import reduced  # noqa: E402
from repro.runtime.engine import Engine, synthetic_requests  # noqa: E402
from repro.runtime.serving import generate  # noqa: E402


def _pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def serve_static(params, cfg, reqs, *, capacity, max_len):
    """Lock-step batches of ``capacity`` in arrival order (virtual clock)."""
    clock = 0.0
    lat, ttft, n_good = [], [], 0
    batches = [reqs[i:i + capacity] for i in range(0, len(reqs), capacity)]
    for group in batches:
        clock = max(clock, max(r["arrival_s"] for r in group))
        lens = [len(r["tokens"]) for r in group]
        steps = max(r["max_new"] for r in group)
        P = max(lens)
        toks = np.zeros((len(group), P), np.int32)
        for i, r in enumerate(group):
            toks[i, :lens[i]] = r["tokens"]
        t0 = time.perf_counter()
        _, gstats = generate(params, cfg, {"tokens": jnp.asarray(toks)},
                             steps=steps, lengths=lens, max_len=max_len,
                             return_stats=True)
        clock += time.perf_counter() - t0
        # every stream's first token lands when the batched prefill ends
        t_first = clock - (time.perf_counter() - t0) + gstats["t_prefill_s"]
        for r in group:
            lat.append(clock - r["arrival_s"])
            ttft.append(max(t_first - r["arrival_s"], 0.0))
            n_good += r["max_new"]
    return {
        "discipline": "static",
        "batches": len(batches),
        "makespan_s": clock,
        "goodput_tok_s": n_good / max(clock, 1e-9),
        "p50_latency_s": _pctl(lat, 50),
        "p95_latency_s": _pctl(lat, 95),
        "ttft_p50_s": _pctl(ttft, 50),
        "ttft_p99_s": _pctl(ttft, 99),
    }


def warm_engine_traces(params, cfg, *, capacity, max_len, bucket, vocab):
    """Compile every admission-group shape the engine can hit: with a
    fixed prefill bucket the group width is constant, so the trace set
    is just the group sizes 1..capacity (plus the shared decode step)."""
    eng = Engine(params, cfg, capacity=capacity, max_len=max_len,
                 prefill_bucket=bucket)
    rng = np.random.default_rng(0)
    for m in range(1, capacity + 1):
        for _ in range(m):
            eng.submit(rng.integers(0, vocab, size=(bucket,)).astype(np.int32),
                       max_new=2)
        eng.run()


def serve_continuous(params, cfg, reqs, *, capacity, max_len, bucket=1,
                     kv_pages=None, page_size=64, prefill_pack=True):
    """Continuous-batching engine fed by the arrival process (virtual
    clock). ``kv_pages`` runs it on the paged KV cache (block-table
    pages, prefix sharing, chunked bucketed prefill, packed prefill)."""
    eng = Engine(params, cfg, capacity=capacity, max_len=max_len,
                 prefill_bucket=bucket, kv_pages=kv_pages,
                 page_size=page_size, prefill_pack=prefill_pack)
    pending = deque(reqs)
    arrival = {}
    clock = 0.0
    lat, ttft, n_good = [], [], 0
    while pending or not eng.idle:
        while pending and pending[0]["arrival_s"] <= clock:
            r = dict(pending.popleft())
            t_arr = r.pop("arrival_s")
            rid = eng.submit(**r)
            arrival[rid] = t_arr
        if eng.idle and pending:
            clock = pending[0]["arrival_s"]  # idle until the next arrival
            continue
        t0 = time.perf_counter()
        retired = eng.step()
        clock += time.perf_counter() - t0
        for res in retired:
            lat.append(clock - arrival[res["rid"]])
            # submit->first-token is measured compute (the loop's clock
            # advances only by step() wall time), so the engine's wall
            # TTFT is the virtual TTFT up to host bookkeeping noise
            ttft.append(res["t_first_token_s"])
            n_good += res["n_new"]
    st = eng.stats()
    out = {
        "discipline": "paged" if eng.paged else "continuous",
        "decode_steps": st["decode_steps"],
        "makespan_s": clock,
        "goodput_tok_s": n_good / max(clock, 1e-9),
        "p50_latency_s": _pctl(lat, 50),
        "p95_latency_s": _pctl(lat, 95),
        "ttft_p50_s": _pctl(ttft, 50),
        "ttft_p99_s": _pctl(ttft, 99),
    }
    if eng.paged:
        from repro.kernels.paged_attn import pages_read_per_step

        bpt = st["kv_bytes_per_token"]
        per_req = [r["kv_pages"] * st["page_size"] * bpt
                   for r in eng.results.values()]
        # modeled decode KV traffic: the block-table kernel streams only
        # the live page span of each row per step (+1 trash page when
        # any table entry is dead); the materializing gather always
        # reads the full NB-page row. Summed over every retired
        # request's actual decode trajectory — the bytes-per-step claim
        # docs/serving.md makes and CI gates as a ratio < 1.
        ps = st["page_size"]
        nb = -(-max_len // ps)
        pages_paged = pages_gather = steps_total = 0
        for r in eng.results.values():
            L0 = r["prompt_len"]
            for t in range(r["n_new"]):
                pages_paged += pages_read_per_step(L0 + t, ps, nb,
                                                   window=cfg.window)
                pages_gather += nb
                steps_total += 1
        out.update(
            kv_pages=st["kv_pages"], page_size=st["page_size"],
            pages_peak=st["pages_peak"], kv_bytes_per_token=bpt,
            kv_bytes_per_request_mean=float(np.mean(per_req)) if per_req
            else 0.0,
            prefix_hit_rate=st.get("prefix_hit_rate", 0.0),
            prefill_chunk_calls=st["prefill_chunk_calls"],
            packed_groups=st["packed_groups"],
            packed_requests=st["packed_requests"],
            prefill_calls_per_request=(
                (st["prefill_chunk_calls"] + st["packed_groups"])
                / max(len(eng.results), 1)),
            decode_kv_bytes_per_step_model=(
                pages_paged * ps * bpt / max(steps_total, 1)),
            pages_read_ratio_vs_gather=(
                pages_paged / max(pages_gather, 1)))
    return out


def _stream_bytes(tree):
    """Modeled weight-stream bytes of one forward pass: every leaf is
    read once per token batch (LUT-Q leaves stream dictionary +
    index plane; fp leaves stream their raw bytes)."""
    tot = 0
    for leaf in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: isinstance(x, LutqState)):
        if isinstance(leaf, LutqState):
            tot += int(leaf.d.nbytes) + int(leaf.a.nbytes)
        elif hasattr(leaf, "nbytes"):
            tot += int(leaf.nbytes)
    return tot


def bench_speculative(args):
    """Self-speculative decoding from the nested LUT-Q dictionary.

    The draft model is the SAME serve tree viewed through a coarser
    dictionary (``api.draft_view``): the transformer body is served at
    5 bits and drafted through its nested 4-bit (packed, 0.5 B/idx)
    view, while embeddings and head — already 4-bit packed — are shared
    by reference, so the draft is exact on them. Greedy acceptance is
    then limited only by the body coarsening, and a draft step streams
    ~47% fewer weight bytes than a target step. A small vocab keeps the
    random-init argmax margins meaningful (a reduced untrained model has
    near-uniform logits; production acceptance rates are higher still).

    Reported per k: measured acceptance, tokens per engine step vs the
    non-speculative engine (same workload, same traces), and the
    modeled weight bytes per accepted token
    ``(k * draft_stream + target_stream) / tokens_per_round`` vs the
    baseline's one target stream per token. CI gates token parity,
    tokens-per-step ratio > 1, and bytes ratio < 1 (see ci.yml).
    """
    spec_k, spec_db, vocab = 2, 4, 32
    pol = QuantPolicy(
        rules=(QuantRule("re:(^|/)table$", QuantSpec(bits=4, min_size=1024)),
               QuantRule("lm_head/*", QuantSpec(bits=4, min_size=1024)),
               QuantRule("*", QuantSpec(bits=5, min_size=1024))),
        name="nested-body5")
    cfg = reduced(get_config(args.arch)).replace(
        quant=pol, act_bits=32, remat=False, vocab=vocab)
    params, _ = api.serve_state(jax.random.PRNGKey(args.seed), cfg,
                                pack4=True)
    dparams, dreport = api.draft_view(params, draft_bits=spec_db,
                                      with_report=True)
    tgt_stream, drf_stream = _stream_bytes(params), _stream_bytes(dparams)

    srng = np.random.default_rng(args.seed + 3)
    # enough requests that the measured acceptance is the model's mean
    # rate, not the luck of a few trajectories (the CI byte gate rides
    # on it)
    sp_reqs = [(srng.integers(0, vocab, size=(int(srng.integers(4, 13)),))
                .astype(np.int32), int(srng.integers(16, 33)))
               for _ in range(6 * args.max_batch)]
    max_len = 12 + 32 + spec_k

    def run(k):
        eng = Engine(params, cfg, capacity=args.max_batch, max_len=max_len,
                     speculative=k, draft_bits=spec_db,
                     draft_params=dparams if k else None)
        for toks, m in sp_reqs:
            eng.submit(toks, max_new=m)
        t0 = time.perf_counter()
        res = eng.run()
        dt = time.perf_counter() - t0
        return [r["tokens"].tolist() for r in res], eng.stats(), dt

    run(0)  # compile
    base_tok, base_st, base_dt = run(0)
    run(spec_k)
    spec_tok, spec_st, spec_dt = run(spec_k)
    tpr = spec_st["spec_tokens_per_round"]
    bytes_spec = (spec_k * drf_stream + tgt_stream) / max(tpr, 1e-9)
    return {
        "k": spec_k, "draft_bits": spec_db, "vocab": vocab,
        "policy": pol.name, "requests": len(sp_reqs),
        "token_parity": bool(base_tok == spec_tok),
        "acceptance_rate": spec_st["acceptance_rate"],
        "spec_tokens_per_round": tpr,
        "spec_rounds": spec_st["spec_rounds"],
        "tokens_per_engine_step": spec_st["tokens_per_engine_step"],
        "baseline_tokens_per_engine_step": base_st["tokens_per_engine_step"],
        "tokens_per_step_ratio": (
            spec_st["tokens_per_engine_step"]
            / max(base_st["tokens_per_engine_step"], 1e-9)),
        "target_stream_bytes": tgt_stream,
        "draft_stream_bytes": drf_stream,
        "draft_extra_resident_bytes": int(
            sum(v["draft_bytes"] for v in dreport.values())),
        "draft_coarse_leaves": int(
            sum(1 for v in dreport.values() if not v["shared"])),
        "weight_bytes_per_accepted_token": bytes_spec,
        "baseline_weight_bytes_per_token": float(tgt_stream),
        "weight_bytes_ratio": bytes_spec / max(tgt_stream, 1e-9),
        "wall_s": spec_dt, "baseline_wall_s": base_dt,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload / CI smoke")
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="0 = 12 with --quick else 24")
    # decode-heavy by default: serving is decode-dominated (the LUT-Q
    # roofline term), and decode steps are where the disciplines differ
    # (static runs max(max_new) for the whole batch; the ragged spread
    # of max_new in [gen/4, gen] is the straggler waste)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate in requests per virtual "
                         "second (0 = 6x the static service rate, i.e. "
                         "an overloaded queue, so goodput measures "
                         "service capacity rather than offered load)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=str(ROOT / "BENCH_engine.json"))
    args = ap.parse_args(argv)

    n = args.requests or (12 if args.quick else 24)
    cfg = reduced(get_config(args.arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    sparams = serve_view(api.quantize(params, cfg, axes),
                         policy=api.resolved_policy(cfg))
    max_len = args.prompt_len + args.gen

    reqs = synthetic_requests(cfg, n, max_prompt=args.prompt_len,
                              max_new=args.gen, seed=args.seed,
                              rate=args.rate or 1.0)
    # warmup: compile every (batch, length) trace both disciplines hit;
    # the engine admits at a fixed bucket width so its trace set is
    # closed (group sizes 1..capacity) regardless of arrival dynamics
    bucket = args.prompt_len
    serve_static(sparams, cfg, reqs, capacity=args.max_batch, max_len=max_len)
    warm_engine_traces(sparams, cfg, capacity=args.max_batch,
                       max_len=max_len, bucket=bucket, vocab=cfg.vocab)
    serve_continuous(sparams, cfg, reqs, capacity=args.max_batch,
                     max_len=max_len, bucket=bucket)

    if not args.rate:
        # calibrate offered load from the static path's *service*
        # capacity (a warm burst with every arrival at t=0 — no
        # arrival-limited feedback), then offer 2x that as a Poisson
        # process: an overloaded queue, so goodput compares service
        # capacity (padding + straggler waste) instead of echoing the
        # offered load back
        burst = [dict(r, arrival_s=0.0) for r in reqs]
        calib = serve_static(sparams, cfg, burst, capacity=args.max_batch,
                             max_len=max_len)
        mean_new = float(np.mean([r["max_new"] for r in reqs]))
        rate = 2.0 * calib["goodput_tok_s"] / max(mean_new, 1.0)
        reqs = synthetic_requests(cfg, n, max_prompt=args.prompt_len,
                                  max_new=args.gen, seed=args.seed, rate=rate)
    # best-of-3: single-call CPU wall times jitter far more than the
    # ~1.2x structural gap; the min-makespan run is the least-noise
    # estimate of each discipline's true service cost
    static = min((serve_static(sparams, cfg, reqs, capacity=args.max_batch,
                               max_len=max_len) for _ in range(3)),
                 key=lambda r: r["makespan_s"])
    cont = min((serve_continuous(sparams, cfg, reqs, capacity=args.max_batch,
                                 max_len=max_len, bucket=bucket)
                for _ in range(3)),
               key=lambda r: r["makespan_s"])

    # ---- paged overload: 10x the request count, all arriving at t=0,
    # on a page pool holding HALF the slot pool's KV bytes. An equal-
    # byte slot engine only affords pool_tokens // max_len slots, so
    # this workload "fits" at full concurrency only under paging. The
    # requests share a one-page system prompt + unique tails — the
    # production shape prefix sharing exists for: the shared page is
    # mapped (not recomputed) for every request after the first, and
    # ragged per-request reservations pack the pool where fixed
    # max_len slots fragment it. CI gates the ratios below (see
    # .github/workflows/ci.yml)
    page_size = 16
    n_blocks = -(-max_len // page_size)
    pool_pages = args.max_batch * n_blocks // 2 + 1  # +1: trash page
    eq_slots = max(((pool_pages - 1) * page_size) // max_len, 1)
    n_over = 10 * n
    orng = np.random.default_rng(args.seed + 1)
    sys_prompt = orng.integers(0, cfg.vocab,
                               size=(page_size,)).astype(np.int32)
    over = []
    for _ in range(n_over):
        tail = orng.integers(
            0, cfg.vocab, size=(int(orng.integers(1, 9)),)).astype(np.int32)
        over.append({"tokens": np.concatenate([sys_prompt, tail]),
                     "max_new": int(orng.integers(4, 13)), "arrival_s": 0.0})
    kw = dict(capacity=args.max_batch, max_len=max_len, bucket=bucket)
    # warm every engine's traces, then measure best-of-3 (same CPU-noise
    # rationale as the static-vs-continuous comparison above). The gated
    # baseline is the STATIC slot pool at the equal byte budget (the
    # pre-engine discipline paging is sold against); the continuous
    # equal-byte engine is also reported — against it the structural
    # win is the decode-step count (concurrency), while CPU wall-clock
    # goodput is ~parity because a CPU decode step costs linearly in
    # batch width (on accelerators decode is memory-bound and width is
    # ~free, which is the regime paging targets)
    serve_continuous(sparams, cfg, over, kv_pages=pool_pages,
                     page_size=page_size, **dict(kw, bucket=1))
    serve_continuous(sparams, cfg, over, **dict(kw, capacity=eq_slots))
    serve_static(sparams, cfg, over, capacity=eq_slots, max_len=max_len)
    paged = min((serve_continuous(sparams, cfg, over, kv_pages=pool_pages,
                                  page_size=page_size, **dict(kw, bucket=1))
                 for _ in range(3)), key=lambda r: r["makespan_s"])
    slot_eq = min((serve_continuous(sparams, cfg, over,
                                    **dict(kw, capacity=eq_slots))
                   for _ in range(3)), key=lambda r: r["makespan_s"])
    slot_eq["discipline"] = "slot-equal-bytes"
    static_eq = min((serve_static(sparams, cfg, over, capacity=eq_slots,
                                  max_len=max_len) for _ in range(3)),
                    key=lambda r: r["makespan_s"])
    static_eq["discipline"] = "static-equal-bytes"

    # ---- prefill packing: a burst of short prompts, co-admitted
    # pack-compatible requests share ONE flash call (per-segment
    # masking) instead of one chunk call each. Runs on fp activations:
    # the engine refuses to pack under act_bits<32 because dynamic
    # per-tensor fake-quant scales couple co-packed rows (see
    # runtime/engine.py), so the quantized serve tree above would
    # silently measure nothing. The dispatch counts are structural
    # (deterministic for an all-at-t=0 burst); wall clock is reported
    # but CI gates only the counts.
    fcfg = reduced(get_config(args.arch)).replace(quant=None, act_bits=32,
                                                  remat=False)
    fparams, _ = api.init(jax.random.PRNGKey(args.seed), fcfg)
    prng = np.random.default_rng(args.seed + 2)
    n_pack = 4 * args.max_batch
    pk_reqs = [{"tokens": prng.integers(
                    0, fcfg.vocab,
                    size=(int(prng.integers(4, args.prompt_len + 1)),)
                ).astype(np.int32),
                "max_new": int(prng.integers(2, 7)), "arrival_s": 0.0}
               for _ in range(n_pack)]
    pk_pool = args.max_batch * (-(-max_len // page_size)) * 4 + 1
    pkw = dict(capacity=args.max_batch, max_len=max_len, bucket=1,
               kv_pages=pk_pool, page_size=page_size)
    for pack in (True, False):  # warm both trace sets
        serve_continuous(fparams, fcfg, pk_reqs, prefill_pack=pack, **pkw)
    pk_on = min((serve_continuous(fparams, fcfg, pk_reqs,
                                  prefill_pack=True, **pkw)
                 for _ in range(3)), key=lambda r: r["makespan_s"])
    pk_off = min((serve_continuous(fparams, fcfg, pk_reqs,
                                   prefill_pack=False, **pkw)
                  for _ in range(3)), key=lambda r: r["makespan_s"])
    pk_on["discipline"] = "paged-packed"
    pk_off["discipline"] = "paged-unpacked"

    spec = bench_speculative(args)

    rec = {
        "workload": {
            "arch": cfg.name, "requests": n, "max_batch": args.max_batch,
            "prompt_len": args.prompt_len, "gen": args.gen,
            "seed": args.seed, "quick": bool(args.quick),
            "total_requested_tokens": int(sum(r["max_new"] for r in reqs)),
        },
        "static": static,
        "continuous": cont,
        "speedup_goodput": cont["goodput_tok_s"] / max(static["goodput_tok_s"],
                                                       1e-9),
        "p95_latency_ratio": static["p95_latency_s"] / max(
            cont["p95_latency_s"], 1e-9),
        "paged_overload": {
            "requests": n_over, "shared_sys_prompt_tokens": page_size,
            "kv_pool_pages": pool_pages, "page_size": page_size,
            "equal_bytes_slots": eq_slots,
            "paged": paged,
            "slot_baseline": static_eq,
            "slot_continuous": slot_eq,
            "goodput_ratio": paged["goodput_tok_s"] / max(
                static_eq["goodput_tok_s"], 1e-9),
            "ttft_p99_ratio": paged["ttft_p99_s"] / max(
                static_eq["ttft_p99_s"], 1e-9),
            # structural (wall-clock-noise-free) win over the
            # *continuous* equal-byte engine: decode steps to drain the
            # same workload — fewer steps = more concurrent requests
            # per step at the same KV byte budget
            "concurrency_gain": slot_eq["decode_steps"] / max(
                paged["decode_steps"], 1),
        },
        "prefill_packing": {
            "requests": n_pack, "capacity": args.max_batch,
            "packed": pk_on, "unpacked": pk_off,
            # one packed call replaces the whole group's chunk calls:
            # total prefill dispatches (chunk calls + packed groups)
            # must shrink strictly when packing engages
            "prefill_dispatch_ratio": (
                (pk_on["prefill_chunk_calls"] + pk_on["packed_groups"])
                / max(pk_off["prefill_chunk_calls"]
                      + pk_off["packed_groups"], 1)),
        },
        "speculative": spec,
    }
    for row in (static, cont, paged, slot_eq, static_eq):
        print(f"{row['discipline']:>16s}: goodput {row['goodput_tok_s']:8.1f} "
              f"tok/s | makespan {row['makespan_s']:6.2f} s | "
              f"latency p50 {row['p50_latency_s']*1e3:7.0f} ms "
              f"p95 {row['p95_latency_s']*1e3:7.0f} ms | ttft p99 "
              f"{row['ttft_p99_s']*1e3:7.0f} ms")
    print(f"continuous/static goodput: {rec['speedup_goodput']:.2f}x | "
          f"static/continuous p95 latency: {rec['p95_latency_ratio']:.2f}x")
    ov = rec["paged_overload"]
    print(f"overload x10 ({n_over} reqs, {pool_pages - 1} pages vs "
          f"{eq_slots} equal-byte slots): paged/static goodput "
          f"{ov['goodput_ratio']:.2f}x | ttft p99 ratio "
          f"{ov['ttft_p99_ratio']:.2f}x | concurrency gain vs "
          f"continuous {ov['concurrency_gain']:.2f}x | prefix hit "
          f"{paged.get('prefix_hit_rate', 0)*100:.0f}% | per-request KV "
          f"{paged.get('kv_bytes_per_request_mean', 0)/1024:.1f} KiB")
    pp = rec["prefill_packing"]
    print(f"prefill packing ({n_pack} short prompts, fp activations): "
          f"{pp['packed']['packed_groups']} packed groups covering "
          f"{pp['packed']['packed_requests']} requests | prefill "
          f"dispatches {pp['packed']['prefill_chunk_calls'] + pp['packed']['packed_groups']} "
          f"vs {pp['unpacked']['prefill_chunk_calls']} unpacked "
          f"({pp['prefill_dispatch_ratio']:.2f}x) | modeled decode KV "
          f"{paged.get('decode_kv_bytes_per_step_model', 0)/1024:.1f} "
          f"KiB/step, pages-read ratio vs gather "
          f"{paged.get('pages_read_ratio_vs_gather', 0):.2f}")
    print(f"speculative (k={spec['k']}, draft_bits={spec['draft_bits']}, "
          f"{spec['policy']}): parity={spec['token_parity']} | acceptance "
          f"{spec['acceptance_rate']*100:.0f}% | "
          f"{spec['spec_tokens_per_round']:.2f} tok/round | tok/engine-step "
          f"{spec['tokens_per_engine_step']:.2f} vs "
          f"{spec['baseline_tokens_per_engine_step']:.2f} "
          f"({spec['tokens_per_step_ratio']:.2f}x) | weight bytes/accepted "
          f"{spec['weight_bytes_per_accepted_token']/1024:.1f} KiB vs "
          f"{spec['baseline_weight_bytes_per_token']/1024:.1f} KiB "
          f"({spec['weight_bytes_ratio']:.2f}x) | draft view "
          f"+{spec['draft_extra_resident_bytes']/1024:.1f} KiB "
          f"({spec['draft_coarse_leaves']} coarse leaves)")
    Path(args.json_out).write_text(json.dumps(rec, indent=1))
    print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
