"""Distillation for LUT-Q training (paper §4: "Distillation is
compatible with our training approach and we are planning to investigate
LUT-Q training together with distillation").

Implements the apprentice-style joint loss the paper cites ([15]):
    L = (1-alpha) * CE(student, labels) + alpha * T^2 * KL(teacher || student)
where the student is the LUT-Q-quantized network and the teacher a
full-precision one. Plugs into make_train_step as a loss_fn wrapper.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def kd_loss(student_logits, teacher_logits, *, temperature: float = 2.0):
    """KL(teacher || student) with temperature, mean over positions."""
    t = temperature
    p_t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    p_s = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    kl = jnp.sum(jnp.exp(p_t) * (p_t - p_s), axis=-1)
    return (t * t) * jnp.mean(kl)


def make_distill_loss(
    forward: Callable,
    teacher_params,
    cfg_teacher,
    *,
    alpha: float = 0.7,
    temperature: float = 2.0,
):
    """Wrap a (params, cfg, batch) -> (loss, metrics) LM objective.

    `forward(params, cfg, tokens, ...)` must return (logits, aux).
    Teacher params are closed over and never receive gradients.
    """

    def loss_fn(params, cfg, batch):
        s_logits, _ = forward(params, cfg, batch["tokens"])
        t_logits, _ = forward(jax.lax.stop_gradient(teacher_params),
                              cfg_teacher, batch["tokens"])
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lg = s_logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
        ce = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        kd = kd_loss(s_logits, t_logits, temperature=temperature)
        loss = (1 - alpha) * ce + alpha * kd
        return loss, {"loss": ce, "kd": kd}

    return loss_fn
