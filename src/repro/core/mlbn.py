"""Multiplier-less batch normalization (paper Appendix A).

At inference BN collapses to ``y = a*x + b`` with
``a = gamma / sqrt(VAR + eps)``. ML-BN requires ``a`` to be powers of
two so inference needs only bit-shifts and adds. During training the
forward pass uses the pow2-quantized effective scale (gamma_hat) while
the backward pass updates the full-precision gamma via STE — exactly the
scheme in Appendix A (quantize at inference, not BinaryNet's
shift-based-training scheme).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.lutq import pow2_round


class BNParams(NamedTuple):
    gamma: jax.Array  # full-precision, trained
    beta: jax.Array


class BNStats(NamedTuple):
    mean: jax.Array  # running mean of inputs
    var: jax.Array   # running variance of inputs


def init_bn(num_features: int, dtype=jnp.float32) -> Tuple[BNParams, BNStats]:
    return (
        BNParams(jnp.ones((num_features,), dtype), jnp.zeros((num_features,), dtype)),
        BNStats(jnp.zeros((num_features,), dtype), jnp.ones((num_features,), dtype)),
    )


def _ml_scale(gamma: jax.Array, var: jax.Array, eps: float) -> jax.Array:
    """Effective scale a = gamma/sqrt(var+eps), pow2-quantized with STE."""
    a = gamma * jax.lax.rsqrt(var + eps)
    return a + jax.lax.stop_gradient(pow2_round(a) - a)


def batch_norm(
    x: jax.Array,
    params: BNParams,
    stats: BNStats,
    *,
    training: bool,
    multiplier_less: bool = False,
    eps: float = 1e-5,
    momentum: float = 0.9,
    axis: int = -1,
) -> Tuple[jax.Array, BNStats]:
    """BN over all axes except `axis` (the feature axis).

    Returns (y, new_stats). With ``multiplier_less=True`` the effective
    scale is pow2-quantized (STE on gamma) so the *inference* form
    ``y = pow2(a)*x + b`` is multiplier-less.
    """
    reduce_axes = tuple(i for i in range(x.ndim) if i != (axis % x.ndim))
    if training:
        mean = jnp.mean(x, axis=reduce_axes)
        var = jnp.var(x, axis=reduce_axes)
        new_stats = BNStats(
            momentum * stats.mean + (1 - momentum) * jax.lax.stop_gradient(mean),
            momentum * stats.var + (1 - momentum) * jax.lax.stop_gradient(var),
        )
    else:
        mean, var = stats.mean, stats.var
        new_stats = stats

    shape = [1] * x.ndim
    shape[axis % x.ndim] = x.shape[axis % x.ndim]

    if multiplier_less:
        a = _ml_scale(params.gamma, var, eps)
        b = params.beta - a * mean
        y = a.reshape(shape) * x + b.reshape(shape)
    else:
        a = params.gamma * jax.lax.rsqrt(var + eps)
        b = params.beta - a * mean
        y = a.reshape(shape) * x + b.reshape(shape)
    return y, new_stats


def apply_scale_offset_shift(x: jax.Array, a: jax.Array, b: jax.Array,
                             *, axis: int = -1) -> jax.Array:
    """``y = a*x + b`` for exact-pow2 ``a``, computed without multiplies.

    The scale is applied as an exponent add (``ldexp``) on a sign-flipped
    ``x`` — negate, shift, add — which is the ML-BN inference claim made
    literal. Bit-identical to ``a*x + b`` for ``a = ±2^k`` in the normal
    float range, so the trained ``multiplier_less`` forward and this
    serve form agree exactly.
    """
    shape = [1] * x.ndim
    shape[axis % x.ndim] = x.shape[axis % x.ndim]
    a = a.reshape(shape)
    b = b.reshape(shape)
    e = jnp.round(jnp.log2(jnp.where(a != 0, jnp.abs(a), 1.0))).astype(jnp.int32)
    y = jnp.ldexp(jnp.where(a < 0, -x, x), e)
    return jnp.where(a != 0, y, jnp.zeros((), x.dtype)) + b


def inference_scale_offset(
    params: BNParams, stats: BNStats, *, multiplier_less: bool = False, eps: float = 1e-5
) -> Tuple[jax.Array, jax.Array]:
    """The folded (a, b) used at inference; a is exact pow2 under ML-BN."""
    a = params.gamma * jax.lax.rsqrt(stats.var + eps)
    if multiplier_less:
        a = pow2_round(a)
    b = params.beta - a * stats.mean
    return a, b
