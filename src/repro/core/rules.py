"""Rule-based mixed-precision quantization policies.

The paper's experiments never quantize uniformly: first/last layers stay
high-precision and per-layer bitwidths are swept (LUT-Q journal version,
arXiv 1911.04951). A :class:`QuantPolicy` expresses this as an ordered
list of :class:`QuantRule`s — each a path pattern over pytree paths
mapped to a :class:`QuantSpec` (or ``None`` to exclude) — resolved with
first-match-wins semantics.

Pattern syntax (matched against ``"/".join(path)``):
  * glob (default): ``fnmatch`` where ``*`` crosses ``/`` — e.g.
    ``*/attn/*`` matches ``layers/attn/q/kernel``; ``*/moe/w*`` matches
    ``layers/moe/wi``.
  * regex: prefix with ``re:`` — e.g. ``re:(^|/)table$`` matches any
    leaf named ``table`` at any depth (``re.search`` semantics).

A bare :class:`QuantSpec` anywhere a policy is accepted auto-wraps as
``uniform(spec)``, reproducing the historical single-knob behavior
bit-identically.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import re
from typing import Any, Dict, Optional, Sequence, Tuple, Union

from repro.core.spec import (
    LUTQ_2BIT_POW2,
    LUTQ_4BIT,
    LUTQ_4BIT_POW2,
    SERVING_POW2,
    TERNARY_SCALED,
    QuantSpec,
    spec_from_dict,
    spec_to_dict,
)


@dataclasses.dataclass(frozen=True)
class QuantRule:
    """One pattern -> spec mapping.

    Attributes:
      pattern: glob (or ``re:``-prefixed regex) over the joined path.
      spec: the QuantSpec to apply, or None to exclude from quantization.
      min_size: per-rule eligibility floor; defaults to spec.min_size.
        Tensors smaller than the floor are left unquantized even when
        the pattern matches (the rule still *claims* the leaf: matching
        stops — first match wins).
      name: id used in reports/serialization; defaults to the pattern.
      backend: serving kernel backend for the matched leaves ('auto' |
        'decode' | 'fused' | 'packed4'); None defers to spec.backend.
        Resolved per leaf by serve_view / kernels.ops.lutq_dot.
    """

    pattern: str
    spec: Optional[QuantSpec]
    min_size: Optional[int] = None
    name: Optional[str] = None
    backend: Optional[str] = None

    def __post_init__(self):
        if self.backend not in (None, "auto", "decode", "fused", "packed4",
                                "pow2"):
            raise ValueError(f"unknown kernel backend {self.backend!r}")

    @property
    def rule_name(self) -> str:
        return self.name if self.name is not None else self.pattern

    @property
    def resolved_backend(self) -> str:
        """Requested backend: rule override > spec.backend > 'auto'."""
        if self.backend is not None:
            return self.backend
        return self.spec.backend if self.spec is not None else "auto"

    def matches(self, path: Tuple[str, ...]) -> bool:
        joined = "/".join(path)
        if self.pattern.startswith("re:"):
            return re.search(self.pattern[3:], joined) is not None
        return fnmatch.fnmatchcase(joined, self.pattern)

    @property
    def size_floor(self) -> int:
        if self.min_size is not None:
            return self.min_size
        return self.spec.min_size if self.spec is not None else 0


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered first-match-wins rule list over pytree paths.

    A leaf claimed by no rule stays unquantized. Hashable (usable inside
    a jit-static ModelConfig) and JSON-serializable (checkpoint
    manifests, ``--quant-policy``).
    """

    rules: Tuple[QuantRule, ...]
    name: str = "custom"

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))

    # -- resolution ---------------------------------------------------------
    def match(self, path: Tuple[str, ...]) -> Optional[int]:
        """Index of the first rule whose pattern matches, else None."""
        for i, rule in enumerate(self.rules):
            if rule.matches(path):
                return i
        return None

    def resolve(self, path: Tuple[str, ...], size: Optional[int] = None
                ) -> Tuple[Optional[int], Optional[QuantSpec]]:
        """(rule_id, spec) for a leaf. spec is None when the leaf stays
        full-precision (no match, exclusion rule, or under the rule's
        size floor)."""
        i = self.match(path)
        if i is None:
            return None, None
        rule = self.rules[i]
        if rule.spec is None:
            return i, None
        if size is not None and size < rule.size_floor:
            return i, None
        return i, rule.spec

    def spec_of(self, rule_id: int) -> Optional[QuantSpec]:
        return self.rules[rule_id].spec

    # -- composition --------------------------------------------------------
    def prepend(self, rule: QuantRule) -> "QuantPolicy":
        return QuantPolicy(rules=(rule,) + self.rules, name=self.name)

    @property
    def specs(self) -> Tuple[QuantSpec, ...]:
        return tuple(r.spec for r in self.rules if r.spec is not None)

    @property
    def is_uniform(self) -> bool:
        return len(self.rules) == 1 and self.rules[0].pattern == "*" \
            and self.rules[0].spec is not None

    def dominant_spec(self) -> Optional[QuantSpec]:
        """Spec of the last spec-carrying rule — by convention the
        catch-all that covers the bulk of the network (used by analytic
        models that need one representative spec)."""
        for rule in reversed(self.rules):
            if rule.spec is not None:
                return rule.spec
        return None

    # -- serialization ------------------------------------------------------
    def to_json_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "rules": [
                {"pattern": r.pattern,
                 "spec": None if r.spec is None else spec_to_dict(r.spec),
                 "min_size": r.min_size,
                 "name": r.name,
                 "backend": r.backend}
                for r in self.rules
            ],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict())

    @staticmethod
    def from_json_dict(d: Dict[str, Any]) -> "QuantPolicy":
        if "rules" not in d or not isinstance(d["rules"], list):
            raise ValueError("policy JSON needs a 'rules' list")
        rules = []
        for i, r in enumerate(d["rules"]):
            if "pattern" not in r:
                raise ValueError(f"policy rule [{i}] is missing 'pattern': {r}")
            rules.append(
                QuantRule(pattern=r["pattern"],
                          spec=None if r.get("spec") is None
                          else spec_from_dict(r["spec"]),
                          min_size=r.get("min_size"),
                          name=r.get("name"),
                          backend=r.get("backend")))
        return QuantPolicy(rules=tuple(rules), name=d.get("name", "custom"))

    @staticmethod
    def from_json(s: str) -> "QuantPolicy":
        return QuantPolicy.from_json_dict(json.loads(s))

    def describe(self) -> str:
        lines = [f"QuantPolicy {self.name!r}:"]
        for i, r in enumerate(self.rules):
            if r.spec is None:
                rhs = "fp (excluded)"
            else:
                rhs = (f"{r.spec.bits}-bit/{r.spec.constraint}"
                       f" (K={r.spec.K}, min_size={r.size_floor})")
            if r.resolved_backend != "auto":
                rhs += f" [{r.resolved_backend}]"
            if r.spec is not None and r.spec.act_bits < 32:
                rhs += (f" act{r.spec.act_bits}"
                        f"{'-frozen' if r.spec.act_frozen else ''}")
            lines.append(f"  [{i}] {r.rule_name:24s} {r.pattern:20s} -> {rhs}")
        return "\n".join(lines)


QuantLike = Union[QuantSpec, QuantPolicy]


def as_policy(quant: Optional[QuantLike]) -> Optional[QuantPolicy]:
    """Normalize a QuantSpec | QuantPolicy | None to a policy (or None)."""
    if quant is None or isinstance(quant, QuantPolicy):
        return quant
    if isinstance(quant, QuantSpec):
        return uniform(quant)
    raise TypeError(f"expected QuantSpec or QuantPolicy, got {type(quant)}")


# ---------------------------------------------------------------------------
# named presets
# ---------------------------------------------------------------------------

# Leaves named `table` (embeddings / tied softmax) at any depth, and the
# untied output head. These are the paper's "first/last layer" set.
EMBED_PATTERN = "re:(^|/)table$"
HEAD_PATTERN = "lm_head/*"


def uniform(spec: QuantSpec, name: str = "uniform") -> QuantPolicy:
    """Single catch-all rule: exactly the historical global-QuantSpec
    behavior (same eligibility predicate, same min_size floor)."""
    return QuantPolicy(rules=(QuantRule("*", spec, name="all"),), name=name)


def paper_default(spec: QuantSpec = LUTQ_4BIT_POW2) -> QuantPolicy:
    """Quantize the body, keep first/last layers full-precision — the
    configuration every experiment table in the paper actually uses."""
    return QuantPolicy(
        rules=(QuantRule(EMBED_PATTERN, None, name="first-layer-fp"),
               QuantRule(HEAD_PATTERN, None, name="last-layer-fp"),
               QuantRule("*", spec, name="body")),
        name="paper_default")


def serving_aggressive() -> QuantPolicy:
    """Serving-footprint preset: fp embeddings, 4-bit attention,
    2-bit-pow2 MLP/MoE, 4-bit-pow2 everything else."""
    return QuantPolicy(
        rules=(QuantRule(EMBED_PATTERN, None, name="embed-fp"),
               QuantRule(HEAD_PATTERN, None, name="head-fp"),
               QuantRule("*/attn/*", LUTQ_4BIT, name="attn-4bit"),
               QuantRule("*/mlp/*", LUTQ_2BIT_POW2, name="mlp-2bit-pow2"),
               QuantRule("*/moe/*", LUTQ_2BIT_POW2, name="moe-2bit-pow2"),
               QuantRule("*", LUTQ_4BIT_POW2, name="rest-4bit-pow2")),
        name="serving_aggressive")


def mixed_paper() -> QuantPolicy:
    """The acceptance-criteria mix: fp embeddings + excluded first/last
    layers, 4-bit-pow2 attention, 2-bit ternary MLPs."""
    return QuantPolicy(
        rules=(QuantRule(EMBED_PATTERN, None, name="first-layer-fp"),
               QuantRule(HEAD_PATTERN, None, name="last-layer-fp"),
               QuantRule("*/attn/*", LUTQ_4BIT_POW2, name="attn-4bit-pow2"),
               QuantRule("*/mlp/*", TERNARY_SCALED, name="mlp-ternary"),
               QuantRule("*/moe/*", TERNARY_SCALED, name="moe-ternary"),
               QuantRule("*", LUTQ_4BIT_POW2, name="rest-4bit-pow2")),
        name="mixed_paper")


def serving_pow2() -> QuantPolicy:
    """Multiplier-less deployment: fp embeddings/head, everything else a
    pow2 dictionary served as sign+exponent planes through the shift-add
    kernel with int8 activations at calibration-frozen scales (paper
    headline + Appendix A; see docs/multiplierless.md)."""
    return QuantPolicy(
        rules=(QuantRule(EMBED_PATTERN, None, name="first-layer-fp"),
               QuantRule(HEAD_PATTERN, None, name="last-layer-fp"),
               QuantRule("*", SERVING_POW2, name="body-pow2-shift")),
        name="serving_pow2")


PRESETS = {
    "paper_default": paper_default,
    "serving_aggressive": serving_aggressive,
    "mixed_paper": mixed_paper,
    "serving_pow2": serving_pow2,
}


def get_policy(name_or_json: str) -> QuantPolicy:
    """Resolve a --quant-policy CLI value: preset name, ``uniform:<bits>
    [:<constraint>]``, inline JSON, or an ``@file.json`` path."""
    s = name_or_json.strip()
    if s in PRESETS:
        return PRESETS[s]()
    if s.startswith("uniform:"):
        parts = s.split(":")
        bits = int(parts[1])
        constraint = parts[2] if len(parts) > 2 else "none"
        return uniform(QuantSpec(bits=bits, constraint=constraint),
                       name=f"uniform{bits}")
    if s.startswith("@"):
        with open(s[1:]) as f:
            return QuantPolicy.from_json(f.read())
    if s.startswith("{"):
        return QuantPolicy.from_json(s)
    raise ValueError(
        f"unknown quant policy {name_or_json!r}; expected one of "
        f"{sorted(PRESETS)}, 'uniform:<bits>[:<constraint>]', inline JSON, "
        f"or @path/to/policy.json")
