"""Uniform activation fake-quantization (paper section 2 / 4: 8-bit).

The paper runs all quantized-weight experiments with activations
"quantized uniformly to 8-bit". We implement symmetric per-tensor
uniform fake-quant with a straight-through gradient. The scale is
dynamic (max-abs of the tensor) by default, which is what NNabla's
uniform quantizer does absent calibration, and can be frozen for
deployment.

Frozen scales + calibration
---------------------------
The activation-quant *regime* threads ``act_bits`` from each leaf's
:class:`repro.core.spec.QuantSpec` through the layer contract
(``nn.linear.dot_kernel`` and friends) instead of hand-placed
``fake_quant`` calls inside model code. Rules with ``act_frozen=True``
additionally carry a calibrated per-leaf ``[scale, qmax]`` pair in
``LutqState.act``:

1. :func:`tag_act_capture` wraps every quantized leaf with its tree
   path;
2. a short forward under :func:`capture_act_scales` records per-leaf
   running max|x| at each matmul boundary (``jax.debug.callback``, so
   jit/scan/vmap all work);
3. :func:`apply_act_scales` freezes ``scale = amax / qmax`` into
   ``LutqState.act`` for every rule with ``act_frozen`` and
   ``act_bits < 32``.

The frozen pair persists through ``serve_view`` and checkpoints, and is
what the multiplier-less ``pow2`` kernel backend uses to int8-quantize
activations without a runtime max-reduction.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.lutq import LutqState


def fake_quant(x: jax.Array, bits: int = 8, scale: jax.Array | None = None) -> jax.Array:
    """Symmetric uniform fake-quant with STE.

    q = clip(round(x / s), -2^{b-1}, 2^{b-1}-1) * s, gradient = identity.
    """
    if bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    if scale is None:
        amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
        scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1.0, qmax) * scale
    return x + jax.lax.stop_gradient(q - x)


def learned_clip_fake_quant(x: jax.Array, alpha: jax.Array,
                            bits: int = 8) -> jax.Array:
    """PACT-style non-uniform-friendly activation quantization with a
    *learned* clipping range (paper §4's future direction: activation
    quantization with learned parameters, lowering the bitwidth floor).

    alpha (scalar, trained) sets the clip; gradient reaches alpha through
    the clip boundary (STE inside the range).
    """
    if bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    a = jnp.abs(alpha) + 1e-6
    xc = jnp.clip(x, -a, a)
    scale = a / qmax
    q = jnp.round(xc / scale) * scale
    # value: quantized; gradient: d/dx = 1 inside clip (STE), d/dalpha via clip
    return xc + jax.lax.stop_gradient(q - xc)


def fake_quant_frozen(x: jax.Array, act: jax.Array) -> jax.Array:
    """STE fake-quant against a frozen calibration pair.

    ``act`` is ``LutqState.act``: trailing-axis ``[scale, qmax]``. Uses
    the same symmetric clip as the pow2 kernels' internal int8 path
    (``kernels.ops._pow2_act_quant``), so a frozen-scale fused forward
    and the shift-add forward quantize activations identically.
    """
    scale = act[..., 0].astype(jnp.float32)
    qmax = act[..., 1].astype(jnp.float32)
    s = jnp.where(scale > 0, scale, 1.0)
    q = (jnp.clip(jnp.round(x.astype(jnp.float32) / s), -qmax, qmax)
         * s).astype(x.dtype)
    return x + jax.lax.stop_gradient(q - x)


def relu_fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    """Unsigned variant for post-ReLU activations (full range on [0, max])."""
    if bits >= 32:
        return jax.nn.relu(x)
    x = jax.nn.relu(x)
    qmax = 2.0 ** bits - 1.0
    amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), 0.0, qmax) * scale
    return x + jax.lax.stop_gradient(q - x)


# ---------------------------------------------------------------------------
# calibration: capture per-leaf activation maxima, freeze [scale, qmax]
# ---------------------------------------------------------------------------

class TaggedLutqState:
    """A :class:`LutqState` carrying its tree path as a static tag.

    Calibration-only wrapper: ``tag_act_capture`` wraps the params tree,
    the layer contract (``nn.linear.dot_kernel`` etc.) calls
    :func:`record_amax` with the tag before unwrapping, and the wrapper
    never escapes the calibration forward. Registered as a pytree with
    the tag static so scan/vmap slice the inner state transparently.
    """

    __slots__ = ("state", "tag")

    def __init__(self, state: LutqState, tag: str):
        self.state = state
        self.tag = tag

    @property
    def w(self):
        return self.state.w

    @property
    def d(self):
        return self.state.d

    @property
    def a(self):
        return self.state.a

    @property
    def sid(self):
        return self.state.sid

    @property
    def act(self):
        return self.state.act


jax.tree_util.register_pytree_node(
    TaggedLutqState,
    lambda s: ((s.state,), s.tag),
    lambda tag, children: TaggedLutqState(children[0], tag),
)

# Active capture record: {tag: running max |x|}. None == not capturing.
_CAPTURE: Optional[Dict[str, float]] = None


@contextlib.contextmanager
def capture_act_scales():
    """Context manager yielding the ``{tag: amax}`` record dict.

    Run calibration forwards inside the block (on a tree wrapped by
    :func:`tag_act_capture`); the record fills via runtime callbacks, so
    block on the forward's outputs before leaving the block.
    """
    global _CAPTURE
    prev, _CAPTURE = _CAPTURE, {}
    try:
        yield _CAPTURE
    finally:
        _CAPTURE = prev


def record_amax(tag: str, x: jax.Array) -> None:
    """Fold max|x| into the active capture record (no-op when inactive).

    ``jax.debug.callback`` defers the host write to runtime, so this
    works under jit/scan/vmap; stacked leaves (scan-over-layers,
    experts) fold every slice into one per-leaf maximum — calibration is
    per *leaf*, not per slice.
    """
    rec = _CAPTURE
    if rec is None:
        return
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))

    def cb(v):
        rec[tag] = max(rec.get(tag, 0.0), float(v))

    jax.debug.callback(cb, amax)


def tag_act_capture(params):
    """Wrap every quantized leaf with its path for calibration capture."""
    from repro.nn.tree import map_with_path

    def wrap(path, leaf):
        if isinstance(leaf, LutqState):
            return TaggedLutqState(leaf, "/".join(path))
        return leaf

    return map_with_path(wrap, params)


def apply_act_scales(params, record: Dict[str, float], quant=None):
    """Freeze captured maxima into ``LutqState.act`` pairs.

    Only leaves whose governing rule has ``act_frozen=True`` and
    ``act_bits < 32`` are filled (``quant`` is a QuantPolicy / QuantSpec
    / None); others pass through untouched. The pair is broadcast over
    stack slices: ``act = [amax / qmax, qmax]`` with
    ``qmax = 2^(act_bits-1) - 1`` (clamped to int8 range by the pow2
    consumers).
    """
    from repro.core.rules import as_policy
    from repro.nn.tree import map_with_path

    pol = as_policy(quant)

    def fill(path, leaf):
        if not isinstance(leaf, LutqState):
            return leaf
        spec = None
        if pol is not None:
            i = pol.match(path)
            spec = pol.rules[i].spec if i is not None else None
        if spec is None or spec.act_bits >= 32 or not spec.act_frozen:
            return leaf
        amax = record.get("/".join(path))
        if amax is None or amax <= 0.0:
            return leaf
        qmax = float(2.0 ** (spec.act_bits - 1) - 1.0)
        pair = jnp.array([amax / qmax, qmax], jnp.float32)
        act = jnp.broadcast_to(pair, leaf.d.shape[:-1] + (2,)) + 0.0
        return leaf._replace(act=act)

    return map_with_path(fill, params)
