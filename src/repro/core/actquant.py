"""Uniform activation fake-quantization (paper section 2 / 4: 8-bit).

The paper runs all quantized-weight experiments with activations
"quantized uniformly to 8-bit". We implement symmetric per-tensor
uniform fake-quant with a straight-through gradient. The scale is
dynamic (max-abs of the tensor) by default, which is what NNabla's
uniform quantizer does absent calibration, and can be frozen for
deployment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_quant(x: jax.Array, bits: int = 8, scale: jax.Array | None = None) -> jax.Array:
    """Symmetric uniform fake-quant with STE.

    q = clip(round(x / s), -2^{b-1}, 2^{b-1}-1) * s, gradient = identity.
    """
    if bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    if scale is None:
        amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
        scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1.0, qmax) * scale
    return x + jax.lax.stop_gradient(q - x)


def learned_clip_fake_quant(x: jax.Array, alpha: jax.Array,
                            bits: int = 8) -> jax.Array:
    """PACT-style non-uniform-friendly activation quantization with a
    *learned* clipping range (paper §4's future direction: activation
    quantization with learned parameters, lowering the bitwidth floor).

    alpha (scalar, trained) sets the clip; gradient reaches alpha through
    the clip boundary (STE inside the range).
    """
    if bits >= 32:
        return x
    qmax = 2.0 ** (bits - 1) - 1.0
    a = jnp.abs(alpha) + 1e-6
    xc = jnp.clip(x, -a, a)
    scale = a / qmax
    q = jnp.round(xc / scale) * scale
    # value: quantized; gradient: d/dx = 1 inside clip (STE), d/dalpha via clip
    return xc + jax.lax.stop_gradient(q - xc)


def relu_fake_quant(x: jax.Array, bits: int = 8) -> jax.Array:
    """Unsigned variant for post-ReLU activations (full range on [0, max])."""
    if bits >= 32:
        return jax.nn.relu(x)
    x = jax.nn.relu(x)
    qmax = 2.0 ** bits - 1.0
    amax = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), 0.0, qmax) * scale
    return x + jax.lax.stop_gradient(q - x)
