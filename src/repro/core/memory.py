"""Analytic memory/compute accounting (paper section 1 formulas).

Storage of one LUT-Q layer with N weights and K dictionary entries:
    bits = K * B_float + N * ceil(log2 K)
vs. N * B_float unquantized. Multiplications per affine output neuron
drop from I to K (group-by-entry summation).

These functions drive the Table 2 reproduction (ResNet-50 @ 2-bit
weights + 8-bit activations = 7.4 MB vs 97.5 MB full precision).
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Tuple


def lutq_layer_bits(n_params: int, K: int, b_float: int = 32) -> int:
    """Storage bits for one LUT-Q quantized tensor."""
    return K * b_float + n_params * max(1, math.ceil(math.log2(K)))


def dense_layer_bits(n_params: int, b_float: int = 32) -> int:
    return n_params * b_float


def pow2_layer_bits(n_params: int, K: int, *, act_pair: bool = True) -> int:
    """Storage bits for one pow2-encoded LUT-Q tensor (serving_pow2).

    The dictionary ships as an int8 sign+exponent plane (8 bits/entry
    instead of ``b_float``); indices are unchanged. ``act_pair`` adds the
    frozen per-leaf activation ``[scale, qmax]`` f32 pair.
    """
    bits = K * 8 + n_params * max(1, math.ceil(math.log2(K)))
    if act_pair:
        bits += 2 * 32
    return bits


def affine_shift_ops(out_features: int, in_features: int,
                     K: int | None = None) -> Dict[str, int]:
    """Multiplier-less op budget for one affine layer forward.

    Group-by-entry summation costs O*I integer adds; applying the pow2
    dictionary is O*K bit-shifts (exponent adds) instead of O*K
    multiplications; the only fp multiplies left are the epilogue scale —
    one per output neuron. ``K=None`` is the dense baseline (all MACs).
    """
    if K is None:
        return {"adds": out_features * in_features,
                "shifts": 0, "fp_mults": out_features * in_features}
    return {"adds": out_features * in_features,
            "shifts": out_features * K, "fp_mults": out_features}


def conv_shift_ops(out_ch: int, in_ch: int, kh: int, kw: int, oh: int,
                   ow: int, K: int | None = None) -> Dict[str, int]:
    """Conv analogue of :func:`affine_shift_ops` (per example)."""
    pix = oh * ow * out_ch
    taps = in_ch * kh * kw
    if K is None:
        return {"adds": pix * taps, "shifts": 0, "fp_mults": pix * taps}
    return {"adds": pix * taps, "shifts": pix * K, "fp_mults": pix}


def affine_mults(out_features: int, in_features: int, K: int | None = None) -> int:
    """Multiplications for one affine layer forward (per example).

    Standard: O*I. LUT-Q: O*K (sum inputs per dictionary entry first,
    then K multiplications per output neuron).
    """
    if K is None:
        return out_features * in_features
    return out_features * K


def conv_mults(
    out_ch: int, in_ch: int, kh: int, kw: int, oh: int, ow: int, K: int | None = None
) -> int:
    """Multiplications for a conv layer forward (per example).

    Standard: oh*ow*out_ch*(in_ch*kh*kw). LUT-Q: each output pixel+channel
    needs only K multiplications after grouping taps by dictionary entry.
    """
    if K is None:
        return oh * ow * out_ch * in_ch * kh * kw
    return oh * ow * out_ch * K


def footprint_mb(
    layer_sizes: Iterable[Tuple[str, int]],
    *,
    weight_bits: int | None,
    K: int | None,
    act_elems: int = 0,
    act_bits: int = 32,
    b_float: int = 32,
    quantize_all: bool = True,
) -> float:
    """Total footprint in MB (10^6 bytes? No — paper uses MiB-as-MB; we use MiB).

    layer_sizes: (name, n_params) of every affine/conv weight tensor.
    weight_bits/K: None -> full precision; else LUT-Q with K entries.
    act_elems: peak activation working-set elements (inference, batch 1).
    """
    bits = 0
    for _, n in layer_sizes:
        if K is None or not quantize_all:
            bits += dense_layer_bits(n, b_float)
        else:
            bits += lutq_layer_bits(n, K, b_float)
    bits += act_elems * act_bits
    return bits / 8 / 2**20


def policy_footprint(
    layer_sizes: Iterable[Tuple[str, int]],
    policy,
    *,
    b_float: int = 32,
) -> Dict[str, Dict]:
    """Per-rule storage breakdown under a QuantPolicy (analytic).

    layer_sizes: (name, n_params) pairs; names are treated as
    pytree-style paths (split on '/') for rule matching. Returns
    {rule_name: {n_params, n_tensors, bits_per_weight, mib}} plus an
    '(unmatched)' row for tensors no rule claims (stored full-precision)
    and a '(total)' row.
    """
    from repro.core.rules import as_policy

    pol = as_policy(policy)
    rows: Dict[str, Dict] = {}

    def row(name, bits_per_weight):
        return rows.setdefault(name, {"n_params": 0, "n_tensors": 0,
                                      "bits_per_weight": bits_per_weight,
                                      "bits": 0})

    for name, n in layer_sizes:
        path = tuple(name.split("/"))
        i, spec = pol.resolve(path, size=n)
        if spec is None:
            if i is None:
                label = "(unmatched)"
            elif pol.rules[i].spec is not None:
                # claimed by a spec rule but under its size floor: keep
                # these fp leaves in their own row so each row's
                # bits_per_weight stays homogeneous
                label = f"{pol.rules[i].rule_name} (fp<floor)"
            else:
                label = pol.rules[i].rule_name
            r = row(label, b_float)
            r["bits"] += dense_layer_bits(n, b_float)
        else:
            r = row(pol.rules[i].rule_name, spec.index_bits)
            r["bits"] += lutq_layer_bits(n, spec.K, b_float)
        r["n_params"] += n
        r["n_tensors"] += 1

    total = {"n_params": sum(r["n_params"] for r in rows.values()),
             "n_tensors": sum(r["n_tensors"] for r in rows.values()),
             "bits_per_weight": None,
             "bits": sum(r["bits"] for r in rows.values())}
    rows["(total)"] = total
    for r in rows.values():
        r["mib"] = r["bits"] / 8 / 2**20
    return rows
