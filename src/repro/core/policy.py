"""Quantization policy: which tensors get LUT-Q, with which spec.

Walks a parameter pytree, converts eligible kernel leaves to
:class:`LutqState` (per-tensor dictionary; stacked leading axes — e.g.
scan-over-layers or MoE experts — get per-slice dictionaries via vmap),
and provides the step-4 k-means refresh over a whole tree.
"""
from __future__ import annotations

import re
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lutq import LutqState, init_state, update_state
from repro.core.spec import QuantSpec
from repro.nn.tree import map_with_path, tree_paths

# Parameters that never get quantized regardless of size (norm gains,
# biases, routers, decay/bonus vectors, conv states...). The paper
# quantizes affine/convolution *weights* only.
_EXCLUDE = re.compile(
    r"(bias|scale|ln|norm|router|A_log|dt_bias|^D$|w0|^u$|mix_|conv_b|gamma|beta)"
)


def default_predicate(path: Tuple[str, ...], leaf) -> bool:
    name = path[-1] if path else ""
    joined = "/".join(path)
    if _EXCLUDE.search(name) or _EXCLUDE.search(joined.split("/")[-1]):
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    return True


# Logical axis names that index *independent tensors* (each gets its own
# LUT-Q dictionary): scan-over-layers stacks and MoE experts.
STACK_AXES = frozenset({"layer", "super", "inner", "expert"})


def _stacked_dims(path: Tuple[str, ...], leaf, axes=None) -> int:
    """Leading axes that index independent tensors (layer stack, experts).

    When the logical-axes tuple for this leaf is available we count its
    leading STACK_AXES names (exact); otherwise fall back to ndim-2 with
    a conv (HWIO, path-unstacked) exception.
    """
    if axes is not None:
        n = 0
        for name in axes:
            if name in STACK_AXES:
                n += 1
            else:
                break
        return n
    if path and path[-1] == "kernel" and leaf.ndim == 4:
        return 0  # conv HWIO
    return max(0, leaf.ndim - 2)


def _vmapped(fn, n: int):
    for _ in range(n):
        fn = jax.vmap(fn)
    return fn


def quantize_tree(params, spec: QuantSpec, predicate: Callable = default_predicate,
                  axes=None):
    """Convert eligible leaves to LutqState (per-slice dictionaries).

    ``axes``: optional logical-axes tree (as returned by model init) used
    to identify stack axes exactly.
    """

    def lookup_axes(path):
        node = axes
        for k in path:
            if not isinstance(node, dict) or k not in node:
                return None
            node = node[k]
        return node if isinstance(node, (tuple, list)) else None

    def convert(path, leaf):
        if isinstance(leaf, LutqState) or not predicate(path, leaf):
            return leaf
        if leaf.size < spec.min_size:
            return leaf
        nstack = _stacked_dims(path, leaf, lookup_axes(path))
        f = _vmapped(lambda w: init_state(w, spec), nstack)
        return f(leaf)

    return map_with_path(convert, params)


def kmeans_tree(params, spec: QuantSpec):
    """Paper step 4 over every quantized leaf in the tree."""

    def refresh(path, leaf):
        if not isinstance(leaf, LutqState):
            return leaf
        nstack = leaf.d.ndim - 1
        f = _vmapped(lambda s: update_state(s, spec), nstack)
        return f(leaf)

    return map_with_path(refresh, params)


def dequantize_tree(params):
    """Replace each LutqState by its decoded weights (deployment export)."""
    from repro.core.lutq import decode_any

    def conv(path, leaf):
        if isinstance(leaf, LutqState):
            return decode_any(leaf.d, leaf.a)
        return leaf

    return map_with_path(conv, params)


def split_trainable(params):
    """Split a params tree into (trainable, static).

    LutqState leaves contribute their full-precision master ``w`` to the
    trainable tree; dictionary + assignments (and any integer/bool leaf)
    go to the static tree. ``merge_trainable`` reassembles. This is how
    train steps differentiate only the paper's W (step 3) while (d, A)
    are refreshed by k-means (step 4).
    """

    def split(path, leaf):
        if isinstance(leaf, LutqState):
            return leaf.w, {"__lutq_d": leaf.d, "__lutq_a": leaf.a}
        if leaf is not None and hasattr(leaf, "dtype") and not jnp.issubdtype(
                leaf.dtype, jnp.inexact):
            return None, {"__static": leaf}
        return leaf, None

    trainable = map_with_path(lambda p, l: split(p, l)[0], params)
    static = map_with_path(lambda p, l: split(p, l)[1], params)
    return trainable, static


def merge_trainable(trainable, static):
    def merge(t, s):
        if isinstance(s, dict) and "__lutq_d" in s:
            return LutqState(w=t, d=s["__lutq_d"], a=s["__lutq_a"])
        if isinstance(s, dict) and "__static" in s:
            return s["__static"]
        if isinstance(t, dict):
            return {k: merge(t[k], s[k] if s is not None else None) for k in t}
        return t

    return merge(trainable, static)


def serve_view(params, *, pack4: bool = False):
    """Deployment form: drop the full-precision masters, keep (d, A).

    This is the paper's memory claim made literal — the served model's
    weight storage is K floats + N indices per tensor. With
    ``pack4=True`` (K <= 16 only) two 4-bit indices pack per byte along
    the last axis (convention: uint8 dtype == packed; int8 == raw), so
    HBM weight traffic at decode is N/2 bytes — the beyond-paper §Perf
    lever matching the Pallas ``lutq_gemv_packed`` kernel layout.
    """

    def conv(path, leaf):
        if isinstance(leaf, LutqState):
            a = leaf.a
            if pack4 and leaf.d.shape[-1] <= 16 and a.shape[-1] % 2 == 0:
                lo = a[..., 0::2].astype(jnp.uint8) & 0xF
                hi = a[..., 1::2].astype(jnp.uint8) & 0xF
                a = (lo | (hi << 4)).astype(jnp.uint8)
            return LutqState(w=None, d=leaf.d, a=a)
        return leaf

    return map_with_path(conv, params)


def unpack4_last(a: jax.Array) -> jax.Array:
    """Inverse of serve_view(pack4=True): uint8 pairs -> int8 indices."""
    lo = (a & 0xF).astype(jnp.int8)
    hi = ((a >> 4) & 0xF).astype(jnp.int8)
    return jnp.stack([lo, hi], axis=-1).reshape(*a.shape[:-1], a.shape[-1] * 2)


def quantized_fraction(params) -> float:
    """Fraction of parameters covered by LUT-Q (for reporting)."""
    q = t = 0
    for _, leaf in tree_paths(params):
        if isinstance(leaf, LutqState):
            q += leaf.w.size
            t += leaf.w.size
        elif leaf is not None and hasattr(leaf, "size"):
            t += leaf.size
    return q / max(t, 1)
