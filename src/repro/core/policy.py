"""Quantization policy application: which tensors get LUT-Q, with which spec.

Walks a parameter pytree, converts eligible kernel leaves to
:class:`LutqState` (per-tensor dictionary; stacked leading axes — e.g.
scan-over-layers or MoE experts — get per-slice dictionaries via vmap),
and provides the step-4 k-means refresh over a whole tree.

Which leaves are converted, and with which :class:`QuantSpec`, is driven
by a :class:`repro.core.rules.QuantPolicy` — an ordered first-match-wins
rule list over pytree paths. Every entry point accepts either a policy
or a bare ``QuantSpec`` (auto-wrapped as ``uniform(spec)``, reproducing
the historical global-knob behavior bit-identically). Each converted
leaf records the id of the rule that claimed it in ``LutqState.sid``;
per-leaf dispatch (k-means refresh, serve packing, reporting) re-resolves
by path, which is deterministic and jit-static.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lutq import LutqState, init_state, pow2_encode, update_state
from repro.core.rules import QuantLike, QuantPolicy, as_policy
from repro.core.spec import QuantSpec
from repro.nn.tree import map_with_path, tree_paths

# Parameters that never get quantized regardless of size (norm gains,
# biases, routers, decay/bonus vectors, conv states...). The paper
# quantizes affine/convolution *weights* only. This base eligibility
# gate applies before any policy rule is consulted.
_EXCLUDE = re.compile(
    r"(bias|scale|ln|norm|router|A_log|dt_bias|^D$|w0|^u$|mix_|conv_b|gamma|beta)"
)


def default_predicate(path: Tuple[str, ...], leaf) -> bool:
    name = path[-1] if path else ""
    joined = "/".join(path)
    if _EXCLUDE.search(name) or _EXCLUDE.search(joined.split("/")[-1]):
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    return True


# Logical axis names that index *independent tensors* (each gets its own
# LUT-Q dictionary): scan-over-layers stacks and MoE experts.
STACK_AXES = frozenset({"layer", "super", "inner", "expert"})


def _stacked_dims(path: Tuple[str, ...], leaf, axes=None) -> int:
    """Leading axes that index independent tensors (layer stack, experts).

    When the logical-axes tuple for this leaf is available we count its
    leading STACK_AXES names (exact); otherwise fall back to ndim-2 with
    a conv (HWIO, path-unstacked) exception.
    """
    if axes is not None:
        n = 0
        for name in axes:
            if name in STACK_AXES:
                n += 1
            else:
                break
        return n
    if path and path[-1] == "kernel" and leaf.ndim == 4:
        return 0  # conv HWIO
    return max(0, leaf.ndim - 2)


def _vmapped(fn, n: int):
    for _ in range(n):
        fn = jax.vmap(fn)
    return fn


def quantize_tree(params, quant: QuantLike, predicate: Callable = default_predicate,
                  axes=None):
    """Convert eligible leaves to LutqState (per-slice dictionaries).

    ``quant``: a QuantPolicy, or a bare QuantSpec (== uniform policy).
    ``predicate``: base eligibility gate (norms/biases/1-D leaves never
    quantize); rules then pick the per-leaf spec among eligible leaves.
    ``axes``: optional logical-axes tree (as returned by model init) used
    to identify stack axes exactly.
    """
    policy = as_policy(quant)
    if policy is None:
        return params

    def lookup_axes(path):
        node = axes
        for k in path:
            if not isinstance(node, dict) or k not in node:
                return None
            node = node[k]
        return node if isinstance(node, (tuple, list)) else None

    def convert(path, leaf):
        if isinstance(leaf, LutqState) or not predicate(path, leaf):
            return leaf
        rid, spec = policy.resolve(path, size=leaf.size)
        if spec is None:
            return leaf
        nstack = _stacked_dims(path, leaf, lookup_axes(path))
        f = _vmapped(lambda w: init_state(w, spec), nstack)
        st = f(leaf)
        # sid mirrors the stack dims so lax.scan over a layer stack
        # slices it consistently with w/d/a.
        return st._replace(sid=jnp.full(st.d.shape[:-1], rid, jnp.int32))

    return map_with_path(convert, params)


def _resolve_for_state(policy: QuantPolicy, path, leaf: LutqState
                       ) -> Optional[QuantSpec]:
    """Spec governing an existing LutqState leaf (path re-resolution).

    Size floors are ignored: the leaf is already quantized, so the rule's
    spec applies regardless of how the floor would gate fresh conversion.
    """
    i = policy.match(path)
    if i is None:
        return None
    return policy.rules[i].spec


def kmeans_tree(params, quant: QuantLike, impl: Optional[str] = None):
    """Paper step 4 over every quantized leaf, honoring each leaf's rule.

    ``impl`` forces the per-leaf k-means implementation ("dense" |
    "segsum" | "stats"); default is the structural choice of
    :func:`repro.core.lutq.resolve_kmeans_impl` — dense one-hot for
    small leaves, the fused Pallas ``kmeans_stats`` kernel on TPU above
    ``_SEGSUM_THRESHOLD``, the sharding-friendly segsum form elsewhere.
    """
    policy = as_policy(quant)

    def refresh(path, leaf):
        if not isinstance(leaf, LutqState):
            return leaf
        spec = None if policy is None else _resolve_for_state(policy, path, leaf)
        if spec is None:
            # policy no longer covers this leaf (or exclusion rule):
            # leave the existing (d, A) frozen rather than guess a spec.
            return leaf
        nstack = leaf.d.ndim - 1
        core = LutqState(w=leaf.w, d=leaf.d, a=leaf.a)
        f = _vmapped(lambda s: update_state(s, spec, impl=impl), nstack)
        return f(core)._replace(sid=leaf.sid, act=leaf.act)

    return map_with_path(refresh, params)


def dequantize_tree(params):
    """Replace each LutqState by its decoded weights (deployment export)."""
    from repro.core.lutq import decode_any

    def conv(path, leaf):
        if isinstance(leaf, LutqState):
            return decode_any(leaf.d, leaf.a)
        return leaf

    return map_with_path(conv, params)


def split_trainable(params):
    """Split a params tree into (trainable, static).

    LutqState leaves contribute their full-precision master ``w`` to the
    trainable tree; dictionary + assignments + rule id (and any
    integer/bool leaf) go to the static tree. ``merge_trainable``
    reassembles. This is how train steps differentiate only the paper's
    W (step 3) while (d, A) are refreshed by k-means (step 4).
    """

    def split(path, leaf):
        if isinstance(leaf, LutqState):
            s = {"__lutq_d": leaf.d, "__lutq_a": leaf.a}
            if leaf.sid is not None:
                s["__lutq_sid"] = leaf.sid
            if leaf.act is not None:
                s["__lutq_act"] = leaf.act
            return leaf.w, s
        if leaf is not None and hasattr(leaf, "dtype") and not jnp.issubdtype(
                leaf.dtype, jnp.inexact):
            return None, {"__static": leaf}
        return leaf, None

    trainable = map_with_path(lambda p, l: split(p, l)[0], params)
    static = map_with_path(lambda p, l: split(p, l)[1], params)
    return trainable, static


def merge_trainable(trainable, static):
    def merge(t, s):
        if isinstance(s, dict) and "__lutq_d" in s:
            return LutqState(w=t, d=s["__lutq_d"], a=s["__lutq_a"],
                             sid=s.get("__lutq_sid"),
                             act=s.get("__lutq_act"))
        if isinstance(s, dict) and "__static" in s:
            return s["__static"]
        if isinstance(t, dict):
            return {k: merge(t[k], s[k] if s is not None else None) for k in t}
        return t

    return merge(trainable, static)


def _leaf_rule(pol: Optional[QuantPolicy], path):
    """(spec, requested_backend) for a leaf under the policy."""
    if pol is None:
        return None, "auto"
    i = pol.match(path)
    if i is None:
        return None, "auto"
    return pol.rules[i].spec, pol.rules[i].resolved_backend


def _pow2_encodable(d, kin: int):
    """(int8 plane, fits) for pow2 serve encoding of a dictionary.

    ``fits`` is the shift-add int32 overflow guard: the accumulator is
    bounded by 127 * 2^span * Kin, so ``7 + span + ceil(log2 Kin)`` must
    stay within 31 bits, where span is the largest max-min nonzero
    exponent spread over the stack slices. Needs concrete values — under
    tracing the caller must fall back to the float dictionary.
    """
    code = pow2_encode(d)
    mag = jnp.abs(code.astype(jnp.int32))
    has = jnp.any(mag > 0, axis=-1)
    mx = jnp.max(mag, axis=-1)
    mn = jnp.min(jnp.where(mag > 0, mag, jnp.iinfo(jnp.int32).max), axis=-1)
    span = int(jnp.max(jnp.where(has, mx - mn, 0)))
    bits = 7 + span + math.ceil(math.log2(max(kin, 2)))
    return code, bits <= 31


def serve_view(params, *, pack4: bool = False, policy: Optional[QuantLike] = None,
               with_manifest: bool = False, mesh=None, axes=None):
    """Deployment form: drop the full-precision masters, keep (d, A).

    This is the paper's memory claim made literal — the served model's
    weight storage is K floats + N indices per tensor. With
    ``pack4=True`` (K <= 16 only) two 4-bit indices pack per byte along
    axis -2 — the matmul reduction (Kin) axis, i.e. exactly the
    ``(Kin/2, N)`` row-pair layout the Pallas ``lutq_gemv_packed``
    kernel streams from HBM (convention: uint8 dtype == packed; int8 ==
    raw) — so decode-time HBM weight traffic is N/2 bytes.

    ``policy``: optional per-leaf gate — with the blanket ``pack4``
    flag a leaf is packed only if its resolved rule's spec has
    index_bits <= 4 and the rule's kernel backend is not an explicit
    ``fused``/``decode`` (the fused int8 kernel cannot read packed
    pairs); a rule with ``backend="packed4"`` packs its leaves even
    without the flag.

    ``with_manifest=True`` additionally returns a JSON-serializable
    ``{path: {backend, requested, packed, K, bits, stack}}`` record of
    the kernel backend each leaf resolves to (via
    ``kernels.ops.resolve_backend`` with ``sliced=True`` — the
    per-slice view the kernels actually see after lax.scan slices a
    layer stack or ``moe_apply`` vmaps over experts).

    ``mesh`` (with ``axes``, the logical-axes tree from model init):
    emit a *sharding-aware* tree — every leaf is placed onto its serving
    NamedSharding as it is built (indices/packed layouts partitioned
    along the model axis per ``distributed.sharding.SERVE_RULES``, with
    the packed4 row-pair axis respected in the divisibility fallback;
    dictionaries, rule ids and fp leaves replicated or batch-free). This
    is the entry point the sharded serving stack starts from; see
    docs/sharding.md.
    """
    from repro.kernels.ops import resolve_backend
    from repro.kernels.ref import pack4_kin

    pol = as_policy(policy)
    manifest: Dict[str, Dict] = {}

    def conv(path, leaf):
        if not isinstance(leaf, LutqState):
            return leaf
        a = leaf.a
        K = leaf.d.shape[-1]
        spec, requested = _leaf_rule(pol, path)
        packable = (a.dtype != jnp.uint8 and K <= 16
                    and a.ndim >= 2 and a.shape[-2] % 2 == 0)
        if requested == "packed4":
            pack = packable
        elif requested in ("fused", "decode", "pow2"):
            pack = False  # pow2 planes carry int8 assignments (never packed)
        else:  # auto
            pack = packable and pack4
            if pack and pol is not None:
                pack = spec is not None and spec.index_bits <= 4
        if pack:
            a = pack4_kin(a)
        d = leaf.d
        if (requested == "pow2" and not pack and a.dtype != jnp.uint8
                and spec is not None and spec.constraint == "pow2"
                and a.ndim >= 2):
            # emit the sign+exponent plane (int8 == pow2-encoded, the
            # structural twin of uint8 == packed) when the shift-add
            # int32 accumulator provably cannot overflow; otherwise keep
            # the float dictionary (degrades to the fused ladder)
            try:
                code, fits = _pow2_encodable(d, int(a.shape[-2]))
                if fits:
                    d = code
            except jax.errors.TracerArrayConversionError:
                pass  # tracing: can't prove the bound, keep float
        out = LutqState(w=None, d=d, a=a, sid=leaf.sid, act=leaf.act)
        if with_manifest:
            # The rule's request has been realized *structurally* (packed
            # vs int8 layout), so the leaf's auto resolution IS what
            # lutq_dot picks at apply time under kernel_backend="auto".
            manifest["/".join(path)] = {
                "backend": resolve_backend(out, "auto", sliced=True),
                "requested": requested,
                "packed": bool(pack),
                "encoding": "pow2" if out.d.dtype == jnp.int8 else "float",
                "act_frozen": bool(out.act is not None),
                "K": int(K),
                "bits": int(math.ceil(math.log2(max(K, 2)))),
                "stack": int(leaf.d.ndim - 1),
            }
        return out

    tree = map_with_path(conv, params)
    if mesh is not None:
        if axes is None:
            raise ValueError("serve_view(mesh=...) needs the logical-axes "
                             "tree from model init (axes=)")
        from repro.distributed.sharding import shard_serve_params

        tree, _ = shard_serve_params(tree, axes, mesh)
    if with_manifest:
        # carry the process tuning cache alongside the backend records
        # (reserved "__"-prefixed key, only when tuned — per-leaf
        # entries stay exactly the set of quantized paths otherwise)
        from repro.kernels.ops import tuning_cache

        tc = tuning_cache()
        if len(tc):
            manifest["__tuning_cache__"] = tc.to_json_dict()
        return tree, manifest
    return tree


def draft_view(params, *, draft_bits: int = 3, with_report: bool = False):
    """Coarse low-bit view of a serve tree for self-speculative decoding.

    Re-clusters each :class:`LutqState` leaf's K dictionary entries into
    ``K' = 2**draft_bits`` coarse entries (weighted 1-D k-means over the
    *entries*, weighted by assignment usage — see
    :func:`repro.core.lutq.coarsen_dictionary`) and remaps the *same*
    stored indices through the monotone fine→coarse map. The draft model
    therefore shares the target's assignment structure: it costs only a
    second tiny dictionary plus remapped (and, when K' <= 16,
    pack4-repacked) indices — no second set of weights. Leaves whose K
    already fits in ``draft_bits`` — and all fp leaves — are shared by
    reference (zero extra bytes). ``sid``/``act`` are carried through
    unchanged so policy re-resolution and frozen activation scales
    behave identically under the draft view.

    pow2-encoded dictionaries (int8 sign+exponent plane) are decoded to
    floats before coarsening; coarse centroids are means and generally
    not powers of two, so the draft leaf always carries a float
    dictionary (it degrades to the fused/packed ladder, never pow2).

    ``with_report=True`` additionally returns a per-leaf
    ``{path: {K, draft_K, shared, draft_bytes}}`` accounting of the
    extra resident bytes the draft view costs (dictionary + indices;
    shared leaves report 0) — surfaced by the serve CLI and the
    speculative bench.
    """
    from repro.core.lutq import coarsen_dictionary, pow2_decode
    from repro.kernels.ref import pack4_kin, unpack4_kin

    k_out = 1 << int(draft_bits)
    report: Dict[str, Dict] = {}

    def conv(path, leaf):
        if not isinstance(leaf, LutqState):
            return leaf
        K = leaf.d.shape[-1]
        if k_out >= K:
            if with_report:
                report["/".join(path)] = {"K": int(K), "draft_K": int(K),
                                          "shared": True, "draft_bytes": 0}
            return leaf
        d = leaf.d
        if d.dtype == jnp.int8:  # pow2 sign+exponent plane → floats
            d = pow2_decode(d)
        a = leaf.a
        if a.dtype == jnp.uint8:
            a = unpack4_kin(a)
        nstack = leaf.d.ndim - 1

        def one(dd, aa):
            # K=256 assignments live in int8 two's-complement (the
            # kernels reinterpret the plane); undo the wrap before the
            # histogram and the fine->coarse gather or the upper half
            # of the dictionary maps through garbage
            ai = aa.astype(jnp.int32)
            ai = jnp.where(ai < 0, ai + 256, ai)
            dc, fmap = coarsen_dictionary(dd, ai, k_out)
            return dc, fmap[ai].astype(jnp.int8)

        dc, ac = _vmapped(one, nstack)(d.astype(jnp.float32), a)
        if k_out <= 16 and ac.ndim >= 2 and ac.shape[-2] % 2 == 0:
            ac = pack4_kin(ac)
        out = LutqState(w=None, d=dc, a=ac, sid=leaf.sid, act=leaf.act)
        if with_report:
            report["/".join(path)] = {
                "K": int(K), "draft_K": int(k_out), "shared": False,
                "draft_bytes": int(dc.nbytes) + int(ac.nbytes)}
        return out

    tree = map_with_path(conv, params)
    if with_report:
        return tree, report
    return tree


def backend_manifest(params, policy: Optional[QuantLike] = None,
                     override: Optional[str] = None) -> Dict[str, Dict]:
    """Per-leaf kernel-backend resolution for an existing (serve) tree.

    Same record as ``serve_view(..., with_manifest=True)`` but computed
    from a tree as it stands — used by the serving CLI to report which
    kernel each quantized leaf will hit, and by tests to assert the
    JSON round-trips to what ``lutq_dot`` resolves at trace time.

    ``override``: a model-wide kernel backend (the CLI's
    ``--kernel-backend`` / ``ModelConfig.kernel_backend``), which at
    apply time supersedes per-rule requests; infeasible leaves degrade
    exactly as ``lutq_dot`` degrades them.
    """
    from repro.kernels.ops import resolve_backend

    pol = as_policy(policy)
    out: Dict[str, Dict] = {}
    for path, leaf in tree_paths(params):
        if not isinstance(leaf, LutqState):
            continue
        _, requested = _leaf_rule(pol, path)
        # Apply-time dispatch sees cfg.kernel_backend (the override), not
        # the rule request — rule requests act through serve_view's
        # *layout* (packed vs int8), which this tree already has. So
        # resolve structurally under the override, "auto" when none.
        effective = override if override is not None else "auto"
        K = leaf.d.shape[-1]
        out["/".join(path)] = {
            "backend": resolve_backend(leaf, effective, sliced=True),
            "requested": requested,
            "packed": bool(leaf.a.dtype == jnp.uint8),
            "encoding": "pow2" if leaf.d.dtype == jnp.int8 else "float",
            "act_frozen": bool(leaf.act is not None),
            "K": int(K),
            "bits": int(math.ceil(math.log2(max(K, 2)))),
            "stack": int(leaf.d.ndim - 1),
        }
    return out


def lutq_weight_count(leaf: LutqState) -> int:
    """Number of logical weights a LutqState covers.

    Works on train trees (w present) and serve_view trees (w=None):
    assignments mirror the weight shape, with uint8 meaning two packed
    4-bit indices per stored byte.
    """
    if leaf.w is not None:
        return leaf.w.size
    n = leaf.a.size
    if leaf.a.dtype == jnp.uint8:
        n *= 2
    return n


def quantized_fraction(params) -> float:
    """Fraction of parameters covered by LUT-Q (for reporting)."""
    q = t = 0
    for _, leaf in tree_paths(params):
        if isinstance(leaf, LutqState):
            n = lutq_weight_count(leaf)
            q += n
            t += n
        elif leaf is not None and hasattr(leaf, "size"):
            t += leaf.size
    return q / max(t, 1)


def effective_bits(params) -> float:
    """Average index bits per quantized weight (4 for K<=16, etc.).

    Reported alongside quantized_fraction: a mixed policy's memory story
    is "X% of params at an average of Y bits".
    """
    bits = n = 0
    for _, leaf in tree_paths(params):
        if isinstance(leaf, LutqState):
            cnt = lutq_weight_count(leaf)
            K = leaf.d.shape[-1]
            bits += cnt * max(1, math.ceil(math.log2(K)))
            n += cnt
    return bits / n if n else 0.0


def rule_breakdown(params, quant: QuantLike) -> List[Dict]:
    """Per-rule coverage/memory report over an actual (quantized) tree.

    Returns one row per policy rule plus a trailing "unmatched" row:
    {rule, pattern, n_params, n_quantized, index_bits, serve_bytes}.
    serve_bytes is the actual resident storage of each leaf as it exists
    in the given tree (dictionary + assignment bytes for quantized
    leaves — packed or not; native nbytes for fp leaves), so the rows
    sum to the tree's real (d, A)+fp footprint.
    """
    policy = as_policy(quant)
    rows = [{"rule": r.rule_name, "pattern": r.pattern,
             "index_bits": (0 if r.spec is None else r.spec.index_bits),
             "n_params": 0, "n_quantized": 0, "serve_bytes": 0}
            for r in policy.rules]
    rows.append({"rule": "(unmatched)", "pattern": "-", "index_bits": 0,
                 "n_params": 0, "n_quantized": 0, "serve_bytes": 0})

    for path, leaf in tree_paths(params):
        if leaf is None or not (isinstance(leaf, LutqState)
                                or hasattr(leaf, "size")):
            continue
        i = policy.match(path)
        row = rows[i if i is not None else -1]
        if isinstance(leaf, LutqState):
            n = lutq_weight_count(leaf)
            row["n_params"] += n
            row["n_quantized"] += n
            row["serve_bytes"] += leaf.d.nbytes + leaf.a.nbytes
            if leaf.sid is not None:
                row["serve_bytes"] += leaf.sid.nbytes
            if leaf.act is not None:
                row["serve_bytes"] += leaf.act.nbytes
        else:
            row["n_params"] += leaf.size
            row["serve_bytes"] += leaf.nbytes
    return rows


def format_breakdown(rows: List[Dict]) -> str:
    lines = [f"{'rule':24s} {'params':>12s} {'quantized':>12s} "
             f"{'bits':>5s} {'serve MiB':>10s}"]
    for r in rows:
        if r["n_params"] == 0:
            continue
        bits = str(r["index_bits"]) if r["n_quantized"] else "fp"
        lines.append(f"{r['rule']:24s} {r['n_params']:12d} "
                     f"{r['n_quantized']:12d} {bits:>5s} "
                     f"{r['serve_bytes']/2**20:10.3f}")
    return "\n".join(lines)
