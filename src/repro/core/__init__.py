"""Core LUT-Q library: the paper's contribution as composable JAX modules."""
from repro.core.spec import (
    QuantSpec,
    LUTQ_4BIT,
    LUTQ_2BIT,
    LUTQ_4BIT_POW2,
    LUTQ_2BIT_POW2,
    BINARY,
    TERNARY,
    TERNARY_SCALED,
)
from repro.core.lutq import (
    LutqState,
    decode,
    quantize_ste,
    assign,
    kmeans_update,
    kmeans_update_segsum,
    kmeans_update_stats,
    resolve_kmeans_impl,
    update_state,
    init_state,
    init_dictionary,
    pow2_round,
    apply_constraint,
)
from repro.core.mlbn import BNParams, BNStats, init_bn, batch_norm, inference_scale_offset
from repro.core.actquant import fake_quant, relu_fake_quant
from repro.core import memory

__all__ = [
    "QuantSpec", "LUTQ_4BIT", "LUTQ_2BIT", "LUTQ_4BIT_POW2", "LUTQ_2BIT_POW2",
    "BINARY", "TERNARY", "TERNARY_SCALED",
    "LutqState", "decode", "quantize_ste", "assign", "kmeans_update",
    "kmeans_update_segsum", "kmeans_update_stats", "resolve_kmeans_impl",
    "update_state", "init_state", "init_dictionary",
    "pow2_round", "apply_constraint",
    "BNParams", "BNStats", "init_bn", "batch_norm", "inference_scale_offset",
    "fake_quant", "relu_fake_quant", "memory",
]
