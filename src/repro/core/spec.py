"""Quantization specifications for LUT-Q.

A ``QuantSpec`` describes how one weight tensor is quantized:
dictionary size, constraint family (free / pow2 / binary / ternary),
optional pruning fraction and the number of k-means refresh iterations
run after every optimizer step (paper Table 1, step 4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Configuration of LUT-Q for a single tensor (or a family of tensors).

    Attributes:
      bits: dictionary address width; K = 2**bits entries.
      constraint: 'none' (free dictionary, paper's plain LUT-Q),
        'pow2' (entries are ±2^b, b integer — multiplier-less),
        'binary' ({-1,+1}, fixed), 'ternary' ({-1,0,+1}, fixed).
      prune_frac: fraction of weights pinned to a zero dictionary entry
        (0.0 disables pruning). Implies one dictionary slot is fixed at 0.
      kmeans_iters: M in the paper — k-means iterations per minibatch.
      min_size: tensors with fewer elements are left unquantized
        (biases, norm gains; the paper quantizes affine/conv weights).
      backend: serving kernel backend for tensors under this spec
        ('auto' | 'decode' | 'fused' | 'packed4' | 'pow2', see
        kernels/ops.py). 'auto' resolves structurally per leaf; explicit
        choices degrade gracefully where a kernel cannot apply.
      act_bits: activation quantization width at this tensor's matmul
        boundary (32 = full precision). Part of the quantization
        *regime*: the layer contract (``nn/linear.dot_kernel``) applies
        it at the kernel boundary instead of models hand-placing
        ``fake_quant`` calls.
      act_frozen: freeze the activation scale from a calibration batch
        (``core/actquant.capture_act_scales`` →
        ``policy.apply_act_scales``) instead of recomputing the max-abs
        scale per call. Required for deployment and for the integer
        ``pow2`` kernel path under K-sharded SPMD.
    """

    bits: int = 4
    constraint: str = "none"
    prune_frac: float = 0.0
    kmeans_iters: int = 1
    min_size: int = 4096
    # For fixed dictionaries: learn a per-tensor scale alpha so the
    # effective values are alpha * {-1[,0],1} (TWN's {-a,0,a}; BWN's
    # scaled binary). False = literal {-1[,0],1} (BinaryConnect).
    fixed_scale: bool = False
    backend: str = "auto"
    act_bits: int = 32
    act_frozen: bool = False

    def __post_init__(self):
        if self.constraint not in ("none", "pow2", "binary", "ternary"):
            raise ValueError(f"unknown constraint {self.constraint!r}")
        if self.backend not in ("auto", "decode", "fused", "packed4", "pow2"):
            raise ValueError(f"unknown kernel backend {self.backend!r}")
        if self.backend == "pow2" and self.constraint != "pow2":
            raise ValueError("backend='pow2' requires constraint='pow2' "
                             "(the shift-add kernel needs ±2^k entries)")
        if not (1 <= self.act_bits <= 32):
            raise ValueError("act_bits must be in [1, 32]")
        if self.constraint == "binary" and self.bits != 1:
            raise ValueError("binary constraint requires bits=1")
        if self.constraint == "ternary" and self.bits != 2:
            raise ValueError("ternary constraint requires bits=2")
        if not (0.0 <= self.prune_frac < 1.0):
            raise ValueError("prune_frac must be in [0, 1)")
        if self.bits < 1 or self.bits > 8:
            raise ValueError("bits must be in [1, 8] (K <= 256, int8 assignments)")

    @property
    def K(self) -> int:
        if self.constraint == "ternary":
            return 3
        return 2 ** self.bits

    @property
    def fixed_dictionary(self) -> bool:
        return self.constraint in ("binary", "ternary")

    @property
    def index_bits(self) -> int:
        """Bits per stored assignment: ceil(log2 K)."""
        return max(1, math.ceil(math.log2(self.K)))


def spec_to_dict(spec: QuantSpec) -> dict:
    """JSON-serializable form (QuantPolicy / checkpoint manifests)."""
    return dataclasses.asdict(spec)


def spec_from_dict(d: dict) -> QuantSpec:
    known = {f.name for f in dataclasses.fields(QuantSpec)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown QuantSpec fields {sorted(unknown)}")
    return QuantSpec(**d)


# Common presets used throughout the experiments / configs.
LUTQ_4BIT = QuantSpec(bits=4)
LUTQ_2BIT = QuantSpec(bits=2)
LUTQ_4BIT_POW2 = QuantSpec(bits=4, constraint="pow2")
LUTQ_2BIT_POW2 = QuantSpec(bits=2, constraint="pow2")
# Multiplier-less serving regime: pow2 dictionary served as sign+exponent
# planes through the shift-add kernel, int8 activations at frozen scales.
SERVING_POW2 = QuantSpec(bits=4, constraint="pow2", backend="pow2",
                         act_bits=8, act_frozen=True)
BINARY = QuantSpec(bits=1, constraint="binary")
TERNARY = QuantSpec(bits=2, constraint="ternary")
TERNARY_SCALED = QuantSpec(bits=2, constraint="ternary", fixed_scale=True)
