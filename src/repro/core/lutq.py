"""LUT-Q: dictionary + assignment quantization with iterative k-means.

Implements the paper's Table 1 algorithm as pure JAX:

  step 1   Q = d[A]                      -> :func:`decode`
  step 2/3 STE forward + master update   -> :func:`quantize_ste`
  step 4   M k-means iterations on (A,d) -> :func:`kmeans_update`

Production note (TPU adaptation): the assignment step is 1-D nearest-
neighbour search. For a *sorted* dictionary the nearest entry is found by
bucketizing against the K-1 midpoints (``searchsorted``), which is
O(N log K) time and O(N) memory instead of the naive N x K distance
matrix. 1-D k-means preserves dictionary order across recenter steps, so
sortedness is an invariant we establish at init and keep thereafter.
Centroid recentering uses one-hot segment sums.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.spec import QuantSpec


class LutqState(NamedTuple):
    """Quantization state for one weight tensor (a pytree node).

    w: full-precision master weights (paper's W), any shape.
    d: dictionary, shape (K,), sorted ascending.
    a: assignments, int8, same shape as w (values in [0, K)).
    sid: resolved QuantPolicy rule id (int32) recording which rule
      produced this state, or None (legacy trees). Shaped like d's
      stack dims — d.shape[:-1] — so scan-over-layers slicing and vmap
      see a consistent leading axis; scalar for unstacked tensors. None
      flattens away as an empty pytree, so 3-field construction and old
      checkpoints keep working unchanged.
    act: frozen activation-quant record for this tensor's matmul
      boundary, or None (dynamic / fp activations). Shape
      d.shape[:-1] + (2,) = per-stack-slice [scale, qmax]: the
      calibration-frozen scale s and clip bound so the kernel boundary
      computes clip(round(x/s), -qmax, qmax). Trailing-axis layout keeps
      scan-over-layers slicing consistent with d (see sid).

    Serve-form convention: ``d.dtype == int8`` means the dictionary is a
    pow2 sign+exponent *plane* (see :func:`pow2_encode`), exactly as
    ``a.dtype == uint8`` means packed assignments.
    """

    w: jax.Array
    d: jax.Array
    a: jax.Array
    sid: Optional[jax.Array] = None
    act: Optional[jax.Array] = None


# ---------------------------------------------------------------------------
# step 1: decode tied weights
# ---------------------------------------------------------------------------

def decode(d: jax.Array, a: jax.Array) -> jax.Array:
    """Q = d[A] (paper step 1)."""
    return jnp.take(d, a.astype(jnp.int32), axis=0)


# ---------------------------------------------------------------------------
# steps 2/3: straight-through estimator
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _ste_exact(w: jax.Array, q: jax.Array) -> jax.Array:
    return q


def _ste_fwd(w, q):
    return q, None


def _ste_bwd(_, g):
    # dC/dW = dC/dQ (paper step 3); q carries no gradient of its own.
    return g, jnp.zeros_like(g)


_ste_exact.defvjp(_ste_fwd, _ste_bwd)


def decode_any(d: jax.Array, a: jax.Array) -> jax.Array:
    """decode() for stacked dictionaries: d (..., K), a (..., *w_shape).

    Leading axes of d index independent tensors (scan-over-layers stacks,
    MoE experts) each with its own dictionary. An int8 ``d`` is a pow2
    sign+exponent plane (serve-form convention) and is decoded to exact
    ±2^k floats first.
    """
    if d.dtype == jnp.int8:
        d = pow2_decode(d)
    nstack = d.ndim - 1
    f = decode
    for _ in range(nstack):
        f = jax.vmap(f)
    return f(d, a)


def quantize_ste_any(w: jax.Array, d: jax.Array, a: jax.Array) -> jax.Array:
    """Stack-aware quantize_ste (see decode_any)."""
    q = decode_any(d, a).astype(w.dtype)
    return _ste_exact(w, q)


def quantize_ste(w: jax.Array, d: jax.Array, a: jax.Array) -> jax.Array:
    """Forward value is *exactly* Q = d[A]; gradient flows straight to w.

    This realizes the paper's split between step 2 (gradients w.r.t. Q)
    and step 3 (applying them to the full-precision W): autodiff through
    this function gives dC/dW = dC/dQ. Bit-exactness of the forward value
    matters for the multiplier-less claims (decoded weights must be exact
    dictionary entries), hence custom_vjp instead of the
    ``w + stop_grad(q - w)`` trick which reintroduces rounding.
    """
    q = decode(d, a).astype(w.dtype)
    return _ste_exact(w, q)


# ---------------------------------------------------------------------------
# dictionary constraints
# ---------------------------------------------------------------------------

def pow2_round(x: jax.Array, min_exp: int = -14, max_exp: int = 15) -> jax.Array:
    """Round magnitudes to the nearest power of two, keep sign.

    Entries exactly 0 stay 0 (used by the pruning constraint). Exponents
    are clamped so decoded bf16/f16 values stay representable.
    """
    mag = jnp.abs(x)
    safe = jnp.where(mag > 0, mag, 1.0)
    # Round in log-space: nearest power of two of m is 2^round(log2 m).
    e = jnp.clip(jnp.round(jnp.log2(safe)), min_exp, max_exp)
    p = jnp.exp2(e)
    return jnp.where(mag > 0, jnp.sign(x) * p, 0.0).astype(x.dtype)


# Exponent window shared by pow2_round and the sign+exponent plane
# encoding below. 30 = (POW2_MAX_EXP - POW2_MIN_EXP + 1) codes fit int8.
POW2_MIN_EXP = -14
POW2_MAX_EXP = 15


def pow2_encode(d: jax.Array) -> jax.Array:
    """Encode a pow2-constrained dictionary as an int8 sign+exponent plane.

    Per entry: code 0 for an exact zero (pruning slot); otherwise
    ``sign(entry) * (exponent - POW2_MIN_EXP + 1)`` with the exponent in
    [POW2_MIN_EXP, POW2_MAX_EXP], so |code| ∈ [1, 30]. Same shape as the
    input (stack axes pass through), and the serve tree stores *only*
    this plane — 1 byte/entry instead of 4 — which is what the shift-add
    kernel consumes. Inverse: :func:`pow2_decode` (exact round-trip for
    in-range pow2 entries).
    """
    mag = jnp.abs(d).astype(jnp.float32)
    safe = jnp.where(mag > 0, mag, 1.0)
    e = jnp.clip(jnp.round(jnp.log2(safe)), POW2_MIN_EXP, POW2_MAX_EXP)
    code = jnp.sign(d).astype(jnp.int32) * (e.astype(jnp.int32)
                                            - POW2_MIN_EXP + 1)
    return jnp.where(mag > 0, code, 0).astype(jnp.int8)


def pow2_decode(code: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Decode an int8 sign+exponent plane back to exact ±2^k / 0 floats.

    2^e for integer e ∈ [-14, 15] is exact in f32, so every decoded
    entry is bit-exactly a power of two (the multiplier-less invariant).
    """
    mag = jnp.abs(code.astype(jnp.int32))
    val = (jnp.exp2((mag - 1 + POW2_MIN_EXP).astype(jnp.float32))
           * jnp.sign(code).astype(jnp.float32))
    return jnp.where(mag > 0, val, 0.0).astype(dtype)


def _fixed_dictionary(spec: QuantSpec, dtype=jnp.float32) -> jax.Array:
    if spec.constraint == "binary":
        return jnp.array([-1.0, 1.0], dtype=dtype)
    if spec.constraint == "ternary":
        return jnp.array([-1.0, 0.0, 1.0], dtype=dtype)
    raise ValueError(spec.constraint)


def apply_constraint(d: jax.Array, spec: QuantSpec) -> jax.Array:
    """Project a (sorted) dictionary onto the spec's constraint set."""
    if spec.constraint == "pow2":
        d = pow2_round(d)
    elif spec.fixed_dictionary:
        d = _fixed_dictionary(spec, d.dtype)
    if spec.prune_frac > 0.0:
        # Pin the entry nearest zero to exactly zero.
        zi = jnp.argmin(jnp.abs(d))
        d = d.at[zi].set(0.0)
    # Constraints (esp. pow2 rounding) can produce duplicates but are
    # monotone, so sortedness is preserved; enforce it defensively.
    return jnp.sort(d)


# ---------------------------------------------------------------------------
# assignment: 1-D nearest neighbour over a sorted dictionary
# ---------------------------------------------------------------------------

def assign(w: jax.Array, d: jax.Array) -> jax.Array:
    """A_ij = argmin_k |W_ij - d_k| for sorted d. Returns int8.

    Bucketize against midpoints between consecutive dictionary entries:
    entry k owns the interval (m_{k-1}, m_k]. Ties at an exact midpoint
    resolve to the lower index (matches argmin-first semantics).
    Operates on w in its native (possibly sharded) shape — no reshape.
    """
    mid = (d[:-1] + d[1:]) * 0.5
    idx = jnp.searchsorted(mid, w.astype(d.dtype), side="left")
    return idx.astype(jnp.int8)


def _fixed_scale_update(d: jax.Array, w, a, spec: QuantSpec) -> jax.Array:
    """TWN/BWN-style per-tensor scale for fixed dictionaries.

    alpha = mean |w| over weights assigned to nonzero entries; effective
    dictionary = alpha * sign pattern. With spec.fixed_scale=False the
    literal {-1[,0],1} dictionary is kept (BinaryConnect)."""
    if not spec.fixed_scale:
        return d
    sign = jnp.sign(d)
    aw = jnp.abs(w.astype(jnp.float32))
    if spec.constraint == "ternary":  # TWN rule, anchored to the masters
        # Delta = 0.7 E|w|; alpha = E{|w| : |w| > Delta}. Anchoring the
        # threshold to the full master distribution (not the previous
        # alpha) avoids the all-zeros death spiral of the pure
        # nearest-assignment fixed point.
        delta = 0.7 * jnp.mean(aw)
        sel = aw > delta
        num = jnp.sum(jnp.where(sel, aw, 0.0))
        den = jnp.maximum(jnp.sum(sel.astype(jnp.float32)), 1.0)
        alpha = jnp.maximum(num / den, 1e-12)
    else:  # binary (BWN-scaled): alpha = E|w|
        alpha = jnp.maximum(jnp.mean(aw), 1e-12)
    return sign * alpha


def _prune_mask(w: jax.Array, prune_frac: float) -> jax.Array:
    """Boolean mask of weights forced to the zero entry (smallest |w|)."""
    flat = jnp.abs(w.ravel())
    k = int(round(prune_frac * flat.size))
    if k <= 0:
        return jnp.zeros(w.shape, dtype=bool)
    # threshold = k-th smallest magnitude, via top_k on negated values:
    # O(N log k) selection instead of a full O(N log N) sort per step.
    thresh = -jax.lax.top_k(-flat, k)[0][k - 1]
    return jnp.abs(w) <= thresh


# ---------------------------------------------------------------------------
# step 4: M k-means iterations
# ---------------------------------------------------------------------------

def kmeans_update(w: jax.Array, d: jax.Array, spec: QuantSpec) -> Tuple[jax.Array, jax.Array]:
    """Run M k-means iterations on the (sorted) dictionary; reassign.

    Returns (new_d, new_a). Empty clusters keep their previous centroid
    (documented deviation: paper does not specify empty-cluster handling).
    For constrained dictionaries the recentered centroids are projected
    back onto the constraint set each iteration (paper: "rounding the
    output of the k-means algorithm to powers-of-two").
    """
    K = spec.K
    flat = w.ravel().astype(jnp.float32)

    if spec.prune_frac > 0.0:
        pmask = _prune_mask(w, spec.prune_frac).ravel()
    else:
        pmask = None

    def one_iter(d, _):
        a = jnp.searchsorted((d[:-1] + d[1:]) * 0.5, flat, side="left")
        if pmask is not None:
            zi = jnp.argmin(jnp.abs(d))
            a = jnp.where(pmask, zi, a)
        if spec.fixed_dictionary:
            return _fixed_scale_update(d, flat, a, spec), None
        onehot = jax.nn.one_hot(a, K, dtype=jnp.float32)  # (N, K)
        counts = onehot.sum(axis=0)
        sums = onehot.T @ flat
        new_d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
        new_d = apply_constraint(new_d.astype(d.dtype), spec)
        return new_d, None

    d, _ = jax.lax.scan(one_iter, d, None, length=spec.kmeans_iters)

    a = assign(w, d)
    if pmask is not None:
        zi = jnp.argmin(jnp.abs(d)).astype(jnp.int8)
        a = jnp.where(pmask.reshape(w.shape), zi, a)
    return d, a


def kmeans_update_segsum(w: jax.Array, d: jax.Array, spec: QuantSpec) -> Tuple[jax.Array, jax.Array]:
    """Sharding-friendly variant of :func:`kmeans_update` for big tensors.

    No reshape, no one-hot, no scatter: assignments come from an
    elementwise bucketize on w *in place*, and per-entry sums/counts are
    K masked reductions (lax.map over K). Every op is elementwise or a
    full reduction, so XLA partitions it along whatever sharding w
    already has — this is what keeps the paper's per-minibatch step 4
    cheap on 100B-parameter FSDP-sharded weights (the scatter/segment_sum
    formulation forces an SPMD full rematerialization). Identical results
    to :func:`kmeans_update`. On-TPU, the Pallas ``kmeans_stats`` kernel
    fuses all K reductions into one pass over w.
    """
    K = spec.K
    w32 = w.astype(jnp.float32)
    pmask = _prune_mask(w, spec.prune_frac) if spec.prune_frac > 0 else None

    def assign_ids(d):
        a = jnp.searchsorted((d[:-1] + d[1:]) * 0.5, w32, side="left")
        if pmask is not None:
            zi = jnp.argmin(jnp.abs(d))
            a = jnp.where(pmask, zi, a)
        return a

    def one_iter(d, _):
        if spec.fixed_dictionary:
            return _fixed_scale_update(d, w32, assign_ids(d), spec), None
        a = assign_ids(d)

        def stat(k):
            m = a == k
            return (jnp.sum(jnp.where(m, w32, 0.0)),
                    jnp.sum(m.astype(jnp.float32)))

        sums, counts = jax.lax.map(stat, jnp.arange(K))
        new_d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
        new_d = apply_constraint(new_d.astype(d.dtype), spec)
        return new_d, None

    d, _ = jax.lax.scan(one_iter, d, None, length=spec.kmeans_iters)
    a = assign_ids(d).astype(jnp.int8)
    return d, a


def kmeans_update_stats(w: jax.Array, d: jax.Array, spec: QuantSpec,
                        *, bn: int = 65536, interpret: Optional[bool] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """:func:`kmeans_update` through the Pallas ``kmeans_stats`` kernel.

    One fused pass per iteration computes assignments and per-entry
    sums/counts (one HBM read of w, one int8 write of a), instead of the
    K separate masked reductions of :func:`kmeans_update_segsum`. The
    constraints the kernel cannot express are composed around its stats:

      * fixed dictionaries (binary/ternary) take the kernel's assignment
        but recenter via :func:`_fixed_scale_update` (cheap reductions);
      * prune masks move each pruned weight's contribution from its
        kernel-assigned cluster to the zero entry with K masked
        correction reductions — only paid when ``prune_frac > 0``;
      * pow2/sort projections run on the (K,)-sized dictionary on host
        as in the reference.

    Same results as :func:`kmeans_update` / ``kmeans_update_segsum`` up
    to f32 accumulation order (the kernel reduces block-partials over a
    sequential grid).
    """
    from repro.kernels import ops  # local: kernels.ops imports this module

    K = spec.K
    flat = w.ravel().astype(jnp.float32)
    pmask = (_prune_mask(w, spec.prune_frac).ravel()
             if spec.prune_frac > 0.0 else None)

    def stats(d):
        a, sums, counts = ops.kmeans_stats(flat, d, bn=bn,
                                           interpret=interpret)
        a = a.astype(jnp.int32)
        if pmask is not None:
            zi = jnp.argmin(jnp.abs(d))

            def corr(k):
                m = pmask & (a == k)
                return (jnp.sum(jnp.where(m, flat, 0.0)),
                        jnp.sum(m.astype(jnp.float32)))

            csum, ccnt = jax.lax.map(corr, jnp.arange(K))
            sums = (sums - csum).at[zi].add(jnp.sum(csum))
            counts = (counts - ccnt).at[zi].add(jnp.sum(ccnt))
            a = jnp.where(pmask, zi, a)
        return a, sums, counts

    def one_iter(d, _):
        a, sums, counts = stats(d)
        if spec.fixed_dictionary:
            return _fixed_scale_update(d, flat, a, spec), None
        new_d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
        return apply_constraint(new_d.astype(d.dtype), spec), None

    d, _ = jax.lax.scan(one_iter, d, None, length=spec.kmeans_iters)
    a, _, _ = stats(d)
    return d, a.astype(jnp.int8).reshape(w.shape)


_SEGSUM_THRESHOLD = 1 << 16

_KMEANS_IMPLS = {
    "dense": kmeans_update,
    "segsum": kmeans_update_segsum,
    "stats": kmeans_update_stats,
}


def resolve_kmeans_impl(n: int, impl: Optional[str] = None) -> str:
    """Structural step-4 implementation choice for an n-element leaf.

    ``None`` resolves: dense one-hot below ``_SEGSUM_THRESHOLD``; above
    it the fused Pallas ``kmeans_stats`` kernel on TPU, and the
    sharding-friendly masked-reduction ``segsum`` form elsewhere (CPU /
    interpret — where the kernel would just emulate the same reductions
    slower). Explicit names force a path (tests, benches).
    """
    if impl is not None:
        if impl not in _KMEANS_IMPLS:
            raise ValueError(
                f"unknown kmeans impl {impl!r}; expected one of "
                f"{tuple(_KMEANS_IMPLS)}")
        return impl
    if n < _SEGSUM_THRESHOLD:
        return "dense"
    return "stats" if jax.default_backend() == "tpu" else "segsum"


def update_state(state: LutqState, spec: QuantSpec,
                 impl: Optional[str] = None) -> LutqState:
    """Paper step 4 applied to a LutqState (after the optimizer touched w).

    ``impl``: force "dense" | "segsum" | "stats"; default structural
    (see :func:`resolve_kmeans_impl`).
    """
    fn = _KMEANS_IMPLS[resolve_kmeans_impl(state.w.size, impl)]
    d, a = fn(state.w, state.d, spec)
    return LutqState(w=state.w, d=d, a=a, sid=state.sid, act=state.act)


# ---------------------------------------------------------------------------
# nested dictionaries: coarsen K entries to K' over the same assignments
# ---------------------------------------------------------------------------

def coarsen_dictionary(d: jax.Array, a: jax.Array, k_out: int,
                       *, iters: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Re-cluster the K dictionary entries into ``k_out`` coarse entries.

    Weighted 1-D k-means over the *entries themselves* (weights = how
    many weights each entry serves, from the assignment histogram), so a
    low-bit "draft view" of a served tensor costs only a second tiny
    dictionary plus remapped indices — the original assignments ``a``
    never change, they just compose with the returned fine->coarse map:
    ``a_draft = fmap[a]``. Empty fine entries keep a vanishing weight so
    they still land in a defined coarse cell (the map must be total).

    d: (K,) sorted dictionary (f32); a: int assignments of any shape
    (only used for usage counts). Returns ``(d_coarse (k_out,) sorted
    f32, fmap (K,) int32 monotone)``. Monotonicity of the map follows
    from both dictionaries being sorted — nested views preserve the
    order structure the packed kernels rely on.
    """
    K = d.shape[-1]
    if k_out > K:
        raise ValueError(f"k_out {k_out} exceeds dictionary size {K}")
    d32 = d.astype(jnp.float32)
    counts = jnp.zeros((K,), jnp.float32).at[
        a.astype(jnp.int32).ravel()].add(1.0)
    w = counts + 1e-3

    # weighted-quantile init: sorted by construction, duplicates spread
    # by a hair exactly like init_dictionary
    cum = jnp.cumsum(w)
    targets = (jnp.arange(k_out, dtype=jnp.float32) + 0.5) / k_out * cum[-1]
    dc = d32[jnp.clip(jnp.searchsorted(cum, targets), 0, K - 1)]
    eps = 1e-8 * (1.0 + jnp.abs(dc))
    dc = jnp.sort(dc + eps * jnp.arange(k_out, dtype=jnp.float32))

    def one_iter(dc, _):
        g = jnp.searchsorted((dc[:-1] + dc[1:]) * 0.5, d32, side="left")
        oh = jax.nn.one_hot(g, k_out, dtype=jnp.float32)        # (K, k_out)
        cnt = (w[:, None] * oh).sum(axis=0)
        s = (w[:, None] * oh * d32[:, None]).sum(axis=0)
        new = jnp.where(cnt > 0, s / jnp.maximum(cnt, 1e-6), dc)
        return jnp.sort(new), None

    dc, _ = jax.lax.scan(one_iter, dc, None, length=iters)
    fmap = jnp.searchsorted((dc[:-1] + dc[1:]) * 0.5, d32,
                            side="left").astype(jnp.int32)
    return dc, fmap


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def init_dictionary(w: jax.Array, spec: QuantSpec) -> jax.Array:
    """Initialize a sorted dictionary from the weight distribution.

    Free/pow2 dictionaries start at the (k+0.5)/K quantiles of w (a good
    1-D k-means init that is sorted by construction); fixed dictionaries
    are the constraint set itself.
    """
    if spec.fixed_dictionary:
        base = _fixed_dictionary(spec)
        if spec.fixed_scale:
            # TWN-compatible init: alpha0 = 1.4 E|w| puts the assignment
            # threshold (alpha/2) at TWN's Delta = 0.7 E|w|.
            alpha0 = 1.4 * jnp.mean(jnp.abs(w.astype(jnp.float32))) + 1e-12
            return base * alpha0
        return base
    flat = w.ravel().astype(jnp.float32)
    qs = (jnp.arange(spec.K, dtype=jnp.float32) + 0.5) / spec.K
    d = jnp.quantile(flat, qs)
    # Quantile init can duplicate on spiky distributions; spread exact
    # duplicates by a hair so intervals stay well-defined.
    eps = 1e-8 * (1.0 + jnp.abs(d))
    d = d + eps * jnp.arange(spec.K, dtype=jnp.float32)
    return apply_constraint(d.astype(jnp.float32), spec)


def init_state(w: jax.Array, spec: QuantSpec) -> LutqState:
    d = init_dictionary(w, spec)
    d, a = (kmeans_update_segsum if w.size >= _SEGSUM_THRESHOLD else kmeans_update)(w, d, spec)
    return LutqState(w=w, d=d, a=a)
