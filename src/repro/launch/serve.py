"""Serving driver: batched prefill + decode with LUT-Q deployment weights.

CPU scale:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --kernel-backend fused

Uses the paper's deployment form (serve_view: dictionary + int8/packed
assignments, no fp masters) and reports the weight-memory footprint both
ways (fp32 vs LUT-Q) alongside throughput. Decode goes through
``runtime.serving.generate`` — the same jit-cached prefill/decode entry
points and SWA-ring cache re-layout the library path uses — and the
quantized matmuls dispatch through the kernel execution-backend layer
(``--kernel-backend``; see kernels/ops.lutq_dot and docs/kernels.md).
"""
from __future__ import annotations

import argparse
import sys
from collections import Counter

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.policy import (backend_manifest, effective_bits,
                               format_breakdown, quantized_fraction,
                               rule_breakdown, serve_view)
from repro.core.rules import get_policy
from repro.core.spec import QuantSpec
from repro.kernels.ops import BACKENDS
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.serving import generate


def footprint_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: x is None):
        if leaf is not None and hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-policy", default=None,
                    help="mixed-precision policy: preset name, "
                         "'uniform:<bits>[:<constraint>]', inline JSON, or "
                         "@policy.json; supersedes --quant-bits")
    ap.add_argument("--quant-bits", type=int, default=4)
    ap.add_argument("--pack4", action="store_true",
                    help="pack two 4-bit assignments per byte (K<=16 leaves)")
    ap.add_argument("--kernel-backend", default="auto", choices=list(BACKENDS),
                    help="kernel path for quantized matmuls: auto resolves "
                         "per leaf (int8 -> fused Pallas, packed -> packed4); "
                         "decode forces the dense-materialize reference; "
                         "packed4 implies --pack4")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.quant_policy:
        cfg = cfg.replace(quant=get_policy(args.quant_policy), act_bits=8)
    else:
        cfg = cfg.replace(quant=QuantSpec(bits=args.quant_bits, min_size=1024),
                          act_bits=8)
    cfg = cfg.replace(kernel_backend=args.kernel_backend)

    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    fp_bytes = footprint_bytes(params)
    qparams = api.quantize(params, cfg, axes)
    policy = api.resolved_policy(cfg)
    pack = args.pack4 or args.kernel_backend == "packed4"
    sparams = serve_view(qparams, pack4=pack, policy=policy)
    manifest = backend_manifest(sparams, policy,
                                override=args.kernel_backend)
    q_bytes = footprint_bytes(sparams)
    print(f"[serve] {cfg.name}: weights fp32 {fp_bytes/2**20:.2f} MiB -> "
          f"LUT-Q {q_bytes/2**20:.2f} MiB ({fp_bytes/max(q_bytes,1):.2f}x) | "
          f"quantized {quantized_fraction(sparams)*100:.1f}% of params "
          f"@ {effective_bits(sparams):.2f} effective bits")
    print(format_breakdown(rule_breakdown(sparams, policy)))
    counts = Counter(m["backend"] for m in manifest.values())
    print(f"[serve] kernel backends (requested {args.kernel_backend!r}): "
          + ", ".join(f"{k}: {v} leaves" for k, v in sorted(counts.items())))

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, P, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)

    gen, stats = generate(sparams, cfg, batch, steps=args.gen,
                          max_len=max_len, return_stats=True)
    print(f"[serve] prefill {P} toks x{B}: {stats['t_prefill_s']*1e3:.1f} ms | "
          f"decode[{stats['backend']}]: {stats['decode_tok_s']:.1f} tok/s | "
          f"sample: {np.asarray(gen[0])[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
