"""Serving driver: batched prefill + decode with LUT-Q deployment weights.

CPU scale:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 32 --gen 16 --kernel-backend fused

Continuous batching (ragged queue through the slot-pool engine):
    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --engine --max-batch 4 --queue 16 --gen 12

Tensor/data-parallel SPMD serving (see docs/sharding.md):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch mistral-nemo-12b --reduced \
        --mesh 2x4 --engine --kernel-backend fused

Uses the paper's deployment form (serve_view: dictionary + int8/packed
assignments, no fp masters) and reports the weight-memory footprint both
ways (fp32 vs LUT-Q) alongside throughput. Decode goes through
``runtime.serving.generate`` — the same jit-cached prefill/decode entry
points and SWA-ring cache re-layout the library path uses — and the
quantized matmuls dispatch through the kernel execution-backend layer
(``--kernel-backend``; see kernels/ops.lutq_dot and docs/kernels.md).
With ``--engine`` the same weights serve a ragged request queue through
``runtime.engine.Engine`` (see docs/serving.md) and the report adds
goodput and p50/p95 request latency.
"""
from __future__ import annotations

import argparse
import sys
from collections import Counter

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.lutq import LutqState
from repro.core.policy import (backend_manifest, effective_bits,
                               format_breakdown, quantized_fraction,
                               rule_breakdown, serve_view)
from repro.core.rules import get_policy
from repro.core.spec import QuantSpec
from repro.kernels.ops import BACKENDS
from repro.launch.partition import device_nbytes
from repro.models import api
from repro.models.reduce import reduced
from repro.nn.tree import tree_paths
from repro.runtime.engine import Engine
from repro.runtime.serving import generate


def footprint_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: x is None):
        if leaf is not None and hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total




def device_footprint(params, dev):
    """(quantized, dense) bytes resident on one device.

    Quantized = dictionary + assignment (+ rule id) shards of LutqState
    leaves; dense = everything else. Shared by the serve CLI report and
    ``benchmarks/shard_bench.py`` so the two always agree on what counts
    as quantized per-device weight bytes.
    """
    q = f = 0
    for _, leaf in tree_paths(params):
        if isinstance(leaf, LutqState):
            q += sum(device_nbytes(t, dev)
                     for t in (leaf.d, leaf.a, leaf.sid) if t is not None)
        elif leaf is not None and hasattr(leaf, "nbytes"):
            f += device_nbytes(leaf, dev)
    return q, f


def shard_report(params, mesh) -> str:
    """Per-device footprint + the resolved pspec of the largest leaves.

    The five largest leaves are listed with the PartitionSpec they
    actually resolved to (including divisibility fallbacks), read back
    from the placed arrays.
    """
    dev = mesh.devices.flat[0]
    q_dev, f_dev = device_footprint(params, dev)
    rows = []
    for path, leaf in tree_paths(params):
        if isinstance(leaf, LutqState):
            spec = getattr(leaf.a.sharding, "spec", None)
            rows.append((leaf.a.nbytes, "/".join(path), tuple(leaf.a.shape),
                         str(leaf.a.dtype), spec))
        elif leaf is not None and hasattr(leaf, "nbytes"):
            spec = getattr(getattr(leaf, "sharding", None), "spec", None)
            rows.append((int(leaf.nbytes), "/".join(path), tuple(leaf.shape),
                         str(leaf.dtype), spec))
    mesh_s = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    lines = [f"[serve] mesh {mesh_s} ({','.join(mesh.axis_names)}): "
             f"per-device weights quantized {q_dev/2**20:.2f} MiB + dense "
             f"{f_dev/2**20:.2f} MiB"]
    for nbytes, path, shape, dtype, spec in sorted(rows, reverse=True)[:5]:
        lines.append(f"[serve]   {path}: {dtype}{list(shape)} "
                     f"{nbytes/2**20:.2f} MiB -> "
                     f"{spec if spec is not None else 'unplaced'}")
    return "\n".join(lines)


def check_ckpt_shapes(cfg, trainable) -> None:
    """Fail loudly when a restored train checkpoint doesn't fit the
    serve config.

    Without this, a vocab/width mismatch serves garbage silently —
    out-of-bounds embedding gathers clamp under jit instead of raising.
    Compares every restored trainable leaf against the config's
    eval_shape structure and reports the offenders with the flags that
    usually explain them.
    """
    from repro.core.policy import split_trainable

    struct, axes = api.init_struct(cfg)
    struct = jax.eval_shape(lambda p: api.quantize(p, cfg, axes), struct)
    t_struct, _ = split_trainable(struct)

    bad = []

    def walk(path, exp, got):
        if isinstance(exp, dict) or isinstance(got, dict):
            e_keys = set(exp) if isinstance(exp, dict) else set()
            g_keys = set(got) if isinstance(got, dict) else set()
            for k in sorted(e_keys | g_keys):
                if k not in e_keys or k not in g_keys:
                    bad.append(f"{'/'.join(path + (k,))}: "
                               f"{'missing from checkpoint' if k not in g_keys else 'not in model'}")
                else:
                    walk(path + (k,), exp[k], got[k])
            return
        e_shape = getattr(exp, "shape", None)
        g_shape = getattr(got, "shape", None)
        if e_shape != g_shape:
            bad.append(f"{'/'.join(path)}: model {e_shape} vs "
                       f"checkpoint {g_shape}")

    walk((), t_struct, trainable)
    if bad:
        raise SystemExit(
            "[serve] checkpoint does not fit the serve config "
            f"({len(bad)} mismatched leaves, e.g. {bad[:3]}). "
            "--arch/--reduced/--vocab (and the quant policy, when the "
            "manifest lacks one) must match the training run.")


def run_engine(params, cfg, *, capacity: int, n_requests: int,
               prompt_len: int, gen: int, seed: int = 0,
               temperature: float = 0.0, mesh=None,
               kv_pages=None, page_size: int = 64,
               prefix_cache: bool = True, requests=None,
               speculative: int = 0, draft_bits: int = 3,
               draft_params=None):
    """Serve a ragged queue through the continuous-batching engine and
    return its stats dict (shared by the CLI and the example, so both
    report identical fields).

    ``kv_pages`` switches supported families onto the paged KV cache
    (block-table pages + prefix sharing; see docs/serving.md).
    ``requests`` overrides the synthetic workload with an explicit list
    of ``Engine.submit`` kwargs dicts."""
    from repro.runtime.engine import synthetic_requests

    src_len = prompt_len if cfg.family == "encdec" else 0
    eng = Engine(params, cfg, capacity=capacity,
                 max_len=prompt_len + gen + int(speculative),
                 src_len=src_len, temperature=temperature,
                 rng=jax.random.PRNGKey(seed), mesh=mesh,
                 kv_pages=kv_pages, page_size=page_size,
                 prefix_cache=prefix_cache, speculative=speculative,
                 draft_bits=draft_bits, draft_params=draft_params)
    if requests is None:
        requests = synthetic_requests(cfg, n_requests, max_prompt=prompt_len,
                                      max_new=gen, seed=seed, src_len=src_len)
    for req in requests:
        req = dict(req)
        req.pop("arrival_s", None)
        eng.submit(**req)
    eng.run()
    return eng.stats()


def format_engine_stats(stats) -> str:
    out = (f"[serve] engine: {stats['completed']}/{stats['admitted']} requests "
           f"on {stats['capacity']} slots | decode[{stats['backend']}]: "
           f"{stats['decode_tok_s']:.1f} tok/s | goodput "
           f"{stats['goodput_tok_s']:.1f} tok/s | latency p50 "
           f"{stats['p50_latency_s']*1e3:.0f} ms p95 "
           f"{stats['p95_latency_s']*1e3:.0f} ms | ttft p50 "
           f"{stats['ttft_p50_s']*1e3:.0f} ms p99 "
           f"{stats['ttft_p99_s']*1e3:.0f} ms | "
           f"{stats['decode_steps']} decode steps, "
           f"prefill {stats['t_prefill_s']:.2f} s, "
           f"decode {stats['t_decode_s']:.2f} s")
    if stats.get("paged"):
        out += (f"\n[serve] paged KV: {stats['pages_in_use']}/"
                f"{stats['kv_pages'] - 1} pages in use "
                f"(peak {stats['pages_peak']}) x {stats['page_size']} tokens"
                f" | {stats['kv_bytes_per_token']} KV bytes/token")
        if "prefix_hit_rate" in stats:
            out += (f" | prefix cache: {stats['prefix_hits']}/"
                    f"{stats['prefix_queries']} page hits "
                    f"({stats['prefix_hit_rate']*100:.0f}%), "
                    f"{stats['prefix_evictions']} evictions")
    if stats.get("speculative_k"):
        out += (f"\n[serve] speculative: k={stats['speculative_k']} "
                f"draft_bits={stats['draft_bits']} | acceptance "
                f"{stats['acceptance_rate']*100:.0f}% | "
                f"{stats['spec_tokens_per_round']:.2f} tok/round | "
                f"{stats['tokens_per_engine_step']:.2f} tok/engine-step")
        if "draft_extra_bytes" in stats:
            out += (f" | draft view +{stats['draft_extra_bytes']/2**10:.1f} "
                    f"KiB ({stats['draft_coarse_leaves']} coarse, "
                    f"{stats['draft_shared_leaves']} shared leaves)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-policy", default=None,
                    help="mixed-precision policy: preset name, "
                         "'uniform:<bits>[:<constraint>]', inline JSON, or "
                         "@policy.json; supersedes --quant-bits")
    ap.add_argument("--quant-bits", type=int, default=4)
    ap.add_argument("--pack4", action="store_true",
                    help="pack two 4-bit assignments per byte (K<=16 leaves)")
    ap.add_argument("--kernel-backend", default="auto", choices=list(BACKENDS),
                    help="kernel path for quantized matmuls: auto resolves "
                         "per leaf (int8 -> fused Pallas, packed -> packed4); "
                         "decode forces the dense-materialize reference; "
                         "packed4 implies --pack4")
    ap.add_argument("--engine", action="store_true",
                    help="serve a ragged FIFO queue through the "
                         "continuous-batching slot-pool engine instead of "
                         "one static batch (see docs/serving.md)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="engine slot-pool capacity (decode batch width)")
    ap.add_argument("--queue", type=int, default=16,
                    help="number of ragged requests to enqueue with --engine")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="serve through the paged KV cache with this many "
                         "pool pages (block-table slots, prefix sharing, "
                         "chunked prefill; attention/encdec families only — "
                         "others fall back to the slot pool; see "
                         "docs/serving.md)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="tokens per KV page (power of two)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="share identical prompt-prefix pages across "
                         "requests (--no-prefix-cache disables)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per "
                         "round from a coarsened view of the same LUT-Q "
                         "weights, verify with one target forward (greedy "
                         "output token-identical; requires --act-bits 32 — "
                         "dynamic activation quant couples draft and verify "
                         "rows; see docs/serving.md)")
    ap.add_argument("--draft-bits", type=int, default=3,
                    help="draft-view dictionary size = 2^draft_bits entries "
                         "per leaf (leaves already at or below this share "
                         "their tables with the target, costing 0 extra "
                         "bytes)")
    ap.add_argument("--act-bits", type=int, default=8, choices=(8, 32),
                    help="activation fake-quant bits for the serve regime "
                         "(32 disables; required for --speculative)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve SPMD on a (data, model) host mesh, e.g. 2x4 "
                         "(indices tensor-parallel on the model axis, batch/"
                         "caches on data; see docs/sharding.md). On CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                         "first")
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve the (d, A) trained by launch/train.py: "
                         "restore the latest LUT-Q train checkpoint (solo or "
                         "sharded — the manifest's quant policy supersedes "
                         "the --quant flags) instead of initializing from "
                         "--seed; composes with --mesh for the train->serve "
                         "handoff (see docs/training.md)")
    ap.add_argument("--vocab", type=int, default=None,
                    help="override vocab size (must match the checkpoint's "
                         "when restoring with --ckpt-dir)")
    ap.add_argument("--autotune", default="off",
                    choices=("off", "cache", "search"),
                    help="kernel tile autotuning: 'cache' loads tuned tiles "
                         "(--tuning-cache file, else the checkpoint "
                         "manifest); 'search' times the pruned candidate "
                         "grid per distinct kernel shape of this serve tree "
                         "and reports the winners (see docs/kernels.md)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning-cache JSON: read by --autotune cache, "
                         "written by --autotune search")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.vocab:
        cfg = cfg.replace(vocab=args.vocab)
    ckpt_policy = None
    if args.ckpt_dir:
        from repro.checkpoint import ckpt as ckpt_mod

        ckpt_policy = ckpt_mod.load_policy(args.ckpt_dir)
    if ckpt_policy is not None:
        cfg = cfg.replace(quant=ckpt_policy, act_bits=args.act_bits)
    elif args.quant_policy:
        cfg = cfg.replace(quant=get_policy(args.quant_policy),
                          act_bits=args.act_bits)
    else:
        cfg = cfg.replace(quant=QuantSpec(bits=args.quant_bits, min_size=1024),
                          act_bits=args.act_bits)
    cfg = cfg.replace(kernel_backend=args.kernel_backend)
    if args.speculative:
        ok, why = api.speculative_supported(cfg)
        if not ok:
            raise SystemExit(f"[serve] --speculative refused: {why}")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh, parse_mesh_arg

        dsz, msz = parse_mesh_arg(args.mesh)
        mesh = make_host_mesh(dsz, msz)

    if args.ckpt_dir:
        from repro.checkpoint import ckpt as ckpt_mod
        from repro.core.policy import merge_trainable

        # params subtrees only, memory-mapped: optimizer moments/EF
        # residuals are never read, and serve_view's packing decides
        # what actually lands on device (no eager full-state host copy)
        state, step = ckpt_mod.restore_params(args.ckpt_dir)
        check_ckpt_shapes(cfg, state["trainable"])
        qparams = merge_trainable(state["trainable"], state["static"])
        axes = api.init_axes(cfg)
        fp_bytes = footprint_bytes(state["trainable"])
        print(f"[serve] restored train checkpoint step {step} from "
              f"{args.ckpt_dir}"
              + (" (policy from manifest)" if ckpt_policy is not None else ""))
    else:
        params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
        fp_bytes = footprint_bytes(params)
        qparams = api.quantize(params, cfg, axes)
    policy = api.resolved_policy(cfg)
    pack = args.pack4 or args.kernel_backend == "packed4"
    sparams = serve_view(qparams, pack4=pack, policy=policy,
                         mesh=mesh, axes=axes)
    manifest = backend_manifest(sparams, policy,
                                override=args.kernel_backend)

    if args.autotune != "off":
        from repro.kernels import autotune, ops

        tc = ops.tuning_cache()
        if args.autotune == "cache":
            if args.tuning_cache:
                tc.update(autotune.TuningCache.load(args.tuning_cache))
                print(f"[serve] autotune: loaded {len(tc)} tuned tiles from "
                      f"{args.tuning_cache}")
            elif args.ckpt_dir:
                from repro.checkpoint import ckpt as ckpt_mod

                stored = ckpt_mod.load_tuning(args.ckpt_dir)
                if stored is not None:
                    tc.update(stored)
                    print(f"[serve] autotune: loaded {len(tc)} tuned tiles "
                          f"from the checkpoint manifest")
                else:
                    print("[serve] autotune: checkpoint manifest carries no "
                          "tuning cache (run --autotune search)")
            else:
                print("[serve] autotune cache: nothing to load "
                      "(--tuning-cache or --ckpt-dir required)")
        else:  # search
            batch_m = args.max_batch if args.engine else args.batch
            autotune.tune_tree(sparams, batch_m=batch_m, dtype=cfg.dtype,
                               cache=tc, emit=print)
            if args.tuning_cache:
                tc.save(args.tuning_cache)
                print(f"[serve] autotune: saved {len(tc)} tuned tiles to "
                      f"{args.tuning_cache}")
        # per-leaf report: the tile each quantized leaf's decode matmul hits
        batch_m = args.max_batch if args.engine else args.batch
        for rec in autotune.leaf_shapes_for_tree(sparams, batch_m=batch_m):
            key = autotune.make_key(
                rec["kernel"], rec["M"], rec["N"], rec["Kin"], rec["K"],
                cfg.dtype, rec["backend"],
                autotune.platform_key(ops._default_interpret()))
            tile = tc.get(key) or ops.DEFAULT_TILE
            for path in rec["paths"]:
                print(f"[serve]   tile {path}: {rec['backend']} "
                      f"bm={tile.bm} bn={tile.bn} bk={tile.bk} "
                      f"{tile.strategy}"
                      + ("" if tc.get(key) else " (default, untuned)"))
    q_bytes = footprint_bytes(sparams)
    print(f"[serve] {cfg.name}: weights fp32 {fp_bytes/2**20:.2f} MiB -> "
          f"LUT-Q {q_bytes/2**20:.2f} MiB ({fp_bytes/max(q_bytes,1):.2f}x) | "
          f"quantized {quantized_fraction(sparams)*100:.1f}% of params "
          f"@ {effective_bits(sparams):.2f} effective bits")
    print(format_breakdown(rule_breakdown(sparams, policy)))
    counts = Counter(m["backend"] for m in manifest.values())
    print(f"[serve] kernel backends (requested {args.kernel_backend!r}): "
          + ", ".join(f"{k}: {v} leaves" for k, v in sorted(counts.items())))
    if mesh is not None:
        print(shard_report(sparams, mesh))

    dparams = None
    if args.speculative:
        dparams, report = api.draft_view(sparams, draft_bits=args.draft_bits,
                                         with_report=True)
        extra = sum(v["draft_bytes"] for v in report.values())
        n_shared = sum(1 for v in report.values() if v["shared"])
        print(f"[serve] draft view (2^{args.draft_bits} entries): "
              f"+{extra/2**10:.1f} KiB over the target weights "
              f"({len(report) - n_shared} coarse leaves, {n_shared} shared)")
        for path, v in sorted(report.items()):
            if not v["shared"]:
                print(f"[serve]   draft {path}: K {v['K']} -> "
                      f"{v['draft_K']}, +{v['draft_bytes']/2**10:.1f} KiB")

    if args.engine:
        stats = run_engine(sparams, cfg, capacity=args.max_batch,
                           n_requests=args.queue, prompt_len=args.prompt_len,
                           gen=args.gen, seed=args.seed, mesh=mesh,
                           kv_pages=args.kv_pages, page_size=args.page_size,
                           prefix_cache=args.prefix_cache,
                           speculative=args.speculative,
                           draft_bits=args.draft_bits, draft_params=dparams)
        print(format_engine_stats(stats))
        return 0

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen + args.speculative
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, P, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)

    gen, stats = generate(sparams, cfg, batch, steps=args.gen,
                          max_len=max_len, return_stats=True, mesh=mesh,
                          speculative=args.speculative,
                          draft_bits=args.draft_bits, draft_params=dparams)
    print(f"[serve] prefill {P} toks x{B}: {stats['t_prefill_s']*1e3:.1f} ms | "
          f"decode[{stats['backend']}]: {stats['decode_tok_s']:.1f} tok/s | "
          f"sample: {np.asarray(gen[0])[:8]}")
    if args.speculative:
        print(f"[serve] speculative: k={args.speculative} acceptance "
              f"{stats['acceptance_rate']*100:.0f}% | "
              f"{stats['spec_tokens_per_round']:.2f} tok/round | "
              f"{stats['tokens_per_engine_step']:.2f} tok/engine-step")
    return 0


if __name__ == "__main__":
    sys.exit(main())
