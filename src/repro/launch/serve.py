"""Serving driver: batched prefill + decode with LUT-Q deployment weights.

CPU scale:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 32 --gen 16

Uses the paper's deployment form (serve_view: dictionary + int8
assignments, no fp masters) and reports the weight-memory footprint both
ways (fp32 vs LUT-Q) alongside throughput.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.policy import (effective_bits, format_breakdown,
                               quantized_fraction, rule_breakdown, serve_view)
from repro.core.rules import get_policy
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced


def footprint_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params, is_leaf=lambda x: x is None):
        if leaf is not None and hasattr(leaf, "nbytes"):
            total += leaf.nbytes
    return total


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quant-policy", default=None,
                    help="mixed-precision policy: preset name, "
                         "'uniform:<bits>[:<constraint>]', inline JSON, or "
                         "@policy.json; supersedes --quant-bits")
    ap.add_argument("--quant-bits", type=int, default=4)
    ap.add_argument("--pack4", action="store_true",
                    help="pack two 4-bit assignments per byte (K<=16 leaves)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.quant_policy:
        cfg = cfg.replace(quant=get_policy(args.quant_policy), act_bits=8)
    else:
        cfg = cfg.replace(quant=QuantSpec(bits=args.quant_bits, min_size=1024),
                          act_bits=8)

    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    fp_bytes = footprint_bytes(params)
    qparams = api.quantize(params, cfg, axes)
    policy = api.resolved_policy(cfg)
    sparams = serve_view(qparams, pack4=args.pack4, policy=policy)
    q_bytes = footprint_bytes(sparams)
    print(f"[serve] {cfg.name}: weights fp32 {fp_bytes/2**20:.2f} MiB -> "
          f"LUT-Q {q_bytes/2**20:.2f} MiB ({fp_bytes/max(q_bytes,1):.2f}x) | "
          f"quantized {quantized_fraction(sparams)*100:.1f}% of params "
          f"@ {effective_bits(sparams):.2f} effective bits")
    print(format_breakdown(rule_breakdown(sparams, policy)))

    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, P, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)

    prefill = jax.jit(lambda p, b: api.prefill(p, cfg, b, max_len=max_len))
    decode = jax.jit(lambda p, t, c: api.decode_step(p, cfg, t, c))

    t0 = time.perf_counter()
    logits, cache = prefill(sparams, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # grow linear caches to max_len where the family needs it
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        full = api.init_cache(cfg, B, max_len,
                              src_len=P if cfg.family == "encdec" else 0)
        def merge(big, small):
            if big.shape == small.shape:
                return small.astype(big.dtype)
            pad = [(0, b - s) for b, s in zip(big.shape, small.shape)]
            return jnp.pad(small.astype(big.dtype), pad)
        cache_layers = jax.tree.map(merge, full["layers"], cache["layers"])
        cache = {**cache, "layers": cache_layers}

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(sparams, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(outs, axis=1)
    tput = B * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {P} toks x{B}: {t_prefill*1e3:.1f} ms | "
          f"decode: {tput_fmt(tput)} tok/s | sample: {np.asarray(gen[0])[:8]}")
    return 0


def tput_fmt(x):
    return f"{x:.1f}"


if __name__ == "__main__":
    sys.exit(main())
