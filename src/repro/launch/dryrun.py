import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the quantized model state via jax.eval_shape (no allocation),
  2. assigns NamedShardings from the logical-axis rules,
  3. jits the right entry point (train_step / prefill / decode_step),
  4. ``.lower().compile()`` on the production mesh,
  5. records memory_analysis, cost_analysis FLOPs/bytes and the
     per-collective byte counts parsed from the optimized HLO,
  6. writes one JSON artifact per cell for the roofline layer.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
          --mesh both --out benchmarks/artifacts/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch import partition
from repro.models import api
from repro.models.api import SHAPES
from repro.optim.optimizers import adamw, sgd
from repro.optim.train_state import init_train_state, make_train_step, state_flat

HLO_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2,
                "u16": 2}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[16,4096,128]'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the optimized HLO.

    These are per-device shapes (SPMD), so totals are per-device wire
    bytes — exactly what the ICI roofline term wants.
    """
    out = {k: 0 for k in HLO_COLLECTIVES}
    counts = {k: 0 for k in HLO_COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.-]+ = (\([^)]*\)|[^ ]+) ([\w-]+)", ls)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        for c in HLO_COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or opname == c + "-done":
                if opname.endswith("-done"):
                    continue  # counted at -start
                out[c] += _op_bytes(shape_str)
                counts[c] += 1
    return out, counts


def _pick_optimizer(n_params: int):
    # paper-faithful SGD+momentum for the giants (3 state bytes/param
    # incl. int8 assignments), AdamW for the rest
    if n_params >= 5e10:
        return sgd(1e-2, momentum=0.9), "sgd_momentum"
    return adamw(3e-4), "adamw"


def build_cell(arch: str, shape_name: str, mesh, overrides=None):
    """Returns (lower_fn, meta) or raises Skip.

    overrides: dict of ModelConfig field -> value (plus the special key
    "microbatches") — used by §Perf to lower optimized variants while
    the unsuffixed artifacts stay paper-faithful baselines.
    """
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    micro_override = overrides.pop("microbatches", None)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    ok, why = api.supports_shape(cfg, shape)
    if not ok:
        raise SkipCell(why)

    cap = {}

    def initp(k):
        p, a = api.init(k, cfg)
        cap["axes"] = a
        return p

    params_struct = jax.eval_shape(initp, jax.random.PRNGKey(0))
    params_struct = jax.eval_shape(
        lambda p: api.quantize(p, cfg, cap["axes"]), params_struct)
    axes = cap["axes"]
    n_params = sum(l.w.size if hasattr(l, "w") and l.w is not None else
                   (l.size if hasattr(l, "size") else 0)
                   for l in jax.tree.leaves(
                       params_struct,
                       is_leaf=lambda x: hasattr(x, "w") or x is None))

    batch_struct = api.input_specs(cfg, shape)
    dp_total = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_total *= mesh.shape[a]

    if shape.kind == "train":
        opt, opt_name = _pick_optimizer(n_params)
        per_shard = shape.global_batch // dp_total
        microbatches = max(1, min(per_shard, 16 if n_params >= 5e10 else 8))
        if micro_override is not None:
            microbatches = micro_override
        state_struct = jax.eval_shape(
            lambda p: state_flat(init_train_state(p, opt)), params_struct)
        state_sh = partition.train_state_shardings(axes, params_struct,
                                                   state_struct, mesh)
        batch_sh = partition.data_batch_shardings(batch_struct, mesh)
        step_fn = make_train_step(cfg, api.loss_fn, opt,
                                  microbatches=microbatches)
        jf = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None))
        lower = lambda: jf.lower(state_struct, batch_struct)
        meta = {"kind": "train", "optimizer": opt_name,
                "microbatches": microbatches}
    else:
        from repro.core.policy import serve_view
        sparams_struct = jax.eval_shape(
            lambda p: serve_view(p, pack4=cfg.pack_assignments), params_struct)
        params_sh = partition.params_shardings(axes, sparams_struct, mesh)
        if shape.kind == "prefill":
            batch_sh = partition.data_batch_shardings(batch_struct, mesh)
            jf = jax.jit(
                lambda p, b: api.prefill(p, cfg, b, max_len=shape.seq_len),
                in_shardings=(params_sh, batch_sh))
            lower = lambda: jf.lower(sparams_struct, batch_struct)
            meta = {"kind": "prefill"}
        else:
            token_struct = batch_struct["token"]
            cache_struct = batch_struct["cache"]
            token_sh = partition.token_shardings(token_struct, mesh)
            cache_sh = partition.cache_shardings(cache_struct, mesh)
            jf = jax.jit(
                lambda p, t, c: api.decode_step(p, cfg, t, c),
                in_shardings=(params_sh, token_sh, cache_sh),
                out_shardings=(None, cache_sh))
            lower = lambda: jf.lower(sparams_struct, token_struct, cache_struct)
            meta = {"kind": "decode"}

    meta.update(arch=arch, shape=shape_name, n_params=int(n_params),
                seq_len=shape.seq_len, global_batch=shape.global_batch)
    return lower, meta


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             force: bool = False, overrides=None, variant: str = ""):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{variant}" if variant else ""
    out_path = out_dir / mesh_tag / f"{arch}__{shape_name}{suffix}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)
    if out_path.exists() and not force:
        print(f"[dryrun] {mesh_tag}/{arch}/{shape_name}{suffix}: cached")
        return json.loads(out_path.read_text())

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
           "devices": int(len(jax.devices())),
           "variant": variant or "baseline", "overrides": overrides or {}}
    try:
        lower_fn, meta = build_cell(arch, shape_name, mesh, overrides)
        rec.update(meta)
        t0 = time.time()
        with mesh:
            lowered = lower_fn()
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)

            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # older jax: list per device
                ca = ca[0] if ca else {}
            rec["cost"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
                "transcendentals": float(ca.get("transcendentals", -1)),
            }
            t0 = time.time()
            hlo = compiled.as_text()
            coll, counts = collective_bytes(hlo)
            rec["collectives_bytes"] = coll
            rec["collectives_count"] = counts
            rec["hlo_parse_s"] = round(time.time() - t0, 2)
            rec["status"] = "ok"
            print(f"[dryrun] {mesh_tag}/{arch}/{shape_name}: OK "
                  f"compile={rec['compile_s']}s flops={rec['cost']['flops']:.3e} "
                  f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB/dev")
    except SkipCell as e:
        rec["status"] = "skipped"
        rec["reason"] = str(e)
        print(f"[dryrun] {mesh_tag}/{arch}/{shape_name}: SKIP ({e})")
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {mesh_tag}/{arch}/{shape_name}: ERROR {rec['error']}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="artifact suffix for optimized lowerings")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field=value (repeatable); special key "
                         "microbatches=N")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out)

    results = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, multi_pod=multi_pod,
                                        out_dir=out_dir, force=args.force,
                                        overrides=overrides or None,
                                        variant=args.variant))
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    er = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {er} errors / {len(results)}")
    return 1 if er else 0


if __name__ == "__main__":
    sys.exit(main())
