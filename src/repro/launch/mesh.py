"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (DCN-connected), "data"
is FSDP/DP within a pod, "model" is tensor/expert parallel on ICI.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    # axis_types / AxisType only exist on newer jax; Auto is the default
    # behavior there anyway, so older versions just omit the kwarg.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_make_mesh = compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _make_mesh((data, model), ("data", "model"))
