"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across pods (DCN-connected), "data"
is FSDP/DP within a pod, "model" is tensor/expert parallel on ICI.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    # axis_types / AxisType only exist on newer jax; Auto is the default
    # behavior there anyway, so older versions just omit the kwarg.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


_make_mesh = compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return _make_mesh((data, model), ("data", "model"))


def parse_mesh_arg(spec: str):
    """CLI mesh spec "DxM" (e.g. "2x4") -> (data, model).

    Raises with an actionable message when the host exposes fewer
    devices than requested (on CPU, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before*
    starting python to split the host into N virtual devices).
    """
    try:
        data, model = (int(t) for t in spec.lower().split("x"))
    except ValueError as e:
        raise ValueError(f"--mesh wants DxM (e.g. 2x4), got {spec!r}") from e
    if data < 1 or model < 1:
        raise ValueError(f"--mesh sizes must be >= 1, got {spec!r}")
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(
            f"--mesh {spec} needs {data * model} devices but only {n} are "
            f"visible; on CPU set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={data * model} before launching")
    return data, model
