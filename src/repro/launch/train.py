"""Training driver.

CPU scale (this container):
    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Mesh-parallel SPMD training (see docs/training.md). The same step runs
over a ("data", "model") mesh with FSDP/DP-sharded masters + optimizer
moments, shard-local step-4 k-means (per-shard sums/counts combined via
psum — exact, no gather), and optionally the compressed data-parallel
gradient exchange:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.train --arch h2o-danube-1.8b --reduced \
        --steps 50 --mesh 2x4 --grad-compress ef --ckpt-dir /tmp/ckpt

The resulting (sharded) checkpoint restores straight into
``launch/serve.py --ckpt-dir ... --mesh DxM`` — the PR 4 sharded serving
path. At pod scale the same driver runs per-host after
``jax.distributed.initialize()`` with the same mesh axes and shardings.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.core.rules import get_policy
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.distributed.compress import (GRAD_COMPRESS_MODES,
                                        dp_grad_transform, trainable_pspecs)
from repro.launch.mesh import make_host_mesh, parse_mesh_arg
from repro.launch import partition
from repro.models import api
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw, cosine_schedule
from repro.optim.train_state import init_train_state, make_train_step, state_flat
from repro.runtime.loop import TrainLoop


def _train_cfg(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if getattr(args, "quant_policy", None):
        policy = get_policy(args.quant_policy)
        cfg = cfg.replace(quant=policy, act_bits=args.act_bits)
        print(policy.describe())
    elif args.quant_bits > 0:
        cfg = cfg.replace(quant=QuantSpec(bits=args.quant_bits,
                                          constraint=args.quant_constraint,
                                          kmeans_iters=1,
                                          min_size=args.quant_min_size),
                          act_bits=args.act_bits)
    else:
        cfg = cfg.replace(quant=None, act_bits=32)
    if args.vocab:
        cfg = cfg.replace(vocab=args.vocab)
    return cfg


def build(args, mesh=None):
    """(cfg, state, step_fn, shardings) for one training run.

    ``mesh=None`` is the solo path (caller jits the returned step).
    With a mesh the state is placed onto its train NamedShardings and
    the step comes back jitted with explicit in/out shardings; with
    ``args.grad_compress`` the compressed-DP grad_transform is installed
    and the state carries the error-feedback tree.
    """
    cfg = _train_cfg(args)
    compress = getattr(args, "grad_compress", None)

    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    params = api.quantize(params, cfg, axes)
    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    state = state_flat(init_train_state(params, opt,
                                        grad_compress=bool(compress)))
    shardings = None
    if mesh is not None:
        shardings = partition.train_shardings(
            cfg, mesh, batch=args.batch, seq=args.seq,
            grad_compress=bool(compress))
        state = partition.place_state(state, shardings["state"])
    grad_transform = (dp_grad_transform(
        mesh, mode=compress,
        pspecs=None if shardings is None
        else trainable_pspecs(shardings["state"]))
        if compress else None)
    step_fn = make_train_step(cfg, api.loss_fn, opt,
                              microbatches=args.microbatches,
                              grad_transform=grad_transform,
                              shardings=shardings)
    return cfg, state, step_fn, shardings


def train_report(state, mesh) -> str:
    """Per-device master/static bytes + the resolved pspecs of the
    largest trainable leaves (train-side twin of serve's shard_report)."""
    from repro.launch.partition import device_nbytes
    from repro.nn.tree import tree_paths

    dev = mesh.devices.flat[0]
    t_dev = sum(device_nbytes(l, dev)
                for _, l in tree_paths(state["trainable"])
                if l is not None and hasattr(l, "nbytes"))
    s_dev = sum(device_nbytes(l, dev)
                for _, l in tree_paths(state["static"])
                if l is not None and hasattr(l, "nbytes"))
    rows = sorted(((int(l.nbytes), "/".join(p),
                    getattr(getattr(l, "sharding", None), "spec", None))
                   for p, l in tree_paths(state["trainable"])
                   if l is not None and hasattr(l, "nbytes")), reverse=True)
    mesh_s = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    lines = [f"[train] mesh {mesh_s} ({','.join(mesh.axis_names)}): "
             f"per-device masters {t_dev/2**20:.2f} MiB + "
             f"LUT-Q/static {s_dev/2**20:.2f} MiB"]
    for nbytes, path, spec in rows[:3]:
        lines.append(f"[train]   {path}: {nbytes/2**20:.2f} MiB -> "
                     f"{spec if spec is not None else 'unplaced'}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (same structure)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant-policy", default=None,
                    help="mixed-precision policy: preset name "
                         "(paper_default | serving_aggressive | mixed_paper), "
                         "'uniform:<bits>[:<constraint>]', inline JSON, or "
                         "@policy.json; supersedes --quant-bits")
    ap.add_argument("--quant-bits", type=int, default=4,
                    help="legacy uniform knob (ignored when --quant-policy "
                         "is given)")
    ap.add_argument("--quant-constraint", default="pow2",
                    choices=["none", "pow2", "binary", "ternary"])
    ap.add_argument("--quant-min-size", type=int, default=4096)
    ap.add_argument("--act-bits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="train SPMD on a (data, model) host mesh, e.g. 2x4 "
                         "(FSDP/DP masters+moments, tensor-parallel kernels, "
                         "shard-local k-means; see docs/training.md). On CPU "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first")
    ap.add_argument("--grad-compress", default=None,
                    choices=list(GRAD_COMPRESS_MODES),
                    help="compressed data-parallel gradient exchange: 'ef' = "
                         "error-feedback int8 (compressed-collective "
                         "arithmetic), 'ring' = ef + the explicit f16-payload "
                         "ppermute ring over the data axis")
    ap.add_argument("--autotune", default="off",
                    choices=("off", "cache", "search"),
                    help="kernel tile autotuning for the train->serve "
                         "handoff: 'cache' loads tuned tiles from "
                         "--tuning-cache; 'search' tunes this config's "
                         "serve-form kernel shapes before training. Either "
                         "way the cache rides in every checkpoint manifest "
                         "(serve --autotune cache picks it up)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="tuning-cache JSON: read by --autotune cache, "
                         "written by --autotune search")
    ap.add_argument("--autotune-batch", type=int, default=8,
                    help="decode batch M the --autotune search tunes for "
                         "(match the serve engine's --max-batch)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        dsz, msz = parse_mesh_arg(args.mesh)
        mesh = make_host_mesh(dsz, msz)

    cfg, state, step_fn, shardings = build(args, mesh)
    if mesh is None:
        step_fn = jax.jit(step_fn)
    else:
        print(train_report(state, mesh))

    tuning = None
    if args.autotune != "off":
        from repro.core.policy import serve_view
        from repro.kernels import autotune, ops

        tuning = ops.tuning_cache()
        if args.autotune == "cache":
            if args.tuning_cache:
                tuning.update(autotune.TuningCache.load(args.tuning_cache))
                print(f"[train] autotune: loaded {len(tuning)} tuned tiles "
                      f"from {args.tuning_cache}")
            else:
                print("[train] autotune cache: --tuning-cache required")
        else:  # search the serve-form shapes this run will deploy as
            from repro.core.policy import merge_trainable

            sv = serve_view(merge_trainable(state["trainable"],
                                            state["static"]),
                            policy=api.resolved_policy(cfg))
            autotune.tune_tree(sv, batch_m=args.autotune_batch,
                               dtype=cfg.dtype, cache=tuning, emit=print)
            if args.tuning_cache:
                tuning.save(args.tuning_cache)
                print(f"[train] autotune: saved {len(tuning)} tuned tiles "
                      f"to {args.tuning_cache}")
            del sv

    lm = MarkovLM(cfg.vocab, seed=args.data_seed)

    def make_batch(step):
        b = lm.batch(args.data_seed, step, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(8), step)
            batch["prefix_embeds"] = jax.random.normal(
                key, (args.batch, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)
        return batch

    from repro.models.api import resolved_policy
    loop = TrainLoop(step_fn, make_batch, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, log_every=10,
                     quant_policy=resolved_policy(cfg),
                     shardings=None if shardings is None
                     else shardings["state"],
                     mesh=mesh, tuning=tuning)
    state, step = loop.run(state, args.steps)
    losses = [h["loss"] for h in loop.history]
    if losses:
        print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(floor ~{lm.entropy_floor():.3f}) in {step} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
