"""Training driver.

CPU scale (this container):
    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

At pod scale the same driver runs per-host after
``jax.distributed.initialize()`` with ``--mesh single|multi`` (the mesh
axes and shardings are identical to the dry-run's).
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.rules import get_policy
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch import partition
from repro.models import api
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw, cosine_schedule
from repro.optim.train_state import init_train_state, make_train_step, state_flat
from repro.runtime.loop import TrainLoop


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if getattr(args, "quant_policy", None):
        policy = get_policy(args.quant_policy)
        cfg = cfg.replace(quant=policy, act_bits=args.act_bits)
        print(policy.describe())
    elif args.quant_bits > 0:
        cfg = cfg.replace(quant=QuantSpec(bits=args.quant_bits,
                                          constraint=args.quant_constraint,
                                          kmeans_iters=1,
                                          min_size=args.quant_min_size),
                          act_bits=args.act_bits)
    else:
        cfg = cfg.replace(quant=None, act_bits=32)
    if args.vocab:
        cfg = cfg.replace(vocab=args.vocab)

    params, axes = api.init(jax.random.PRNGKey(args.seed), cfg)
    params = api.quantize(params, cfg, axes)
    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    state = state_flat(init_train_state(params, opt))
    step_fn = make_train_step(cfg, api.loss_fn, opt,
                              microbatches=args.microbatches)
    return cfg, state, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config (same structure)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--quant-policy", default=None,
                    help="mixed-precision policy: preset name "
                         "(paper_default | serving_aggressive | mixed_paper), "
                         "'uniform:<bits>[:<constraint>]', inline JSON, or "
                         "@policy.json; supersedes --quant-bits")
    ap.add_argument("--quant-bits", type=int, default=4,
                    help="legacy uniform knob (ignored when --quant-policy "
                         "is given)")
    ap.add_argument("--quant-constraint", default="pow2",
                    choices=["none", "pow2", "binary", "ternary"])
    ap.add_argument("--quant-min-size", type=int, default=4096)
    ap.add_argument("--act-bits", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, state, step_fn = build(args)
    step_fn = jax.jit(step_fn)

    lm = MarkovLM(cfg.vocab, seed=args.data_seed)

    def make_batch(step):
        b = lm.batch(args.data_seed, step, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "encdec":
            key = jax.random.fold_in(jax.random.PRNGKey(7), step)
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            key = jax.random.fold_in(jax.random.PRNGKey(8), step)
            batch["prefix_embeds"] = jax.random.normal(
                key, (args.batch, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)
        return batch

    from repro.models.api import resolved_policy
    loop = TrainLoop(step_fn, make_batch, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, log_every=10,
                     quant_policy=resolved_policy(cfg))
    state, step = loop.run(state, args.steps)
    losses = [h["loss"] for h in loop.history]
    if losses:
        print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"(floor ~{lm.entropy_floor():.3f}) in {step} steps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
