"""Sharding assembly for the full train/serve states.

Maps the model's logical-axes tree + structural knowledge of the cache
trees onto concrete NamedShardings for every jit boundary the launcher
lowers: train_step(state, batch), prefill(params, batch),
decode_step(params, token, cache), and — via :func:`serve_shardings` —
the serving runtime's prefill/decode/engine-step jits (see
docs/sharding.md).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.lutq import LutqState
from repro.distributed.sharding import (batch_pspec, pspec_for, train_pspecs,
                                        tree_pspecs)


def _named(mesh, spec):
    return NamedSharding(mesh, spec)


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    axes = axes if isinstance(axes, tuple) else (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0 and dim >= size


def params_shardings(axes_tree, params_struct, mesh: Mesh):
    pspecs = tree_pspecs(axes_tree, mesh, params_struct)
    return jax.tree.map(lambda s: _named(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _mirror_split(pspecs, struct):
    """Split a params pspec tree the way split_trainable splits params."""
    import jax.numpy as jnp

    def walk(ps, st):
        if isinstance(st, LutqState):
            s = {"__lutq_d": ps.d, "__lutq_a": ps.a}
            if st.sid is not None:
                s["__lutq_sid"] = ps.sid if ps.sid is not None else P()
            return ps.w, s
        if isinstance(st, dict):
            pairs = {k: walk(ps[k], st[k]) for k in st}
            return ({k: v[0] for k, v in pairs.items()},
                    {k: v[1] for k, v in pairs.items()})
        if st is None:
            return None, None
        if not jnp.issubdtype(st.dtype, jnp.inexact):
            return None, {"__static": ps}
        return ps, None

    return walk(pspecs, struct)


def train_state_shardings(axes_tree, params_struct, state_struct, mesh: Mesh):
    """Shardings for {"trainable","static","opt_state","step"[,"ef"]}.

    Masters/moments/EF residuals follow ``train_pspecs`` (FSDP embed ->
    data + tensor-parallel model axes; dictionaries and rule ids
    replicated); ``step`` replicates.
    """
    pspecs = train_pspecs(axes_tree, mesh, params_struct)
    t_spec, s_spec = _mirror_split(pspecs, params_struct)

    def like_trainable(opt_struct):
        # opt entries ("m", "v") mirror the trainable tree exactly
        return {k: t_spec for k in opt_struct}

    spec_tree = {
        "trainable": t_spec,
        "static": s_spec,
        "opt_state": like_trainable(state_struct["opt_state"]),
        "step": P(),
    }
    if "ef" in state_struct:
        spec_tree["ef"] = t_spec

    def to_sharding(spec, st):
        if st is None:
            return None
        return _named(mesh, spec if spec is not None else P())

    return jax.tree.map(to_sharding, spec_tree, state_struct,
                        is_leaf=lambda x: x is None or isinstance(x, P))


def data_batch_shardings(batch_struct, mesh: Mesh):
    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def one(x):
        parts = [None] * x.ndim
        if x.ndim >= 1 and _fits(x.shape[0], mesh, spec_dp):
            parts[0] = spec_dp
        if x.ndim == 3 and _fits(x.shape[-1], mesh, "model"):
            parts[-1] = "model"  # frames/prefix embeddings: shard feature dim
        return _named(mesh, P(*parts))

    return jax.tree.map(one, batch_struct)


_SEQ_CACHE_KEYS = {"k", "v", "xk", "xv", "c_kv", "k_rope"}
_SEQ_SCALE_KEYS = {"k_scale", "v_scale"}
_STATE_CACHE_KEYS = {"ssm", "wkv"}


def cache_shardings(cache_struct, mesh: Mesh):
    """Decode-cache shardings.

    Sequence-major caches (KV, MLA latents) shard batch on DP and the
    sequence dim on "model" (context parallel: 8 kv-heads don't divide a
    16-way model axis, the 32k/524k sequence always does). O(1) SSM/WKV
    states shard batch on DP and heads/features on "model".
    """
    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def walk(path, x):
        if x is None:
            return None
        name = path[-1]
        parts = [None] * x.ndim
        if name in _SEQ_CACHE_KEYS or name in _SEQ_SCALE_KEYS:
            # (..., B, S, ...) — B at ndim-3 or ndim-4 depending on rank
            b_idx = x.ndim - (4 if name in ("k", "v", "xk", "xv") else 3)
            s_idx = b_idx + 1
            if _fits(x.shape[b_idx], mesh, spec_dp):
                parts[b_idx] = spec_dp
            if _fits(x.shape[s_idx], mesh, "model"):
                parts[s_idx] = "model"
        elif name in _STATE_CACHE_KEYS:
            b_idx = x.ndim - 4
            h_idx = b_idx + 1
            if _fits(x.shape[b_idx], mesh, spec_dp):
                parts[b_idx] = spec_dp
            if _fits(x.shape[h_idx], mesh, "model"):
                parts[h_idx] = "model"
        elif name in ("shift_t", "shift_c"):
            b_idx = x.ndim - 3
            if _fits(x.shape[b_idx], mesh, spec_dp):
                parts[b_idx] = spec_dp
            if _fits(x.shape[-1], mesh, "model"):
                parts[-1] = "model"
        elif name == "conv":
            b_idx = x.ndim - 3
            if _fits(x.shape[b_idx], mesh, spec_dp):
                parts[b_idx] = spec_dp
            if _fits(x.shape[-1], mesh, "model"):
                parts[-1] = "model"
        elif name == "len":
            pass
        return _named(mesh, P(*parts))

    from repro.nn.tree import map_with_path
    return map_with_path(walk, cache_struct)


def token_shardings(token_struct, mesh: Mesh):
    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    parts = [None] * token_struct.ndim
    if _fits(token_struct.shape[0], mesh, spec_dp):
        parts[0] = spec_dp
    return _named(mesh, P(*parts))


# ---------------------------------------------------------------------------
# serving: explicit shardings for the runtime's prefill/decode/engine jits
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def cache_batch_axes(cfg, max_len: int, src_len: int):
    """Per-leaf batch axis of the decode cache, found structurally.

    Stacked layer leaves carry the batch on axis 1 ((L, B, S, ...)),
    zamba mamba states on axis 2, ``len`` on axis 0 — rather than
    hard-coding per family, compare the cache shapes at two batch
    sizes and take the axis that scales."""
    from repro.models import api

    s1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, max_len, src_len=src_len))
    s3 = jax.eval_shape(lambda: api.init_cache(cfg, 3, max_len, src_len=src_len))
    axes = []
    for a, b in zip(jax.tree.leaves(s1), jax.tree.leaves(s3)):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diff) != 1:
            raise ValueError(f"ambiguous batch axis: {a.shape} vs {b.shape}")
        axes.append(diff[0])
    return tuple(axes)


def serve_cache_shardings(cfg, mesh: Mesh, batch: int, max_len: int,
                          src_len: int = 0):
    """Decode-pool cache shardings for serving: batch on the data axis.

    Unlike the dryrun's :func:`cache_shardings` (context parallelism:
    sequence dim on "model" for the 32k/524k lowerings), the serving
    pool replicates the sequence dim — attention reductions then stay
    whole per stream, which keeps sharded decode bit-identical to a
    single device (the engine-parity contract). The batch dim lands on
    "data" wherever the slot count divides it.
    """
    from repro.models import api

    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    struct = jax.eval_shape(
        lambda: api.init_cache(cfg, batch, max_len, src_len=src_len))
    axes = cache_batch_axes(cfg, max_len, src_len)
    leaves, treedef = jax.tree.flatten(struct)
    out = []
    for leaf, b_ax in zip(leaves, axes):
        parts = [None] * leaf.ndim
        if _fits(leaf.shape[b_ax], mesh, spec_dp):
            parts[b_ax] = spec_dp
        out.append(_named(mesh, P(*parts)))
    return jax.tree.unflatten(treedef, out)


@functools.lru_cache(maxsize=64)
def serve_shardings(cfg, mesh: Mesh, *, batch: int, max_len: int,
                    src_len: int = 0):
    """NamedShardings for every serving jit boundary of one engine pool.

    Returns a dict (cached per (cfg, mesh, pool geometry) — both keys
    are hashable, so runtime jit caches keyed on the same tuple never
    reuse a trace across meshes):

      cache   decode-pool cache tree (batch on "data", seq replicated)
      token   (B, 1) decode token / sampled-token layout
      keys    (B, key) per-slot rng chains
      logits  (B, 1, V) sampler input — vocab on "model" when it divides
    """
    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    b_parts = spec_dp if _fits(batch, mesh, spec_dp) else None
    v_parts = "model" if _fits(cfg.vocab, mesh, "model") else None
    return {
        "cache": serve_cache_shardings(cfg, mesh, batch, max_len, src_len),
        "token": _named(mesh, P(b_parts, None)),
        "keys": _named(mesh, P(b_parts, None)),
        "logits": _named(mesh, P(b_parts, None, v_parts)),
    }


@functools.lru_cache(maxsize=64)
def paged_serve_shardings(cfg, mesh: Mesh, *, batch: int, n_pages: int,
                          page_size: int, n_blocks: int, src_len: int = 0):
    """NamedShardings for the paged-engine jit boundaries.

    The page pool is *replicated over the data axis* — any slot's block
    row may reference any physical page (that is the whole point of
    prefix sharing), so pages cannot follow the batch partition — and
    model-sharded on the KV-head axis when it divides. Block table and
    length vectors batch-shard on "data" like the slot pool; token/keys/
    logits reuse the slot-path layout.
    """
    from repro.models import api
    from repro.nn.tree import map_with_path

    dp = _dp_axes(mesh)
    spec_dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    b_parts = spec_dp if _fits(batch, mesh, spec_dp) else None
    v_parts = "model" if _fits(cfg.vocab, mesh, "model") else None
    struct = jax.eval_shape(
        lambda: api.init_paged_cache(cfg, batch, n_pages, page_size,
                                     n_blocks, src_len=src_len))

    def walk(path, leaf):
        name = path[-1]
        parts = [None] * leaf.ndim
        if "pool" in path:
            # (Ls, P, page, Hkv[, dh]) — replicate pages, split KV heads
            if leaf.ndim >= 4 and _fits(leaf.shape[3], mesh, "model"):
                parts[3] = "model"
        elif name in ("xk", "xv"):
            # (Ls, B, src, Hkv, dh) — per-slot cross KV follows the batch
            if _fits(leaf.shape[1], mesh, spec_dp):
                parts[1] = spec_dp
        elif name == "block":
            if _fits(leaf.shape[0], mesh, spec_dp):
                parts[0] = spec_dp
        # len / src_len replicated
        return _named(mesh, P(*parts))

    return {
        "cache": map_with_path(walk, struct),
        "token": _named(mesh, P(b_parts, None)),
        "keys": _named(mesh, P(b_parts, None)),
        "logits": _named(mesh, P(b_parts, None, v_parts)),
    }


# ---------------------------------------------------------------------------
# training: explicit shardings for the train-step jit boundary
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def train_shardings(cfg, mesh: Mesh, *, batch: int, seq: int,
                    optimizer: str = "adamw", grad_compress: bool = False):
    """NamedShardings for the SPMD train step of one (cfg, mesh, batch
    geometry) cell — the train-side twin of :func:`serve_shardings`.

    Returns a cached dict (both keys hashable, so every jit keyed on the
    same tuple reuses one trace per mesh):

      state   {"trainable","static","opt_state","step"[,"ef"]} — masters,
              moments and EF residuals FSDP/TP-sharded per TRAIN_RULES;
              LUT-Q dictionaries/rule ids replicated
      batch   tokens/labels (+frames/prefix embeds) batch-sharded on the
              data axes

    Feed them to ``make_train_step(..., shardings=)`` and reuse
    ``["state"]`` for initial placement, checkpoint restore
    (``ckpt.restore(shardings=)``) and elastic resume onto a different
    mesh.
    """
    from repro.models import api
    from repro.optim.optimizers import adamw, sgd
    from repro.optim.train_state import init_train_state, state_flat

    params_struct, axes = api.init_struct(cfg)
    params_struct = jax.eval_shape(
        lambda p: api.quantize(p, cfg, axes), params_struct)
    opt = {"adamw": adamw(1e-3), "sgd": sgd(1e-2)}[optimizer]
    state_struct = jax.eval_shape(
        lambda p: state_flat(init_train_state(p, opt,
                                              grad_compress=grad_compress)),
        params_struct)
    state_sh = train_state_shardings(axes, params_struct,
                                     state_struct, mesh)

    sds, i32 = jax.ShapeDtypeStruct, jnp.int32
    batch_struct = {"tokens": sds((batch, seq), i32),
                    "labels": sds((batch, seq), i32)}
    if cfg.family == "encdec":
        batch_struct["frames"] = sds((batch, seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch_struct["prefix_embeds"] = sds(
            (batch, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)
    return {"state": state_sh,
            "batch": data_batch_shardings(batch_struct, mesh)}


def place_state(state, state_shardings):
    """device_put every train-state leaf onto its NamedSharding (initial
    placement / after an unsharded restore)."""
    return jax.tree.map(
        lambda x, s: x if (x is None or s is None) else jax.device_put(x, s),
        state, state_shardings, is_leaf=lambda x: x is None)


def device_nbytes(x, dev) -> int:
    """Bytes of ``x`` resident on one device (its shard, or everything
    for unsharded/host arrays). Shared by the train/serve CLI reports
    and the shard/train benchmarks so they agree on what counts as
    per-device bytes."""
    try:
        shards = x.addressable_shards
    except Exception:  # noqa: BLE001 — numpy / host leaf
        return int(x.nbytes)
    for s in shards:
        if s.device == dev:
            return int(s.data.nbytes)
    return 0
