"""Sharded, async, atomic checkpointing with elastic restore.

Layout:
    <dir>/step_<N>.tmp/      (written)
    <dir>/step_<N>/          (atomic rename on completion)
        manifest.json        tree structure, shapes, dtypes, step
        arr_<k>.npy          one file per leaf (per-host shard at scale)

Design notes for 1000+ node deployment (implemented here at CPU scale,
interfaces shaped for the real thing):
  * every host writes only the shards it owns (`addressable_shards`);
    the manifest records the global shape so restore can re-shard onto a
    *different* mesh (elastic scaling).
  * writes go to `.tmp` then `os.replace` -> crash-consistent; a partial
    checkpoint is never visible.
  * `save_async` snapshots to host RAM synchronously (cheap) and writes
    on a background thread so the train loop is not blocked.
  * `keep_n` garbage-collects old steps after a successful write.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.lutq import LutqState
from repro.core.rules import QuantPolicy

_TAG = {"LutqState": LutqState}


def _flatten(tree, prefix="") -> List[Tuple[str, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out += _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, LutqState):
        node = {"__lutq__w": tree.w, "__lutq__d": tree.d, "__lutq__a": tree.a}
        if tree.sid is not None:
            node["__lutq__sid"] = tree.sid
        if tree.act is not None:
            node["__lutq__act"] = tree.act
        out += _flatten(node, prefix)
    elif tree is None:
        out.append((prefix.rstrip("/") + "@none", None))
    else:
        out.append((prefix.rstrip("/"), tree))
    return out


def _unflatten(items: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, val in items.items():
        if key.endswith("@none"):
            key, val = key[: -len("@none")], None
        parts = key.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if isinstance(node, dict):
            if "__lutq__w" in node:
                return LutqState(w=node["__lutq__w"], d=node["__lutq__d"],
                                 a=node["__lutq__a"],
                                 sid=node.get("__lutq__sid"),
                                 act=node.get("__lutq__act"))
            return {k: rebuild(v) for k, v in node.items()}
        return node

    return rebuild(tree)


def save(tree, directory: str, step: int, *, keep_n: int = 3,
         policy: Optional[QuantPolicy] = None, mesh=None,
         tuning=None) -> str:
    """Synchronous checkpoint write. Returns the final path.

    ``policy``: the QuantPolicy governing any LutqState leaves; stored
    in the manifest so a restore can rebuild the exact per-leaf spec
    mapping (see :func:`load_policy`).

    ``mesh``: the device mesh the tree was sharded under when saved;
    recorded in the manifest (axis names + sizes) so a restore job can
    tell whether it is re-sharding onto a different topology (elastic
    restore) or resuming in place. See :func:`load_mesh`.

    ``tuning``: a ``kernels.autotune.TuningCache`` (or its json dict);
    stored in the manifest so a tuned deployment restores its kernel
    tile choices with the weights and never re-searches. See
    :func:`load_tuning`.
    """
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step:08d}.tmp"
    final = d / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    items = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    if policy is not None:
        manifest["quant_policy"] = policy.to_json_dict()
    if tuning is not None and len(tuning):
        manifest["tuning_cache"] = (tuning if isinstance(tuning, dict)
                                    else tuning.to_json_dict())
    if mesh is not None:
        manifest["mesh"] = {
            "axes": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        }
    for i, (key, val) in enumerate(items):
        entry = {"key": key, "file": None}
        if val is not None:
            arr = np.asarray(jax.device_get(val))
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            entry.update(file=fname, shape=list(arr.shape), dtype=str(arr.dtype))
        manifest["leaves"].append(entry)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(d, keep_n)
    return str(final)


def _gc(d: Path, keep_n: int):
    steps = sorted(p for p in d.glob("step_????????") if p.is_dir())
    for p in steps[:-keep_n] if keep_n > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write on a background thread."""

    def __init__(self, directory: str, keep_n: int = 3,
                 policy: Optional[QuantPolicy] = None, mesh=None,
                 tuning=None):
        self.directory = directory
        self.keep_n = keep_n
        self.policy = policy
        self.mesh = mesh
        self.tuning = tuning
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, tree, step: int):
        self.wait()  # one outstanding write at a time
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)) if x is not None else None,
            tree, is_leaf=lambda x: x is None)
        # snapshot now: the cache may mutate while the writer runs
        tuning = (self.tuning.to_json_dict()
                  if self.tuning is not None and not isinstance(self.tuning,
                                                                dict)
                  else self.tuning)

        def _write():
            self.last_path = save(host_tree, self.directory, step,
                                  keep_n=self.keep_n, policy=self.policy,
                                  mesh=self.mesh, tuning=tuning)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(p.name for p in d.glob("step_????????") if p.is_dir()
                   and (p / "manifest.json").exists())
    return int(steps[-1].split("_")[1]) if steps else None


def _manifest(directory: str, step: Optional[int]) -> Tuple[Path, Dict, int]:
    """(step dir, parsed manifest, resolved step) for a checkpoint."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = Path(directory) / f"step_{step:08d}"
    return d, json.loads((d / "manifest.json").read_text()), step


def load_policy(directory: str, step: Optional[int] = None
                ) -> Optional[QuantPolicy]:
    """QuantPolicy stored with a checkpoint, or None (fp / legacy)."""
    _, manifest, _ = _manifest(directory, step)
    pol = manifest.get("quant_policy")
    return None if pol is None else QuantPolicy.from_json_dict(pol)


def load_mesh(directory: str, step: Optional[int] = None) -> Optional[Dict]:
    """Mesh record ({"axes", "shape"}) stored with a checkpoint, or None
    (unsharded / legacy save)."""
    return _manifest(directory, step)[1].get("mesh")


def load_tuning(directory: str, step: Optional[int] = None):
    """TuningCache stored with a checkpoint, or None (untuned / legacy).

    Returns a ``kernels.autotune.TuningCache``; callers typically merge
    it into the process cache:
    ``ops.tuning_cache().update(load_tuning(dir))``.
    """
    tc = _manifest(directory, step)[1].get("tuning_cache")
    if tc is None:
        return None
    from repro.kernels.autotune import TuningCache

    return TuningCache.from_json_dict(tc)


def prune_shardings(directory: str, shardings, step: Optional[int] = None):
    """Restrict a shardings tree to the leaves a checkpoint actually
    stores.

    Elastic resume may carry shardings for state the checkpoint
    predates — e.g. error-feedback residuals after turning
    ``--grad-compress`` on mid-run. :func:`restore`'s strict
    structure check would reject those keys; pruning them lets the
    stored leaves land sharded while the new leaves keep their live
    value through the caller's graft (``TrainLoop.maybe_resume``).
    """
    _, manifest, _ = _manifest(directory, step)
    stored = {e["key"] for e in manifest["leaves"]}
    items = {k: (s if (k in stored or f"{k.removesuffix('@none')}@none"
                       in stored) else None)
             for k, s in _flatten(shardings)}
    return _unflatten(items)


def restore(directory: str, step: Optional[int] = None, *, shardings=None):
    """Load a checkpoint; place onto `shardings` (a matching tree of
    jax.sharding.Sharding or None) if given — this is the elastic-restore
    path: the stored global arrays land on whatever mesh the new job
    runs with, which may differ from the mesh recorded at save time.

    Leaves with a sharding are memory-mapped and ``device_put`` straight
    onto their NamedSharding: the file is never copied into a full host
    ndarray first, so restore peaks at (device bytes + mmap pages)
    instead of the 2x host-then-device spike on big configs. Unsharded
    leaves load eagerly as before.
    """
    d, manifest, step = _manifest(directory, step)
    sh_items = dict(_flatten(shardings)) if shardings is not None else {}
    if sh_items:
        # a sharding tree that doesn't line up with the stored tree would
        # silently fall back to eager unsharded loads — fail loudly instead
        # (a sharding for a leaf stored as None — e.g. a serve-form
        # LutqState master — has no data to place and is fine)
        stored = {e["key"] for e in manifest["leaves"]}
        unmatched = sorted(k for k, s in sh_items.items()
                           if s is not None and k not in stored
                           and f"{k}@none" not in stored)
        if unmatched:
            raise ValueError(
                f"shardings tree does not match checkpoint structure: "
                f"{len(unmatched)} sharding keys absent from the manifest "
                f"(e.g. {unmatched[:3]})")
    items = {}
    for entry in manifest["leaves"]:
        if entry["file"] is None:
            items[entry["key"]] = None
            continue
        sharding = sh_items.get(entry["key"])
        if sharding is not None:
            arr = np.load(d / entry["file"], mmap_mode="r")
            items[entry["key"]] = jax.device_put(arr, sharding)
        else:
            items[entry["key"]] = np.load(d / entry["file"])
    return _unflatten(items), manifest["step"]


def load(directory: str, step: Optional[int] = None, *, shardings=None):
    """Alias of :func:`restore` (sharded direct-to-device placement)."""
    return restore(directory, step, shardings=shardings)


def restore_params(directory: str, step: Optional[int] = None):
    """Restore only the {"trainable", "static"} subtrees of a train
    checkpoint — what serving needs. Optimizer moments and EF residuals
    (the bulk of the state) are never read from disk; the returned
    leaves are memory-mapped, so the caller pays pages for the params it
    touches instead of an eager full-state host copy. Returns
    ``({"trainable", "static"}, step)``.
    """
    d, manifest, step = _manifest(directory, step)
    items = {}
    for entry in manifest["leaves"]:
        key = entry["key"]
        if not key.startswith(("trainable/", "static/")):
            continue
        items[key] = (None if entry["file"] is None
                      else np.load(d / entry["file"], mmap_mode="r"))
    return _unflatten(items), step
