"""Shape-keyed autotuner for the Pallas LUT-Q kernels.

The compiled-mode perf story of this repo rests on tile choice: the
fused/packed kernels stream the assignment matrix HBM->VMEM in
(bk x bn) blocks and decode in front of the MXU, so the right bm/bn/bk
(and the dictionary-placement *strategy* — MXU-friendly one-hot matmul
vs the Mosaic gather ``d[a]``) is a per-shape, per-platform property.
This module searches a pruned candidate grid per

    (kernel, M, N, Kin, K, dtype, backend, platform)

key, times each candidate with proper warmup + ``block_until_ready``,
and records the winner in a :class:`TuningCache` that JSON-persists —
``kernels.ops`` holds the process-level instance that ``lutq_dot``
consults at trace time, ``serve_view(with_manifest=True)`` and the
checkpoint manifest carry it, so a tuned deployment never re-searches.

Candidate grids
---------------
* **Real TPU** (``platform == "tpu"``): MXU-aligned tiles — bm in
  sublane multiples, bn/bk in lane multiples — pruned by a VMEM budget
  (x block + assignment block + f32 accumulator + the one-hot decode
  temporary must fit comfortably in ~16 MB).
* **Interpret mode** (CPU/GPU emulation, this container): a tiny grid
  that varies only bm/bn and the decode strategy while pinning the k
  extent to the whole (padded) reduction axis. Splitting k changes the
  f32 accumulation grouping; keeping it whole matches the default-tile
  reduction order exactly, so every interpret-mode candidate is
  **bit-identical** to the default tiles (asserted by
  tests/test_autotune.py) and the tuner can never change serving
  numerics under CI.

Candidates are generated in deterministic sorted order and the timing
loop takes a strict improvement to switch winners, so repeated searches
on the same machine are stable; ``tune(measure=...)`` injects the
timing function for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import json
import statistics
import time
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

#: kernel component of a cache key per backend
KERNEL_OF_BACKEND = {"fused": "matmul", "packed4": "gemv_packed",
                     "pow2": "shift"}

#: decode strategies the kernels implement (dictionary placement)
STRATEGIES = ("onehot", "gather")

#: paged-attention decode dispatch choices: the Pallas block-table
#: kernel vs the gather+decode_attention oracle. Both are bit-identical
#: by contract, so this knob is always safe to tune.
PAGED_STRATEGIES = ("kernel", "gather")

_VMEM_BUDGET = 12 * 2**20  # leave headroom under the ~16 MiB/core VMEM


def platform() -> str:
    """The backend JAX actually dispatches to ("cpu" | "tpu" | "gpu").

    Recorded in every cache key and BENCH JSON so interpret-mode numbers
    can never masquerade as real-hardware ones.
    """
    return jax.default_backend()


def default_interpret() -> bool:
    """Pallas interpret mode default: emulate everywhere but real TPU."""
    return platform() != "tpu"


def platform_key(interpret: bool) -> str:
    """Platform component of a cache key.

    Interpret-mode timings are meaningless for real-TPU tile choice, so
    forcing interpret on a TPU host keys as ``"interpret"`` rather than
    polluting the ``"tpu"`` namespace.
    """
    p = platform()
    return "interpret" if (interpret and p == "tpu") else p


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One tuned kernel configuration.

    ``bm``/``bn``/``bk`` are tile *limits* (``lutq_dot`` clamps them to
    the padded operand dims, so ``bk >= Kin`` means "one k step").
    ``strategy`` picks the dictionary placement: ``"onehot"`` decodes
    via an (idx == iota) @ d matmul, ``"gather"`` via ``jnp.take``.
    """

    bm: int
    bn: int
    bk: int
    strategy: str = "onehot"

    def to_json_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json_dict(cls, d: Dict) -> "TileConfig":
        return cls(bm=int(d["bm"]), bn=int(d["bn"]), bk=int(d["bk"]),
                   strategy=str(d.get("strategy", "onehot")))


def make_key(kernel: str, M: int, N: int, Kin: int, K: int, dtype,
             backend: str, plat: Optional[str] = None) -> str:
    """Canonical cache key: every field that changes the optimal tile."""
    plat = platform() if plat is None else plat
    return (f"{kernel}|M{int(M)}|N{int(N)}|Kin{int(Kin)}|K{int(K)}"
            f"|{jnp.dtype(dtype).name}|{backend}|{plat}")


class TuningCache:
    """Process-level {key -> TileConfig} map with JSON persistence.

    ``version`` increments on every mutation; ``kernels.ops`` feeds it
    into the lru keys of the serving jits (``decode_fn`` etc.), so a
    tuned tile landing after a trace was cached forces a re-trace
    instead of silently serving stale tiles.
    """

    def __init__(self, entries: Optional[Dict[str, TileConfig]] = None):
        self._entries: Dict[str, TileConfig] = dict(entries or {})
        self.version = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: str) -> Optional[TileConfig]:
        return self._entries.get(key)

    def put(self, key: str, tile: TileConfig) -> None:
        self._entries[key] = tile
        self.version += 1

    def update(self, other: "TuningCache | Dict[str, TileConfig]") -> None:
        items = other._entries if isinstance(other, TuningCache) else other
        self._entries.update(items)
        self.version += 1

    def clear(self) -> None:
        self._entries.clear()
        self.version += 1

    def items(self):
        return sorted(self._entries.items())

    def to_json_dict(self) -> Dict[str, Dict]:
        return {k: t.to_json_dict() for k, t in self.items()}

    @classmethod
    def from_json_dict(cls, d: Dict[str, Dict]) -> "TuningCache":
        return cls({k: TileConfig.from_json_dict(v) for k, v in d.items()})

    def save(self, path) -> str:
        Path(path).write_text(json.dumps(self.to_json_dict(), indent=1,
                                         sort_keys=True))
        return str(path)

    @classmethod
    def load(cls, path) -> "TuningCache":
        return cls.from_json_dict(json.loads(Path(path).read_text()))


def paged_attn_key(page: int, pages_per_row: int, hkv: int, dh: int,
                   kv_dtype, *, interpret: Optional[bool] = None) -> str:
    """Cache key for the paged-attention decode dispatch.

    Reuses ``make_key``'s field layout so one cache file carries both
    matmul tiles and attention entries: M=page, N=pages_per_row (NB),
    Kin=Hkv, K=dh, dtype=the pool dtype (int8 vs fp distinguishes the
    dequant variant), platform via ``platform_key`` (an interpret-forced
    TPU host never pollutes the "tpu" namespace).
    """
    interpret = default_interpret() if interpret is None else interpret
    return make_key("paged_attn", page, pages_per_row, hkv, dh, kv_dtype,
                    "paged", platform_key(interpret))


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _vmem_bytes(bm: int, bn: int, bk: int, K: int, strategy: str,
                packed: bool) -> int:
    """Rough per-step VMEM footprint of one fused-kernel grid cell."""
    a_bytes = (bk // 2) * bn if packed else bk * bn
    x_bytes = bm * bk * 4
    acc_bytes = bm * bn * 4
    w_bytes = bk * bn * 4  # decoded tile
    onehot = bk * bn * K * 4 if strategy == "onehot" else 0
    return x_bytes + a_bytes + acc_bytes + w_bytes + onehot + K * 4


def candidates(kernel: str, M: int, N: int, Kin: int, K: int, *,
               interpret: Optional[bool] = None) -> List[TileConfig]:
    """Deterministic pruned candidate grid for one shape.

    Interpret mode pins ``bk`` to the full reduction extent (single k
    step == default reduction grouping == bit-identical outputs) and
    varies bm/bn/strategy only. Real TPU searches MXU-aligned bk too,
    pruned by the VMEM budget.
    """
    interpret = default_interpret() if interpret is None else interpret
    if kernel == "paged_attn":
        # no tile grid: the kernel's blocking IS the page geometry. The
        # only knob is which bit-identical dispatch wins the byte race;
        # bm/bn/bk echo the keyed geometry for JSON readability.
        return [TileConfig(bm=M, bn=N, bk=K, strategy=s)
                for s in PAGED_STRATEGIES]
    packed = kernel == "gemv_packed"
    out: List[TileConfig] = []
    if interpret:
        bk = max(512, _round_up(Kin, 2))
        bms = sorted({b for b in (8, 32, 256) if b <= _round_up(M, 8)} | {256})
        if packed:
            bms = [256]  # the gemv keeps x whole; bm is unused
        bns = sorted({b for b in (32, 128, 256) if b <= _round_up(N, 8)}
                     | {256})
        for bm in bms:
            for bn in bns:
                for strat in STRATEGIES:
                    out.append(TileConfig(bm=bm, bn=bn, bk=bk, strategy=strat))
    else:
        bms = ([256] if packed else
               [b for b in (8, 64, 128, 256, 512)
                if b <= _round_up(M, 8)] or [8])
        bns = [b for b in (128, 256, 512) if b <= _round_up(N, 128)] or [128]
        bks = [b for b in (256, 512, 1024, 2048)
               if b <= _round_up(Kin, 256)] or [256]
        for bm in bms:
            for bn in bns:
                for bk in bks:
                    if packed and bk % 2:
                        continue
                    for strat in STRATEGIES:
                        if _vmem_bytes(bm, bn, bk, K, strat,
                                       packed) > _VMEM_BUDGET:
                            continue
                        out.append(TileConfig(bm=bm, bn=bn, bk=bk,
                                              strategy=strat))
    # deterministic order -> stable winner under timing ties
    return sorted(out, key=lambda t: (t.bm, t.bn, t.bk, t.strategy))


def measure_call(fn: Callable, *args, reps: int = 3, warmup: int = 2) -> float:
    """Median wall-clock us of ``fn(*args)`` with the compile + warmup
    calls excluded and every rep fenced by ``block_until_ready``."""
    try:
        jax.block_until_ready(fn(*args))  # compile
        for _ in range(max(warmup - 1, 0)):
            jax.block_until_ready(fn(*args))
    except Exception:  # infeasible candidate (e.g. Mosaic layout reject)
        return float("inf")
    ts = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


def _operands(kernel: str, M: int, N: int, Kin: int, K: int, dtype, seed: int):
    from repro.core.lutq import LutqState, pow2_encode
    from repro.kernels.ref import pack4_kin

    key = jax.random.PRNGKey(seed)
    kx, ka, kd = jax.random.split(key, 3)
    # the shift kernel consumes int8-quantized activations internally;
    # its probe x stays f32 (lutq_dot quantizes at the boundary)
    xdt = jnp.float32 if kernel == "shift" else dtype
    x = jax.random.normal(kx, (M, Kin), jnp.float32).astype(xdt)
    a = jax.random.randint(ka, (Kin, N), 0, K, jnp.int8)
    d = jnp.sort(jax.random.normal(kd, (K,), jnp.float32))
    if kernel == "gemv_packed":
        a = pack4_kin(a)
    if kernel == "shift":
        d = pow2_encode(d)  # int8 sign+exponent plane
    return x, LutqState(w=None, d=d, a=a)


def tune(kernel: str, *, M: int, N: int, Kin: int, K: int,
         dtype=jnp.float32, backend: Optional[str] = None,
         interpret: Optional[bool] = None, reps: int = 3, warmup: int = 2,
         seed: int = 0, cache: Optional[TuningCache] = None,
         measure: Optional[Callable[[TileConfig], float]] = None,
         ) -> Tuple[str, TileConfig, Dict[str, float]]:
    """Search the candidate grid for one shape; returns
    ``(key, best_tile, {repr(tile): us})`` and records the winner in
    ``cache`` when given.

    ``measure`` (tests): maps a TileConfig to a time, replacing the real
    benchmark loop. The winner is the first strict minimum in candidate
    order, so equal-time candidates resolve deterministically.
    """
    import functools

    from repro.kernels import ops

    backend = backend or {"gemv_packed": "packed4",
                          "shift": "pow2"}.get(kernel, "fused")
    interpret = default_interpret() if interpret is None else interpret
    if kernel == "shift":
        # the shift kernel's hot operand is the int8 quantized x; key on
        # int8 regardless of the model compute dtype so trace-time
        # lookups (_tuned_tile("pow2", ...)) always hit
        dtype = jnp.int8
    key = make_key(kernel, M, N, Kin, K, dtype, backend,
                   platform_key(interpret))
    if measure is None:
        x, state = _operands(kernel, M, N, Kin, K, dtype, seed)

        def measure(tile: TileConfig) -> float:
            fn = jax.jit(functools.partial(
                ops.lutq_dot, backend=backend, bm=tile.bm, bn=tile.bn,
                bk=tile.bk, strategy=tile.strategy, interpret=interpret))
            return measure_call(fn, x, state, reps=reps, warmup=warmup)

    best: Optional[TileConfig] = None
    best_us = float("inf")
    timings: Dict[str, float] = {}
    for tile in candidates(kernel, M, N, Kin, K, interpret=interpret):
        us = measure(tile)
        timings[f"{tile.bm}x{tile.bn}x{tile.bk}/{tile.strategy}"] = us
        if us < best_us:
            best, best_us = tile, us
    if best is None:  # every candidate infeasible: keep defaults
        best = TileConfig(bm=256, bn=256, bk=512)
    if cache is not None:
        cache.put(key, best)
    return key, best, timings


def leaf_shapes_for_tree(params, *, batch_m: int = 8,
                         transpose_batch_m: Optional[int] = None,
                         ) -> List[Dict]:
    """Distinct fused/packed kernel shapes a serve tree will dispatch.

    Walks the tree like ``backend_manifest`` does, resolves each leaf's
    backend per-slice (what the kernels actually see after scan/vmap
    slicing), and emits one record per distinct
    ``(kernel, M, N, Kin, K)``: the decode-time matmul shape with
    ``M = batch_m`` (the engine's decode batch), plus the transposed
    orientation for tied-logits leaves.
    """
    from repro.core.lutq import LutqState
    from repro.kernels.ops import resolve_backend
    from repro.nn.tree import tree_paths

    seen: Dict[Tuple, Dict] = {}
    for path, leaf in tree_paths(params):
        if not isinstance(leaf, LutqState) or leaf.w is not None:
            continue
        be = resolve_backend(leaf, "auto", sliced=True)
        if be == "decode":
            continue
        K = int(leaf.d.shape[-1])
        nstack = leaf.d.ndim - 1
        a_shape = leaf.a.shape[nstack:]
        Kin, N = int(a_shape[0]), int(a_shape[1])
        if leaf.a.dtype == jnp.uint8:
            Kin *= 2
        kernel = KERNEL_OF_BACKEND[be]
        rec_key = (kernel, batch_m, N, Kin, K)
        seen.setdefault(rec_key, {"kernel": kernel, "backend": be,
                                  "M": batch_m, "N": N, "Kin": Kin, "K": K,
                                  "paths": []})["paths"].append("/".join(path))
        if be in ("fused", "pow2") and path and path[-1] == "table":
            # tied-logits orientation: x @ d[A].T swaps Kin/N
            tm = batch_m if transpose_batch_m is None else transpose_batch_m
            tkey = (kernel, tm, Kin, N, K)
            seen.setdefault(tkey, {"kernel": kernel, "backend": be,
                                   "M": tm, "N": Kin, "Kin": N, "K": K,
                                   "paths": []})["paths"].append(
                                       "/".join(path) + ".T")
    return [seen[k] for k in sorted(seen)]


def tune_tree(params, *, batch_m: int = 8, dtype=jnp.float32,
              cache: Optional[TuningCache] = None,
              reps: int = 3, warmup: int = 2,
              emit: Optional[Callable[[str], None]] = None) -> TuningCache:
    """Autotune every distinct kernel shape of a serve tree.

    ``dtype`` is the *activation* dtype the model computes in (part of
    every cache key — bf16 and f32 tiles tune separately). Returns the
    cache (the given one, or a fresh TuningCache) with one entry per
    shape; ``emit`` receives a per-shape report line.
    """
    cache = TuningCache() if cache is None else cache
    for rec in leaf_shapes_for_tree(params, batch_m=batch_m):
        key, tile, _ = tune(rec["kernel"], M=rec["M"], N=rec["N"],
                            Kin=rec["Kin"], K=rec["K"], dtype=dtype,
                            backend=rec["backend"],
                            reps=reps, warmup=warmup, cache=cache)
        if emit is not None:
            emit(f"[autotune] {rec['kernel']:12s} M={rec['M']:<5d} "
                 f"N={rec['N']:<6d} Kin={rec['Kin']:<6d} K={rec['K']:<4d} -> "
                 f"bm={tile.bm} bn={tile.bn} bk={tile.bk} "
                 f"{tile.strategy} ({len(rec['paths'])} leaves)")
    return cache
