"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lutq_matmul_ref(x: jax.Array, a: jax.Array, d: jax.Array) -> jax.Array:
    """y = x @ d[a]. x: (M, Kin) f32/bf16; a: (Kin, N) int8; d: (K,)."""
    w = jnp.take(d, a.astype(jnp.int32), axis=0).astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def pack4(a: jax.Array) -> jax.Array:
    """Pack two 4-bit indices per int8 byte along axis 0 (row pairs)."""
    assert a.shape[0] % 2 == 0
    lo = a[0::2].astype(jnp.uint8)
    hi = a[1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4(p: jax.Array) -> jax.Array:
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=1)  # (Kin/2, 2, N)
    return out.reshape(p.shape[0] * 2, *p.shape[1:])


def pack4_kin(a: jax.Array) -> jax.Array:
    """Pack two 4-bit indices per byte along axis -2.

    For a linear assignment matrix (Kin, N) — possibly with leading
    stack axes (layers, experts) — axis -2 is the matmul *reduction*
    axis, which is exactly the layout ``lutq_gemv_packed`` streams
    (packed rows (Kin/2, N), even index in the low nibble). The
    serve-time convention: uint8 dtype == packed, int8 == raw indices.
    """
    assert a.shape[-2] % 2 == 0, a.shape
    lo = a[..., 0::2, :].astype(jnp.uint8) & 0xF
    hi = a[..., 1::2, :].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack4_kin(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack4_kin`: uint8 pairs -> int8 indices."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=-2)  # (..., Kin/2, 2, N)
    return out.reshape(*p.shape[:-2], p.shape[-2] * 2, p.shape[-1])


def lutq_gemv_packed_ref(x: jax.Array, packed: jax.Array, d: jax.Array) -> jax.Array:
    """y = x @ d[unpack(packed)]. x: (B, Kin); packed: (Kin/2, N) uint8."""
    a = unpack4(packed)
    w = jnp.take(d, a.astype(jnp.int32), axis=0).astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def kmeans_stats_ref(w: jax.Array, d: jax.Array):
    """One assignment pass over flat w vs sorted d.

    Returns (assignments int8 (N,), sums (K,) f32, counts (K,) f32).
    """
    mid = (d[:-1] + d[1:]) * 0.5
    a = jnp.searchsorted(mid, w.astype(d.dtype), side="left")
    K = d.shape[0]
    onehot = jax.nn.one_hot(a, K, dtype=jnp.float32)
    return (a.astype(jnp.int8), onehot.T @ w.astype(jnp.float32),
            onehot.sum(axis=0))
