"""Pure-jnp oracles for every Pallas kernel (the correctness contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lutq_matmul_ref(x: jax.Array, a: jax.Array, d: jax.Array) -> jax.Array:
    """y = x @ d[a]. x: (M, Kin) f32/bf16; a: (Kin, N) int8; d: (K,)."""
    w = jnp.take(d, a.astype(jnp.int32), axis=0).astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def pack4(a: jax.Array) -> jax.Array:
    """Pack two 4-bit indices per int8 byte along axis 0 (row pairs)."""
    assert a.shape[0] % 2 == 0
    lo = a[0::2].astype(jnp.uint8)
    hi = a[1::2].astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack4(p: jax.Array) -> jax.Array:
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=1)  # (Kin/2, 2, N)
    return out.reshape(p.shape[0] * 2, *p.shape[1:])


def pack4_kin(a: jax.Array) -> jax.Array:
    """Pack two 4-bit indices per byte along axis -2.

    For a linear assignment matrix (Kin, N) — possibly with leading
    stack axes (layers, experts) — axis -2 is the matmul *reduction*
    axis, which is exactly the layout ``lutq_gemv_packed`` streams
    (packed rows (Kin/2, N), even index in the low nibble). The
    serve-time convention: uint8 dtype == packed, int8 == raw indices.
    """
    assert a.shape[-2] % 2 == 0, a.shape
    lo = a[..., 0::2, :].astype(jnp.uint8) & 0xF
    hi = a[..., 1::2, :].astype(jnp.uint8) & 0xF
    return lo | (hi << 4)


def unpack4_kin(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack4_kin`: uint8 pairs -> int8 indices."""
    lo = (p & 0xF).astype(jnp.int8)
    hi = ((p >> 4) & 0xF).astype(jnp.int8)
    out = jnp.stack([lo, hi], axis=-2)  # (..., Kin/2, 2, N)
    return out.reshape(*p.shape[:-2], p.shape[-2] * 2, p.shape[-1])


def lutq_gemv_packed_ref(x: jax.Array, packed: jax.Array, d: jax.Array) -> jax.Array:
    """y = x @ d[unpack(packed)]. x: (B, Kin); packed: (Kin/2, N) uint8."""
    a = unpack4(packed)
    w = jnp.take(d, a.astype(jnp.int32), axis=0).astype(x.dtype)
    return (x @ w).astype(jnp.float32)


def pow2_shift_weights(code: jax.Array) -> jax.Array:
    """Shifted-integer dictionary for the shift-add path.

    ``code`` is an int8 sign+exponent plane (``core.lutq.pow2_encode``)
    of shape (..., K). Returns int32 ``sign * (1 << (|code| - minm))``
    per entry (0 stays 0), where ``minm`` is the smallest nonzero
    magnitude over the last axis — i.e. every dictionary entry becomes
    an integer left-shift relative to the smallest exponent. The
    matching epilogue scale is ``2^(minm - 1 + POW2_MIN_EXP)``
    (:func:`pow2_shift_scale`). O(K) work: this is where the
    "exponent-add / bit-shift" of the LUT happens — the kernel then only
    streams int8 assignments and integer-accumulates.
    """
    mag = jnp.abs(code.astype(jnp.int32))
    big = jnp.where(mag > 0, mag, jnp.iinfo(jnp.int32).max)
    minm = jnp.where(jnp.any(mag > 0, axis=-1, keepdims=True),
                     jnp.min(big, axis=-1, keepdims=True), 1)
    shift = jnp.where(mag > 0, mag - minm, 0)
    return jnp.sign(code.astype(jnp.int32)) * (1 << shift)


def pow2_shift_scale(code: jax.Array) -> jax.Array:
    """f32 epilogue scale matching :func:`pow2_shift_weights`.

    ``scale = 2^(minm - 1 + POW2_MIN_EXP)`` per stack slice (shape
    ``code.shape[:-1]``) — the single fp multiply of the whole matmul,
    applied at O(M·N) to the int32 accumulator.
    """
    from repro.core.lutq import POW2_MIN_EXP

    mag = jnp.abs(code.astype(jnp.int32))
    big = jnp.where(mag > 0, mag, jnp.iinfo(jnp.int32).max)
    minm = jnp.where(jnp.any(mag > 0, axis=-1),
                     jnp.min(big, axis=-1), 1)
    return jnp.exp2((minm - 1 + POW2_MIN_EXP).astype(jnp.float32))


def lutq_shift_ref(xq: jax.Array, a: jax.Array, wsh: jax.Array) -> jax.Array:
    """Integer decode-oracle for the shift-add kernel.

    xq: (M, Kin) int8 quantized activations; a: (Kin, N) int8
    assignments; wsh: (K,) int32 shifted-integer dictionary
    (:func:`pow2_shift_weights`). Returns the exact int32 accumulator
    ``xq @ wsh[a]`` — integer arithmetic, so bit-identical under any
    tiling/sharding order. The caller applies
    ``acc * (act_scale * pow2_shift_scale(code))`` as the fp epilogue.
    """
    w = jnp.take(wsh, a.astype(jnp.int32), axis=0)
    return jax.lax.dot_general(
        xq.astype(jnp.int32), w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def kmeans_stats_ref(w: jax.Array, d: jax.Array):
    """One assignment pass over flat w vs sorted d.

    Returns (assignments int8 (N,), sums (K,) f32, counts (K,) f32).
    """
    mid = (d[:-1] + d[1:]) * 0.5
    a = jnp.searchsorted(mid, w.astype(d.dtype), side="left")
    K = d.shape[0]
    onehot = jax.nn.one_hot(a, K, dtype=jnp.float32)
    return (a.astype(jnp.int8), onehot.T @ w.astype(jnp.float32),
            onehot.sum(axis=0))
