"""Causal flash attention Pallas TPU kernel with block skipping.

The pure-JAX chunked attention (nn/attention.py) computes every
(q-block, kv-block) pair and masks — 2x FLOP waste for causal training
(counted honestly in the roofline's useful-ratio). Here the grid is
(batch*kv_head, q_blocks, kv_blocks) with kv innermost; blocks strictly
above the diagonal are skipped with ``pl.when`` — on TPU the sequential
grid makes this a real branch, so causal attention does ~S^2/2 work.

GQA: queries arrive grouped as (B*Hkv, G*bq, d) so one kernel instance
serves all G query heads of its kv head — no KV replication.

Layout choices: bq/bk multiples of 128 keep the MXU fed; m/l statistics
live in SMEM-friendly (8,128)-padded f32 blocks via the output spec.
Validated in interpret mode against the dense oracle (tests), run on
real TPU via ops.flash_attention_tpu.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bq: int, bk: int, causal: bool):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip blocks strictly above the diagonal (true FLOP skip on TPU)
    run = (not causal) or (j * bk < (i + 1) * bq)

    @pl.when(run)
    def _compute():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            # row r of this q block is query position i*bq + r (groups are
            # handled by feeding per-group q blocks, see wrapper)
            q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[0] = acc_ref[0] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_tpu(
    q: jax.Array,  # (BH, S, D)  — batch*heads flattened, per-head queries
    k: jax.Array,  # (BH, Skv, D)
    v: jax.Array,  # (BH, Skv, D)
    *,
    causal: bool = True,
    scale=None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, D = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = D ** -0.5
    bq = min(bq, S)
    bk = min(bk, Skv)
    assert S % bq == 0 and Skv % bk == 0
    grid = (BH, S // bq, Skv // bk)

    out, _, _, _ = pl.pallas_call(
        functools.partial(_kernel, scale=float(scale), bq=bq, bk=bk,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), q.dtype),
            jax.ShapeDtypeStruct((BH, S, D), jnp.float32),  # acc scratch
            jax.ShapeDtypeStruct((BH, S, 1), jnp.float32),  # m scratch
            jax.ShapeDtypeStruct((BH, S, 1), jnp.float32),  # l scratch
        ],
        interpret=interpret,
    )(q, k, v)
    return out
