"""Pallas paged-attention decode kernel: walk the block table, not a gather.

The paged serving path (runtime/paged_kv.py) keeps the KV cache in
fixed-size physical pages behind per-slot block tables. Before this
kernel, every decode step gathered the whole table into a contiguous
``(B, NB*page, Hkv, dh)`` tensor — and for int8 KV dequantized *all* of
it to bf16 — per layer, per step. At decode batch sizes the KV stream
dominates the byte traffic, so that materialization was pure waste:
``NB*page`` positions read regardless of how many are live.

This kernel walks the block table directly. The grid is
``(B, Hkv, NB)`` with the page dimension innermost; the block table and
``cache_len`` ride as scalar-prefetch operands so each step's BlockSpec
index_map can resolve ``logical page j of row b`` to a physical page
and DMA exactly that ``(page, dh)`` tile per (batch, kv-head). Pages
with no live positions — beyond ``cache_len``, or wholly behind the
sliding window — are redirected to the trash page (physical page 0) in
the index_map, so consecutive dead steps re-request the same block and
the pipeline never streams them from HBM. int8 pages dequantize
in-kernel from the per-token scale planes, page by page, into a
VMEM-resident scratch; the full dequantized cache never exists.

Numerics contract: **bit-identical to the gather oracle**
(``ops.paged_attention(..., backend="gather")``, i.e. ``gather_pages``
+ ``nn.attention.decode_attention``) on every platform the tests run.
Decode has a single query row, so instead of the multi-block
online-softmax rescaling of ``flash_attn.py`` (whose ``alpha``
reordering cannot reproduce a one-shot softmax bit-for-bit), the
finalize step replays ``decode_attention``'s exact op sequence — same
einsum structure (singleton batch dims included, so XLA picks the same
contraction lowering even at G=1), same ``NEG_INF`` masking of
positions ``>= cache_len`` and behind-window positions (which subsumes
trash-page columns: the engine zeroes released block entries), same
f32 softmax and f32 V accumulation. Masked columns contribute exactly
``exp(NEG_INF - m) = 0.0`` and dead pages are zero-filled in scratch,
so skipped pages are exact no-ops, not approximations.

VMEM note: the single-pass kernel's scratch holds one row's
dequantized K and V (``NB*page × dh`` each, per (batch, kv-head)
step). When that outgrows the VMEM budget (``vmem_budget_bytes``,
default 16 MiB), ``paged_attention_tpu`` switches to a **multi-pass
split** (``vmem_plan`` decides): phase A streams K page by page and
accumulates the f32 score row (``G × NB*page`` scratch — no K scratch
at all), masking + softmaxing in place on the last page; phase B
streams V in ``dh``-column chunks (``NB*page × dchunk`` scratch) and
emits the matching output columns with a full-length einsum per chunk.
Per-page score rows and per-chunk output columns are *independent
outputs* of the oracle's einsums — concatenation reproduces the
one-shot result bit-for-bit, unlike a chunked-K accumulation (whose
f32 partial sums would reorder the reduction). The multi-pass path
therefore keeps the same bitwise contract as the single-pass kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.nn.attention import NEG_INF

TRASH_PAGE = 0

#: default per-core VMEM budget for the decode kernel's scratch (bytes).
DEFAULT_VMEM_BUDGET = 16 * 2**20


def vmem_plan(nb: int, page: int, dh: int, g: int, *, quant: bool,
              kv_itemsize: int, budget_bytes: Optional[int] = None) -> dict:
    """Pick the kernel's scratch layout for one (batch, kv-head) row.

    Single-pass scratch is ``2 * nb*page*dh`` entries (dequantized K and
    V; bf16 under int8 quant, else the pool dtype). When that exceeds
    ``budget_bytes`` the plan switches to the multi-pass split: an f32
    score row (``g × nb*page``) plus a V chunk (``nb*page × dchunk``),
    with ``dchunk`` the largest divisor of ``dh`` that fits. The chunk
    never drops below 2 columns: a width-1 output einsum lowers to a
    differently-ordered reduction (~1-ulp drift against the oracle), so
    the plan streams at the smallest >= 2 divisor even when that
    overshoots the budget (best effort rather than refusal).

    Pure host arithmetic so tests can probe the decision without
    running the kernel."""
    budget = DEFAULT_VMEM_BUDGET if budget_bytes is None else int(budget_bytes)
    scr_item = 2 if quant else kv_itemsize
    single = 2 * nb * page * dh * scr_item
    if single <= budget:
        return {"multipass": False, "dchunk": dh, "nd": 1,
                "single_bytes": single, "multi_bytes": single}
    score_bytes = 4 * g * nb * page
    divisors = [dc for dc in range(2, dh + 1) if dh % dc == 0] or [dh]
    dchunk = divisors[0]  # best effort: smallest bit-stable chunk
    for dc in reversed(divisors):
        if score_bytes + nb * page * dc * scr_item <= budget:
            dchunk = dc
            break
    return {"multipass": True, "dchunk": dchunk, "nd": dh // dchunk,
            "single_bytes": single,
            "multi_bytes": score_bytes + nb * page * dchunk * scr_item}


def _page_live(start: jax.Array, page: int, cl: jax.Array,
               window: Optional[int]) -> jax.Array:
    """Does logical page [start, start+page) hold any attended position?"""
    live = start < cl
    if window is not None:
        live = jnp.logical_and(live, start + page > cl - window)
    return live


def _decode_kernel(blk_ref, cl_ref, q_ref, k_ref, v_ref, *rest, page, nb,
                   window, scale, quant):
    if quant:
        ks_ref, vs_ref, o_ref, k_scr, v_scr = rest
    else:
        o_ref, k_scr, v_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    cl = cl_ref[b]
    start = j * page
    live = _page_live(start, page, cl, window)

    @pl.when(live)
    def _copy_page():
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        if quant:
            # mirror the oracle's dequant exactly: int8 -> bf16, scaled by
            # the bf16 per-token plane (promotion to f32 happens inside
            # the score einsum, as it does outside the kernel)
            k = k.astype(jnp.bfloat16) * ks_ref[0, :, 0][:, None]
            v = v.astype(jnp.bfloat16) * vs_ref[0, :, 0][:, None]
        k_scr[pl.dslice(start, page), :] = k.astype(k_scr.dtype)
        v_scr[pl.dslice(start, page), :] = v.astype(v_scr.dtype)

    @pl.when(jnp.logical_not(live))
    def _zero_page():
        # dead pages must be *finite* in scratch: their softmax weight is
        # exactly 0.0 and 0.0 * finite == 0.0 matches the oracle's masked
        # gather contribution bit-for-bit (0.0 * NaN would not)
        z = jnp.zeros((page, k_scr.shape[1]), k_scr.dtype)
        k_scr[pl.dslice(start, page), :] = z
        v_scr[pl.dslice(start, page), :] = z

    @pl.when(j == nb - 1)
    def _finalize():
        # decode_attention, replayed bit-for-bit on the scratch row: the
        # singleton (b, h) einsum batch dims keep XLA's contraction
        # lowering identical to the batched oracle even at G=1.
        q = (q_ref[0, 0] * scale)[None, None]            # (1, 1, G, dh)
        kc = k_scr[...][None, :, None]                   # (1, W, 1, dh)
        s = jnp.einsum("bhgd,bkhd->bhgk", q, kc).astype(jnp.float32)
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, nb * page), 1)[0]
        valid = pos < cl
        if window is not None:
            valid &= pos >= cl - window
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        vc = v_scr[...][None, :, None]                   # (1, W, 1, dh)
        o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.float32),
                       vc.astype(jnp.float32))
        o_ref[0, 0] = o[0, 0].astype(o_ref.dtype)


def _decode_kernel_multipass(blk_ref, cl_ref, q_ref, k_ref, v_ref, *rest,
                             page, nb, nd, dchunk, window, scale, quant):
    """Two-phase VMEM-bounded twin of ``_decode_kernel``.

    Grid step j: j < nb is phase A (stream K page j, write its score
    columns; mask + softmax the full row in place on the last page);
    j >= nb is phase B sub-pass ``(j - nb) // nb`` over dh-chunk
    columns (stream V page ``(j - nb) % nb``'s chunk; on the last page
    of a sub-pass, one full-length einsum emits the output chunk)."""
    if quant:
        ks_ref, vs_ref, o_ref, s_scr, v_scr = rest
    else:
        o_ref, s_scr, v_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    cl = cl_ref[b]
    W = nb * page

    # ---- phase A: scores -------------------------------------------------
    jA = j
    startA = jA * page
    liveA = jnp.logical_and(j < nb, _page_live(startA, page, cl, window))

    @pl.when(liveA)
    def _score_page():
        k = k_ref[0, :, 0, :]
        if quant:
            k = k.astype(jnp.bfloat16) * ks_ref[0, :, 0][:, None]
        # the oracle's score einsum restricted to this page's columns:
        # each column is an independent output of the contraction, so
        # the concatenated row is bit-identical to the one-shot einsum
        q = (q_ref[0, 0] * scale)[None, None]            # (1, 1, G, dh)
        kc = k[None, :, None]                            # (1, page, 1, dh)
        s = jnp.einsum("bhgd,bkhd->bhgk", q, kc).astype(jnp.float32)
        s_scr[:, pl.dslice(startA, page)] = s[0, 0]

    @pl.when(jnp.logical_and(j < nb, jnp.logical_not(liveA)))
    def _zero_score_page():
        # finite filler: these columns are NEG_INF-masked before softmax
        s_scr[:, pl.dslice(startA, page)] = jnp.zeros(
            (s_scr.shape[0], page), jnp.float32)

    @pl.when(j == nb - 1)
    def _softmax():
        s = s_scr[...][None, None]                       # (1, 1, G, W)
        pos = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)[0]
        valid = pos < cl
        if window is not None:
            valid &= pos >= cl - window
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        s_scr[...] = p[0, 0]                             # probs, in place

    # ---- phase B: output chunks ------------------------------------------
    t = jnp.maximum(j - nb, 0)
    sc = t // nb
    jp = t % nb
    startB = jp * page
    liveB = jnp.logical_and(j >= nb, _page_live(startB, page, cl, window))

    @pl.when(liveB)
    def _copy_v_chunk():
        v = v_ref[0, :, 0, :]                            # (page, dchunk)
        if quant:
            v = v.astype(jnp.bfloat16) * vs_ref[0, :, 0][:, None]
        v_scr[pl.dslice(startB, page), :] = v.astype(v_scr.dtype)

    @pl.when(jnp.logical_and(j >= nb, jnp.logical_not(liveB)))
    def _zero_v_chunk():
        v_scr[pl.dslice(startB, page), :] = jnp.zeros((page, dchunk),
                                                      v_scr.dtype)

    @pl.when(jnp.logical_and(j >= nb, jp == nb - 1))
    def _emit_chunk():
        # full-length output einsum over this chunk's dh columns — the
        # oracle's einsum restricted to independent output columns, so
        # no reduction is reordered (unlike chunking over K)
        p = s_scr[...][None, None]                       # (1, 1, G, W)
        vc = v_scr[...][None, :, None]                   # (1, W, 1, dchunk)
        o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.float32),
                       vc.astype(jnp.float32))
        o_ref[0, 0, :, pl.dslice(sc * dchunk, dchunk)] = (
            o[0, 0].astype(o_ref.dtype))


def paged_attention_tpu(
    q: jax.Array,
    k_pool: jax.Array,
    v_pool: jax.Array,
    block: jax.Array,
    cache_len: jax.Array,
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    interpret: bool = False,
    vmem_budget_bytes: Optional[int] = None,
) -> jax.Array:
    """One-token paged decode attention through the block table.

    q: (B, 1, H, dh); k_pool/v_pool: (P, page, Hkv, dh) physical pages
    (int8 when ``k_scale``/``v_scale`` planes (P, page, Hkv) are given);
    block: (B, NB) int32 block table; cache_len: (B,) or scalar int32.
    Returns (B, 1, H, dh) in q.dtype, bit-identical to
    ``decode_attention(q, gather_pages(...), ...)``.

    ``vmem_budget_bytes`` bounds per-row scratch (default
    ``DEFAULT_VMEM_BUDGET``); rows whose single-pass scratch outgrows it
    run the multi-pass split picked by :func:`vmem_plan` — same bitwise
    contract, thinner VMEM footprint.
    """
    B, _, H, dh = q.shape
    _, page, Hkv, _ = k_pool.shape
    NB = block.shape[1]
    G = H // Hkv
    if scale is None:
        scale = dh ** -0.5
    quant = k_scale is not None
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32).reshape(-1), (B,))
    qr = q.reshape(B, Hkv, G, dh)
    scr_dtype = jnp.bfloat16 if quant else k_pool.dtype
    plan = vmem_plan(NB, page, dh, G, quant=quant,
                     kv_itemsize=k_pool.dtype.itemsize,
                     budget_bytes=vmem_budget_bytes)
    if plan["multipass"]:
        return _paged_attention_multipass(
            qr, k_pool, v_pool, block, cl, page=page, nb=NB,
            dchunk=plan["dchunk"], nd=plan["nd"], window=window,
            scale=scale, k_scale=k_scale, v_scale=v_scale,
            scr_dtype=scr_dtype, interpret=interpret
        ).reshape(B, 1, H, dh)

    def page_map(b, h, j, blk, cln):
        live = _page_live(j * page, page, cln[b], window)
        return (jnp.where(live, blk[b, j], TRASH_PAGE), 0, h, 0)

    def scale_map(b, h, j, blk, cln):
        live = _page_live(j * page, page, cln[b], window)
        return (jnp.where(live, blk[b, j], TRASH_PAGE), 0, h)

    def head_map(b, h, j, blk, cln):
        return (b, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, G, dh), head_map),
        pl.BlockSpec((1, page, 1, dh), page_map),
        pl.BlockSpec((1, page, 1, dh), page_map),
    ]
    operands = [qr, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), scale_map),
                     pl.BlockSpec((1, page, 1), scale_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, NB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, dh), head_map),
        scratch_shapes=[pltpu.VMEM((NB * page, dh), scr_dtype),
                        pltpu.VMEM((NB * page, dh), scr_dtype)],
    )
    kernel = functools.partial(_decode_kernel, page=page, nb=NB,
                               window=window, scale=scale, quant=quant)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(block, cl, *operands)
    return out.reshape(B, 1, H, dh)


def _paged_attention_multipass(qr, k_pool, v_pool, block, cl, *, page, nb,
                               dchunk, nd, window, scale, k_scale, v_scale,
                               scr_dtype, interpret):
    """Grid/spec assembly for the multi-pass kernel.

    Grid (B, Hkv, nb*(1+nd)): the first nb steps stream K pages (phase
    A), the remaining nb*nd stream V dh-chunks (phase B). Off-phase
    operands park on the trash page / chunk 0 so consecutive grid steps
    re-request the same block and the pipeline never streams them."""
    B, Hkv, G, dh = qr.shape
    quant = k_scale is not None

    def k_map(b, h, j, blk, cln):
        live = jnp.logical_and(j < nb,
                               _page_live(j * page, page, cln[b], window))
        phys = blk[b, jnp.minimum(j, nb - 1)]
        return (jnp.where(live, phys, TRASH_PAGE), 0, h, 0)

    def ks_map(b, h, j, blk, cln):
        live = jnp.logical_and(j < nb,
                               _page_live(j * page, page, cln[b], window))
        phys = blk[b, jnp.minimum(j, nb - 1)]
        return (jnp.where(live, phys, TRASH_PAGE), 0, h)

    def v_map(b, h, j, blk, cln):
        t = jnp.maximum(j - nb, 0)
        sc, jp = t // nb, t % nb
        live = jnp.logical_and(j >= nb,
                               _page_live(jp * page, page, cln[b], window))
        return (jnp.where(live, blk[b, jp], TRASH_PAGE), 0, h,
                jnp.where(live, sc, 0))

    def vs_map(b, h, j, blk, cln):
        t = jnp.maximum(j - nb, 0)
        sc, jp = t // nb, t % nb
        live = jnp.logical_and(j >= nb,
                               _page_live(jp * page, page, cln[b], window))
        return (jnp.where(live, blk[b, jp], TRASH_PAGE), 0, h)

    def head_map(b, h, j, blk, cln):
        return (b, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, G, dh), head_map),
        pl.BlockSpec((1, page, 1, dh), k_map),
        pl.BlockSpec((1, page, 1, dchunk), v_map),
    ]
    operands = [qr, k_pool, v_pool]
    if quant:
        in_specs += [pl.BlockSpec((1, page, 1), ks_map),
                     pl.BlockSpec((1, page, 1), vs_map)]
        operands += [k_scale, v_scale]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nb * (1 + nd)),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, dh), head_map),
        scratch_shapes=[pltpu.VMEM((G, nb * page), jnp.float32),
                        pltpu.VMEM((nb * page, dchunk), scr_dtype)],
    )
    kernel = functools.partial(_decode_kernel_multipass, page=page, nb=nb,
                               nd=nd, dchunk=dchunk, window=window,
                               scale=scale, quant=quant)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), qr.dtype),
        interpret=interpret,
    )(block, cl, *operands)


def pages_read_per_step(cache_len: int, page: int, nb: int,
                        window: Optional[int] = None) -> int:
    """Modeled distinct KV pages the kernel streams for one row's decode
    step (the gather oracle always reads ``nb``). Dead/out-of-window
    pages collapse onto the trash page, which the pipeline requests but
    never re-streams between consecutive grid steps; count it as one
    page when any step is dead."""
    if cache_len <= 0:
        return 1
    first = 0 if window is None else max(0, (cache_len - window) // page)
    last = (min(cache_len, nb * page) - 1) // page
    live = max(0, last - first + 1)
    dead = nb - live
    return live + (1 if dead else 0)
