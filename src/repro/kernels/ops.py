"""Kernel execution-backend layer: jit'd wrappers + the ``lutq_dot`` entry.

``interpret`` defaults to True on CPU (this container) and False on TPU,
so the same call sites work in tests and production.

The raw Pallas kernels (``lutq_matmul``, ``lutq_gemv_packed``) demand
tile-multiple shapes, 2-D operands and a single shared dictionary.
:func:`lutq_dot` is the entry point the model layer actually calls: it
resolves a *backend* per quantized leaf, pads/reshapes real-world shapes
onto the kernel grids, consumes serve-packed uint8 assignments directly
(no unpack round-trip), and falls back to the dense-decode reference
wherever a kernel cannot apply (training STE, stacked per-layer /
per-expert dictionaries, transposed packed layouts).

Backends
--------
``decode``   dense reference: ``x @ d[A]`` with the STE master when
             training — the numerics oracle for everything else.
``fused``    :mod:`repro.kernels.lutq_matmul` — int8 assignments stream
             HBM->VMEM at 1 byte/weight and decode against the
             VMEM-resident dictionary in front of the MXU.
``packed4``  :mod:`repro.kernels.lutq_gemv_packed` — 4-bit pairs stay
             packed in HBM (0.5 byte/weight), unpacked in VMEM.
``pow2``     :mod:`repro.kernels.lutq_shift` — pow2 dictionaries stored
             as int8 sign+exponent planes, applied as integer shifted
             adds over int8-quantized activations; the only fp multiply
             is the O(M·N) epilogue scale. Bit-identical to its integer
             decode oracle under any tiling (int32 accumulation).
``auto``     per-leaf structural resolution (see :func:`resolve_backend`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lutq import LutqState, decode_any, quantize_ste_any
from repro.kernels.autotune import (
    KERNEL_OF_BACKEND,
    TileConfig,
    TuningCache,
    make_key,
    platform_key,
)
from repro.kernels.kmeans_tpu import kmeans_stats as _kmeans_stats
from repro.kernels.lutq_gemv_packed import lutq_gemv_packed as _gemv_packed
from repro.kernels.lutq_matmul import lutq_matmul as _lutq_matmul
from repro.kernels.lutq_shift import lutq_shift as _lutq_shift
from repro.kernels.ref import (  # noqa: F401  (re-export for callers)
    lutq_shift_ref,
    pack4,
    pack4_kin,
    pow2_shift_scale,
    pow2_shift_weights,
    unpack4,
    unpack4_kin,
)

#: Backend names accepted by ``lutq_dot`` / policy rules / CLI flags.
BACKENDS = ("auto", "decode", "fused", "packed4", "pow2")

#: Default tiles when the tuning cache has no entry for a shape.
DEFAULT_TILE = TileConfig(bm=256, bn=256, bk=512, strategy="onehot")

# process-level tuning cache: ``lutq_dot`` consults it at trace time,
# ``--autotune cache|search`` fills it, ``serve_view`` / checkpoints
# persist it. Its monotonic version feeds the serving-jit lru keys (via
# :func:`tuning_fingerprint`) so late-arriving tiles force a re-trace.
_TUNING_CACHE = TuningCache()


def tuning_cache() -> TuningCache:
    """The process-level :class:`TuningCache` instance."""
    return _TUNING_CACHE


def tuning_fingerprint() -> int:
    """Monotonic version of the process tuning cache — salt this into
    any lru key whose cached trace bakes in tuned tile choices."""
    return _TUNING_CACHE.version


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "strategy", "interpret"))
def lutq_matmul(x, a, d, *, bm=256, bn=256, bk=512, strategy="onehot",
                interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lutq_matmul(x, a, d, bm=bm, bn=bn, bk=bk,
                        decode_onehot=(strategy == "onehot"),
                        interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bn", "bk", "strategy", "interpret"))
def lutq_gemv_packed(x, packed, d, *, bn=256, bk=512, strategy="onehot",
                     interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gemv_packed(x, packed, d, bn=bn, bk=bk,
                        decode_onehot=(strategy == "onehot"),
                        interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "strategy", "interpret"))
def lutq_shift(xq, a, wsh, *, bm=256, bn=256, bk=512, strategy="onehot",
               interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lutq_shift(xq, a, wsh, bm=bm, bn=bn, bk=bk,
                       decode_onehot=(strategy == "onehot"),
                       interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_stats(w, d, *, bn=4096, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _kmeans_stats(w, d, bn=bn, interpret=interpret)


def kmeans_step_fused(w_flat, d, *, bn=4096, interpret=None):
    """One full k-means iteration via the Pallas stats kernel: assign +
    recenter (empty clusters keep their centroid). Drop-in for the inner
    loop of repro.core.lutq.kmeans_update."""
    a, sums, counts = kmeans_stats(w_flat, d, bn=bn, interpret=interpret)
    new_d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
    return a, jnp.sort(new_d)


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------

def resolve_backend(state: LutqState, backend: str = "auto", *,
                    transpose_rhs: bool = False, sliced: bool = False) -> str:
    """Concrete backend ("decode" | "fused" | "packed4") for one leaf.

    Resolution only consults trace-static leaf structure (dtypes, shapes,
    presence of the fp master), so the result is stable under jit and
    identical to what ``serve_view``'s backend manifest records:

      * train-form leaves (``w`` present) -> ``decode`` — the STE forward
        must stay differentiable and bit-exact with the paper's step 2/3;
      * stacked dictionaries (``d.ndim > 1``: scan-over-layers slices
        them away before the matmul, but MoE expert einsums see them
        whole) -> ``decode``;
      * packed uint8 assignments -> ``packed4`` (the packed kernel reads
        them in place), except transposed use, where the row-pair layout
        is along the wrong axis -> ``decode``;
      * pow2-*encoded* dictionaries (``d.dtype == int8``: the sign+
        exponent plane ``serve_view`` emits for ``backend="pow2"``
        rules) -> ``pow2`` when the shift-add kernel applies (serve
        form, 2-D int8 assignments, K <= 256), else ``decode`` — and
        the decode path on an encoded leaf runs the *integer* oracle,
        so it stays token-identical to the kernel;
      * int8 assignments, K <= 256 -> ``fused``.

    Explicit requests degrade down the same ladder
    (pow2 -> fused -> decode for float dictionaries, since the shift
    trick needs the encoded plane; packed4 -> fused -> decode) instead
    of erroring, so a policy can pin ``backend="packed4"`` on rules
    whose leaves may not all pack.

    ``sliced=True`` resolves the *per-slice* view of a stacked leaf —
    what the kernels see after lax.scan slices a layer stack or
    ``moe_apply`` vmaps over experts. ``serve_view``'s backend manifest
    records this per-tensor resolution.
    """
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; expected one of {BACKENDS}")
    nstack = state.d.ndim - 1
    d_ndim = 1 if sliced else state.d.ndim
    a_ndim = state.a.ndim - nstack if sliced else state.a.ndim
    if state.w is not None or d_ndim > 1 or a_ndim != 2:
        return "decode"
    if backend == "decode":
        return "decode"
    K = state.d.shape[-1]
    if state.d.dtype == jnp.int8:  # pow2 sign+exponent plane
        if state.a.dtype == jnp.uint8 or K > 256:
            return "decode"
        return "pow2"
    if state.a.dtype == jnp.uint8:  # serve-packed 4-bit pairs (pack4_kin)
        if transpose_rhs or K > 16:
            return "decode"
        return "packed4"
    return "fused" if K <= 256 else "decode"


# ---------------------------------------------------------------------------
# shape plumbing: tile choice + zero-padding onto the kernel grids
# ---------------------------------------------------------------------------

def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _tile(dim: int, block: int, base: int):
    """(tile, padded_dim): tile <= block, tile % base == 0, padded % tile == 0.

    In interpret mode base is 1 (any block shape emulates); on real TPU
    base is the hardware tiling (8 sublanes / 128 lanes for f32), so the
    padded operand is always Mosaic-layout friendly.
    """
    t = min(block, _round_up(dim, base))
    return t, _round_up(dim, t)


def _tuned_tile(be: str, M: int, N: int, Kin: int, K: int, dtype,
                interpret: bool) -> TileConfig:
    """Cache lookup for one kernel shape; defaults when absent."""
    key = make_key(KERNEL_OF_BACKEND[be], M, N, Kin, K, dtype, be,
                   platform_key(interpret))
    return _TUNING_CACHE.get(key) or DEFAULT_TILE


# ---------------------------------------------------------------------------
# pow2 shift-add path (multiplier-less serving)
# ---------------------------------------------------------------------------

def _pow2_act_quant(x2, act, axis_name=None):
    """int8-quantize activations for the shift-add path.

    ``act`` is the leaf's frozen calibration pair ``[scale, qmax]``
    (``LutqState.act``, trailing shape (2,)) or None for dynamic
    per-call scaling (``stop_grad(max|x|) / 127``). Returns
    (xq int8, scale f32 scalar). Under K-sharding pass ``axis_name`` so
    the dynamic amax is a global ``pmax`` — max is exact, so the sharded
    quantization is bit-identical to the unsharded one.
    """
    xf = x2.astype(jnp.float32)
    if act is not None:
        qmax = jnp.minimum(act[..., 1].astype(jnp.float32), 127.0)
        s = act[..., 0].astype(jnp.float32)
    else:
        qmax = jnp.float32(127.0)
        amax = jnp.max(jnp.abs(xf))
        if axis_name is not None:
            amax = jax.lax.pmax(amax, axis_name)
        s = jax.lax.stop_gradient(amax) / qmax
    s = jnp.where(s > 0, s, 1.0)
    xq = jnp.clip(jnp.round(xf / s), -qmax, qmax).astype(jnp.int8)
    return xq, s


def _pow2_dot_acc(x2, code, a, act, *, transpose_rhs=False, axis_name=None,
                  use_kernel=True, bm=None, bn=None, bk=None, strategy=None,
                  interpret=None):
    """(int32 accumulator (M, N), f32 epilogue scale) of the pow2 path.

    Shared by the ``pow2`` Pallas backend, the integer decode oracle
    (``use_kernel=False``) and the shard_map local function — all three
    run the same quantize / shifted-dict / int32-accumulate algebra, so
    results are bit-identical (int32 accumulation is exact under any
    tiling or psum order; the fp epilogue multiplies identical values).
    """
    interpret = _default_interpret() if interpret is None else interpret
    if transpose_rhs:
        a = a.T
    M, Kin = x2.shape
    assert a.shape[0] == Kin, (a.shape, x2.shape)
    N = a.shape[1]
    K = code.shape[-1]
    wsh = pow2_shift_weights(code)            # (K,) int32, O(K) exponent-add
    xq, s = _pow2_act_quant(x2, act, axis_name)
    scale = s * pow2_shift_scale(code)        # the single fp multiply factor
    if not use_kernel:
        return lutq_shift_ref(xq, a, wsh), scale
    tile = _tuned_tile("pow2", M, N, Kin, K, jnp.int8, interpret)
    bm = tile.bm if bm is None else bm
    bn = tile.bn if bn is None else bn
    bk = tile.bk if bk is None else bk
    strategy = tile.strategy if strategy is None else strategy
    base_m = 1 if interpret else 8
    base_l = 1 if interpret else 128
    tm, Mp = _tile(M, bm, base_m)
    tn, Np = _tile(N, bn, base_l)
    tk, Kp = _tile(Kin, bk, base_l)
    if Mp != M or Kp != Kin:
        xq = jnp.pad(xq, ((0, Mp - M), (0, Kp - Kin)))
    if Kp != Kin or Np != N:
        a = jnp.pad(a, ((0, Kp - Kin), (0, Np - N)))
    if not interpret and K % base_l:
        # padded dictionary entries are never indexed (assignments < K)
        wsh = jnp.pad(wsh, (0, _round_up(K, base_l) - K))
    acc = lutq_shift(xq, a, wsh, bm=tm, bn=tn, bk=tk, strategy=strategy,
                     interpret=interpret)
    return acc[:M, :N], scale


def lutq_dot(
    x: jax.Array,
    state: LutqState,
    *,
    backend: str = "auto",
    transpose_rhs: bool = False,
    out_dtype=None,
    bm: int = None,
    bn: int = None,
    bk: int = None,
    strategy: str = None,
    interpret: bool = None,
) -> jax.Array:
    """``x @ d[A]`` (or ``x @ d[A].T``) through the resolved backend.

    x: (..., Kin) — leading dims are flattened for the kernels and
    restored on return. state: a LutqState whose assignments are
    (Kin, N) int8, (Kin/2, N) packed uint8, or any stacked/train form
    (those fall back to the dense decode path, which also carries the
    training STE). Returns (..., N) in ``out_dtype`` (default x.dtype).

    Tile sizes and decode strategy default to the process
    :class:`TuningCache` entry for this (kernel, shape, dtype, platform)
    key — :data:`DEFAULT_TILE` when untuned. Explicit ``bm/bn/bk/
    strategy`` arguments override the cache field-by-field. Callers that
    jit around ``lutq_dot`` must salt their jit/lru keys with
    :func:`tuning_fingerprint` or a tile tuned after the first trace
    would be silently ignored.

    Fused backends never materialize the decoded weight matrix in HBM:
    non-tile-multiple shapes are zero-padded onto the kernel grid
    (padded x rows/K-columns are zero, padded assignment entries index
    dictionary slot 0 against zero activations, padded dictionary lanes
    are never indexed), and the pad is sliced off the f32 kernel output.
    """
    be = resolve_backend(state, backend, transpose_rhs=transpose_rhs)
    out_dtype = out_dtype or x.dtype

    if be == "decode":
        a = state.a
        if (state.d.dtype == jnp.int8 and state.w is None
                and state.d.ndim == 1 and a.ndim == 2
                and a.dtype != jnp.uint8):
            # encoded pow2 leaf: run the *integer* decode oracle so the
            # decode backend stays token-identical to the shift-add kernel
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            acc, scale = _pow2_dot_acc(x2, state.d, a, state.act,
                                       transpose_rhs=transpose_rhs,
                                       use_kernel=False)
            y = acc.astype(jnp.float32) * scale
            return y.reshape(*lead, y.shape[-1]).astype(out_dtype)
        if a.dtype == jnp.uint8:
            a = unpack4_kin(a)
        if state.w is not None:
            w = quantize_ste_any(state.w, state.d, a)
        else:
            w = decode_any(state.d, a)
        w = w.astype(x.dtype)
        if transpose_rhs:
            w = jnp.swapaxes(w, -1, -2)
        return jnp.matmul(x, w).astype(out_dtype)

    interpret = _default_interpret() if interpret is None else interpret
    lead, Kin = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, Kin)
    M = x2.shape[0]
    d = state.d
    K = d.shape[-1]
    base_m = 1 if interpret else 8
    base_l = 1 if interpret else 128

    if be == "pow2":
        acc, scale = _pow2_dot_acc(x2, d, state.a, state.act,
                                   transpose_rhs=transpose_rhs,
                                   bm=bm, bn=bn, bk=bk, strategy=strategy,
                                   interpret=interpret)
        y = acc.astype(jnp.float32) * scale
        N = y.shape[-1]
    elif be == "fused":
        a = state.a.T if transpose_rhs else state.a  # (Kin, N) int8
        assert a.shape[0] == Kin, (a.shape, x.shape)
        N = a.shape[1]
        tile = _tuned_tile(be, M, N, Kin, K, x.dtype, interpret)
        bm = tile.bm if bm is None else bm
        bn = tile.bn if bn is None else bn
        bk = tile.bk if bk is None else bk
        strategy = tile.strategy if strategy is None else strategy
        tm, Mp = _tile(M, bm, base_m)
        tn, Np = _tile(N, bn, base_l)
        tk, Kp = _tile(Kin, bk, base_l)
        if Mp != M or Kp != Kin:
            x2 = jnp.pad(x2, ((0, Mp - M), (0, Kp - Kin)))
        if Kp != Kin or Np != N:
            a = jnp.pad(a, ((0, Kp - Kin), (0, Np - N)))
        if not interpret and K % base_l:
            # compiled 1-D VMEM blocks want lane-multiple extents; the
            # padded entries are never indexed (assignments < K), so
            # decode stays exact
            d = jnp.pad(d, (0, _round_up(K, base_l) - K))
        y = lutq_matmul(x2, a, d, bm=tm, bn=tn, bk=tk, strategy=strategy,
                        interpret=interpret)
        y = y[:M, :N]
    else:  # packed4: x (M, Kin) @ unpack(packed (Kin/2, N))
        p = state.a
        assert p.shape[0] * 2 == Kin, (p.shape, x.shape)
        N = p.shape[1]
        tile = _tuned_tile(be, M, N, Kin, K, x.dtype, interpret)
        bn = tile.bn if bn is None else bn
        bk = tile.bk if bk is None else bk
        strategy = tile.strategy if strategy is None else strategy
        Mp = _round_up(M, base_m)  # sublane-pad M for the compiled MXU
        tn, Np = _tile(N, bn, base_l)
        tk, Kp = _tile(Kin, bk, 2 if interpret else 2 * base_l)
        if Mp != M or Kp != Kin:
            x2 = jnp.pad(x2, ((0, Mp - M), (0, Kp - Kin)))
        if Kp != Kin or Np != N:
            p = jnp.pad(p, ((0, (Kp - Kin) // 2), (0, Np - N)))
        if not interpret and K % base_l:
            d = jnp.pad(d, (0, _round_up(K, base_l) - K))
        y = lutq_gemv_packed(x2, p, d, bn=tn, bk=tk, strategy=strategy,
                             interpret=interpret)
        y = y[:M, :N]
    return y.reshape(*lead, N).astype(out_dtype)


# ---------------------------------------------------------------------------
# SPMD: explicit shard_map path over a device mesh
# ---------------------------------------------------------------------------

def _spec_parts(spec, ndim: int):
    """Right-pad a PartitionSpec to ``ndim`` entries."""
    parts = list(tuple(spec) if spec is not None else ())
    return parts + [None] * (ndim - len(parts))


def lutq_dot_spmd(
    x: jax.Array,
    state: LutqState,
    mesh,
    *,
    a_spec,
    x_spec=None,
    backend: str = "auto",
    transpose_rhs: bool = False,
    out_dtype=None,
):
    """:func:`lutq_dot` under ``shard_map``: each device runs the fused
    Pallas kernel on its **local** index shard.

    This is the path GSPMD cannot give a ``pallas_call``: the automatic
    partitioner has no rule for the custom call, so inside a plain jit a
    sharded Pallas matmul falls back to replicate-and-gather. Here the
    grid is split by hand instead:

      * ``a_spec``: PartitionSpec of the assignments, matching their
        actual layout — ``(K, N)`` int8, ``(K/2, N)`` packed uint8
        (shards then hold whole row *pairs* by construction), or
        ``(E, K, N)`` expert-stacked, where sharding E is expert
        parallelism (each device computes its local experts; ``x`` must
        then carry a matching leading E axis, e.g. the MoE capacity
        buffer ``(E, C, D)``).
      * output-dim (N) sharding keeps the full reduction local — the
        result is bit-identical to the unsharded kernel, just sharded.
      * reduction-dim (K) sharding emits one ``psum`` over the named
        axes of the partial products (f32 accumulation; not bit-exact
        against a single device, like any reduce-scatter matmul).
      * ``transpose_rhs`` (tied logits: ``x @ d[A].T``): the roles of
        a's last two dims swap — sharding the vocab dim shards the
        output, sharding the feature dim triggers the psum.

    ``x_spec`` defaults to replicated leading dims with the last dim
    matching a's reduction sharding (so local shards always line up);
    pass e.g. ``P("data", ...)`` to batch-shard activations too. The
    dictionary (and any stacked per-expert dictionaries) are replicated
    across the sharded matmul axes — LUT-Q's tiny-d / big-A split is
    exactly what makes this cheap.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    nstack = state.a.ndim - 2
    if nstack not in (0, 1):
        raise ValueError(f"lutq_dot_spmd supports at most one stack axis, "
                         f"got assignments of rank {state.a.ndim}")
    if nstack and transpose_rhs:
        raise ValueError("transpose_rhs with expert-stacked assignments "
                         "is not supported")
    aparts = _spec_parts(a_spec, state.a.ndim)
    # contraction/output entries of the *assignment* spec
    k_entry, n_entry = (aparts[-1], aparts[-2]) if transpose_rhs else \
                       (aparts[-2], aparts[-1])
    stack_entry = aparts[0] if nstack else None

    if x_spec is None:
        x_spec = P(*([stack_entry] if nstack else []),
                   *([None] * (x.ndim - nstack - 1)), k_entry)
    xparts = _spec_parts(x_spec, x.ndim)
    out_spec = P(*xparts[:-1], n_entry)
    d_spec = P(stack_entry, None) if nstack else P()

    def local(x_l, d_l, a_l, *act_rest):
        act_l = act_rest[0] if act_rest else None
        if (d_l.dtype == jnp.int8 and a_l.dtype == jnp.int8
                and k_entry is not None):
            # encoded pow2 under K-sharding: psum the *int32* partial
            # accumulators (exact) and pmax the dynamic act amax inside
            # _pow2_act_quant, so the sharded result is bit-identical to
            # one device — unlike the f32 psum below
            use_kernel = backend != "decode"

            def parts(xe, de, ae, ce):
                x2 = xe.reshape(-1, xe.shape[-1])
                acc, scale = _pow2_dot_acc(
                    x2, de, ae, ce, transpose_rhs=transpose_rhs,
                    axis_name=k_entry, use_kernel=use_kernel)
                return acc.reshape(*xe.shape[:-1], acc.shape[-1]), scale

            if nstack:
                acc, scale = jax.vmap(parts)(x_l, d_l, a_l, act_l)
                scale = scale.reshape(scale.shape + (1,) * (acc.ndim - 1))
            else:
                acc, scale = parts(x_l, d_l, a_l, act_l)
            acc = jax.lax.psum(acc, k_entry)
            return (acc.astype(jnp.float32) * scale).astype(
                out_dtype or x_l.dtype)
        if nstack:
            y = jax.vmap(lambda xe, de, ae, ce: lutq_dot(
                xe, LutqState(w=None, d=de, a=ae, act=ce), backend=backend,
                out_dtype=out_dtype))(x_l, d_l, a_l, act_l)
        else:
            y = lutq_dot(x_l, LutqState(w=None, d=d_l, a=a_l, act=act_l),
                         backend=backend,
                         transpose_rhs=transpose_rhs, out_dtype=out_dtype)
        if k_entry is not None:
            y = jax.lax.psum(y, k_entry)
        return y

    operands = [x, state.d, state.a]
    in_specs = [P(*xparts), d_spec, P(*aparts)]
    if state.act is not None:
        # act [scale, qmax] pairs are tiny and replicated across the
        # sharded matmul axes, like the dictionary
        operands.append(state.act)
        in_specs.append(P(stack_entry, None) if nstack else P(None))
    return shard_map(local, mesh=mesh,
                     in_specs=tuple(in_specs),
                     out_specs=out_spec, check_rep=False)(*operands)


# ---------------------------------------------------------------------------
# SPMD annotation: route model-layer dots to lutq_dot_spmd inside a jit
# ---------------------------------------------------------------------------

class SpmdLutqState:
    """A :class:`LutqState` tagged with its mesh + assignment sharding.

    Trace-local wrapper: the meshed serving jits call
    :func:`annotate_spmd` on their *tracer* params, so model-layer code
    (``nn/linear.dot_kernel``, ``nn/moe._expert_dot``) can dispatch the
    leaf to :func:`lutq_dot_spmd` — running each ``pallas_call`` on its
    local index shard — instead of letting GSPMD gather the assignments
    around the custom call. The wrapper never escapes the trace, so
    checkpointing, manifests and tests always see plain LutqStates.

    Registered as a pytree with (mesh, a_spec) static so scan/vmap/remat
    transparently slice the inner state while the annotation rides along.
    """

    __slots__ = ("state", "mesh", "a_spec")

    def __init__(self, state: LutqState, mesh, a_spec):
        self.state = state
        self.mesh = mesh
        self.a_spec = a_spec

    # convenience passthroughs so shape probes keep working
    @property
    def w(self):
        return self.state.w

    @property
    def d(self):
        return self.state.d

    @property
    def a(self):
        return self.state.a

    @property
    def sid(self):
        return self.state.sid

    @property
    def act(self):
        return self.state.act

    def tree_flatten(self):
        return (self.state,), (self.mesh, self.a_spec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


jax.tree_util.register_pytree_node(
    SpmdLutqState,
    lambda s: s.tree_flatten(),
    SpmdLutqState.tree_unflatten,
)


def annotate_spmd(params, axes, mesh):
    """Wrap serve-form LutqState leaves with their serve PartitionSpecs.

    Call *inside* a meshed jit on the params tracers. Only leaves whose
    assignment spec actually names a mesh axis are wrapped — replicated
    leaves (and train-form / non-LutqState leaves) pass through, so the
    decode fallback and unsharded paths are byte-identical to before.
    """
    if mesh is None:
        return params
    from repro.distributed.sharding import serve_pspecs

    pspecs = serve_pspecs(axes, mesh, params)

    def wrap(leaf, spec):
        if not isinstance(leaf, LutqState) or leaf.w is not None:
            return leaf
        a_spec = getattr(spec, "a", None)
        if a_spec is None or not any(e is not None for e in tuple(a_spec)):
            return leaf
        return SpmdLutqState(leaf, mesh, a_spec)

    return jax.tree_util.tree_map(
        wrap, params, pspecs,
        is_leaf=lambda n: isinstance(n, LutqState))


def lutq_dot_sharded(
    x: jax.Array,
    leaf: "SpmdLutqState",
    *,
    backend: str = "auto",
    transpose_rhs: bool = False,
    out_dtype=None,
):
    """Dispatch an annotated leaf: shard-local kernels when they apply.

    scan-over-layers slices leading stack axes off the *arrays* but not
    off the recorded spec, so the spec's trailing entries are
    right-aligned to the runtime assignment rank. Leaves that resolve to
    ``decode``, or whose live spec entries are all None, take the plain
    :func:`lutq_dot` path (GSPMD shards dense decode fine on its own).
    """
    from jax.sharding import PartitionSpec as P

    state = leaf.state
    # right-align to the runtime rank: scan/vmap slicing removed leading
    # stack axes from a but specs were recorded on the full stacked leaf
    ndim = state.a.ndim
    parts = list(tuple(leaf.a_spec))[-ndim:] if leaf.a_spec else []
    parts = [None] * (ndim - len(parts)) + parts
    nstack = state.a.ndim - 2
    be = resolve_backend(state, backend, transpose_rhs=transpose_rhs,
                         sliced=True)
    live = any(e is not None for e in parts)
    if (be == "decode" or not live or nstack not in (0, 1)
            or (nstack and transpose_rhs)):
        return lutq_dot(x, state, backend=backend,
                        transpose_rhs=transpose_rhs, out_dtype=out_dtype)
    return lutq_dot_spmd(x, state, leaf.mesh, a_spec=P(*parts),
                         backend=backend, transpose_rhs=transpose_rhs,
                         out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Paged attention (decode): block-table Pallas kernel vs gather oracle
# ---------------------------------------------------------------------------

#: dispatch names accepted by :func:`paged_attention`.
PAGED_BACKENDS = ("auto", "kernel", "gather")


def paged_attention_reference(q, k_pool, v_pool, block, cache_len, *,
                              window=None, scale=None, k_scale=None,
                              v_scale=None):
    """Gather oracle: assemble the row once, dequant once, attend.

    This is the pre-kernel paged decode path and the numerics contract
    the kernel must match bit-for-bit. The int8 scale planes are
    gathered exactly once each and reused for the dequant (the old
    in-model path re-gathered them right after scattering the new
    token's scales).
    """
    from repro.nn.attention import decode_attention, gather_pages

    kc = gather_pages(k_pool, block)
    vc = gather_pages(v_pool, block)
    if k_scale is not None:
        ks = gather_pages(k_scale, block)
        vs = gather_pages(v_scale, block)
        kc = kc.astype(jnp.bfloat16) * ks[..., None]
        vc = vc.astype(jnp.bfloat16) * vs[..., None]
    return decode_attention(q, kc, vc, cache_len, window=window, scale=scale)


def _paged_attention_sharded(q, k_pool, v_pool, block, cache_len, *,
                             window, scale, k_scale, v_scale, interpret,
                             mesh, vmem_budget_bytes=None):
    """KV-head-sharded kernel dispatch under a ("data","model") mesh.

    ``paged_serve_shardings`` lays pool leaves out with the Hkv axis on
    "model" and the block table / batch on "data"; the kernel grid is
    purely parallel over (batch, kv-head), so a shard_map over both axes
    runs the identical kernel on local shards — bit-identical by
    construction. Falls back to the gather oracle (which GSPMD
    partitions on its own) when an axis does not divide.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.paged_attn import paged_attention_tpu

    B, _, H, _ = q.shape
    hkv = k_pool.shape[2]
    sizes = dict(mesh.shape)
    data, model = sizes.get("data", 1), sizes.get("model", 1)
    if B % data or hkv % model:
        return paged_attention_reference(
            q, k_pool, v_pool, block, cache_len, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    dp = "data" if data > 1 else None
    tp = "model" if model > 1 else None
    quant = k_scale is not None

    def local(q_l, k_l, v_l, blk_l, cl_l, *scales):
        ks_l, vs_l = scales if scales else (None, None)
        return paged_attention_tpu(
            q_l, k_l, v_l, blk_l, cl_l, window=window, scale=scale,
            k_scale=ks_l, v_scale=vs_l, interpret=interpret,
            vmem_budget_bytes=vmem_budget_bytes)

    in_specs = [P(dp, None, tp, None), P(None, None, tp, None),
                P(None, None, tp, None), P(dp, None), P(dp)]
    operands = [q, k_pool, v_pool, block, cache_len]
    if quant:
        in_specs += [P(None, None, tp), P(None, None, tp)]
        operands += [k_scale, v_scale]
    return shard_map(local, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=P(dp, None, tp, None),
                     check_rep=False)(*operands)


def paged_attention(q, k_pool, v_pool, block, cache_len, *, window=None,
                    scale=None, k_scale=None, v_scale=None, backend="auto",
                    interpret=None, mesh=None, vmem_budget_bytes=None):
    """One-token decode attention over a paged KV pool.

    q: (B, 1, H, dh); k_pool/v_pool: (P, page, Hkv, dh); block: (B, NB)
    int32 block table; cache_len: (B,) or scalar valid lengths. int8
    pools carry bf16 per-token scale planes (P, page, Hkv) in
    ``k_scale``/``v_scale``.

    ``backend="kernel"`` walks the block table in Pallas
    (:mod:`repro.kernels.paged_attn`), streaming ``ceil(cache_len/page)``
    live pages per row instead of the full ``NB*page`` gather —
    ``window/page`` pages under SWA. ``"gather"`` is the materializing
    oracle. ``"auto"`` consults the process :class:`TuningCache` under
    the ``paged_attn`` key (both entries are bit-identical, so tuning
    only ever trades bytes for bytes) and defaults to the kernel.
    ``mesh`` routes through a shard_map over ("data","model") so
    KV-head-sharded serving keeps shard-local pages.
    ``vmem_budget_bytes`` caps the kernel's per-row VMEM scratch (see
    ``paged_attn.vmem_plan``): rows too long for the single-pass scratch
    run the bit-identical multi-pass split instead of failing.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if backend not in PAGED_BACKENDS:
        raise ValueError(f"backend={backend!r} not in {PAGED_BACKENDS}")
    _, page, hkv, dh = k_pool.shape
    nb = block.shape[1]
    if backend == "auto":
        from repro.kernels.autotune import paged_attn_key

        tile = _TUNING_CACHE.get(paged_attn_key(
            page, nb, hkv, dh, k_pool.dtype, interpret=interpret))
        backend = tile.strategy if tile is not None else "kernel"
    cl = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (q.shape[0],))
    if backend == "gather":
        return paged_attention_reference(
            q, k_pool, v_pool, block, cl, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale)
    if mesh is not None:
        return _paged_attention_sharded(
            q, k_pool, v_pool, block, cl, window=window, scale=scale,
            k_scale=k_scale, v_scale=v_scale, interpret=interpret, mesh=mesh,
            vmem_budget_bytes=vmem_budget_bytes)
    from repro.kernels.paged_attn import paged_attention_tpu

    return paged_attention_tpu(
        q, k_pool, v_pool, block, cl, window=window, scale=scale,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret,
        vmem_budget_bytes=vmem_budget_bytes)


def tune_paged_attention(*, batch=4, page=16, pages_per_row=4, hkv=2,
                         dh=16, g=2, kv_dtype=jnp.float32, window=None,
                         interpret=None, reps=3, warmup=2, seed=0,
                         cache=None):
    """Time kernel vs gather on one paged geometry; record the winner.

    Returns ``(key, best_tile, {candidate: us})`` like
    :func:`repro.kernels.autotune.tune` (which this wraps — the cache
    key is ``paged_attn|M<page>|N<NB>|Kin<Hkv>|K<dh>|...``). Both
    candidates are bit-identical, so a recorded entry only ever changes
    which byte stream the decode jits trace; the TuningCache version
    bump re-traces them.
    """
    import numpy as np

    from repro.kernels import autotune

    interpret = _default_interpret() if interpret is None else interpret
    rng = np.random.RandomState(seed)
    n_pages = 1 + batch * pages_per_row
    quant = jnp.dtype(kv_dtype) == jnp.int8
    if quant:
        kp = jnp.asarray(rng.randint(-127, 128,
                                     (n_pages, page, hkv, dh)), jnp.int8)
        vp = jnp.asarray(rng.randint(-127, 128,
                                     (n_pages, page, hkv, dh)), jnp.int8)
        ks = jnp.asarray(np.abs(rng.randn(n_pages, page, hkv)) * 0.05,
                         jnp.bfloat16)
        vs = jnp.asarray(np.abs(rng.randn(n_pages, page, hkv)) * 0.05,
                         jnp.bfloat16)
    else:
        kp = jnp.asarray(rng.randn(n_pages, page, hkv, dh), kv_dtype)
        vp = jnp.asarray(rng.randn(n_pages, page, hkv, dh), kv_dtype)
        ks = vs = None
    q = jnp.asarray(rng.randn(batch, 1, hkv * g, dh), jnp.float32)
    blk = jnp.asarray(
        rng.randint(1, n_pages, (batch, pages_per_row)), jnp.int32)
    cl = jnp.asarray(
        rng.randint(1, pages_per_row * page + 1, (batch,)), jnp.int32)

    def measure(tile):
        def run(q, kp, vp, blk, cl):
            return paged_attention(q, kp, vp, blk, cl, window=window,
                                   k_scale=ks, v_scale=vs,
                                   backend=tile.strategy,
                                   interpret=interpret)

        return autotune.measure_call(jax.jit(run), q, kp, vp, blk, cl,
                                     reps=reps, warmup=warmup)

    return autotune.tune("paged_attn", M=page, N=pages_per_row, Kin=hkv,
                         K=dh, dtype=kv_dtype, backend="paged",
                         interpret=interpret, measure=measure, cache=cache)
