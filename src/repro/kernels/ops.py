"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU,
so the same call sites work in tests and production.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_tpu import kmeans_stats as _kmeans_stats
from repro.kernels.lutq_gemv_packed import lutq_gemv_packed as _gemv_packed
from repro.kernels.lutq_matmul import lutq_matmul as _lutq_matmul
from repro.kernels.ref import pack4, unpack4  # re-export for callers


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lutq_matmul(x, a, d, *, bm=256, bn=256, bk=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _lutq_matmul(x, a, d, bm=bm, bn=bn, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def lutq_gemv_packed(x, packed, d, *, bn=256, bk=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gemv_packed(x, packed, d, bn=bn, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def kmeans_stats(w, d, *, bn=4096, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _kmeans_stats(w, d, bn=bn, interpret=interpret)


def kmeans_step_fused(w_flat, d, *, bn=4096, interpret=None):
    """One full k-means iteration via the Pallas stats kernel: assign +
    recenter (empty clusters keep their centroid). Drop-in for the inner
    loop of repro.core.lutq.kmeans_update."""
    a, sums, counts = kmeans_stats(w_flat, d, bn=bn, interpret=interpret)
    new_d = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), d)
    return a, jnp.sort(new_d)
