"""Tiled k-means assignment + statistics Pallas kernel (paper step 4).

Step 4 runs over EVERY weight of EVERY layer each minibatch — the
training-time hot spot LUT-Q adds. For a sorted dictionary the nearest
entry of w is ``sum(mid < w)`` over the K-1 interval midpoints: a dense
(bn x K-1) compare + row-sum, which maps onto the VPU with no gather.
Per-entry sums/counts come from a one-hot matmul; both accumulate across
the sequential TPU grid into (K,)-shaped outputs, so one pass of the
kernel yields everything the centroid recenter step needs.

HBM traffic: one read of w + one write of a (int8) per iteration —
the same arrays the training step already touches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, d_ref, a_ref, sums_ref, counts_ref, *, n_dict: int,
            bn: int, n_valid: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    w = w_ref[...].astype(jnp.float32)          # (bn,)
    d = d_ref[...].astype(jnp.float32)          # (n_dict,)
    mid = (d[:-1] + d[1:]) * 0.5                # (n_dict-1,)
    # assignment = number of midpoints strictly below w (ties -> lower)
    a = jnp.sum((mid[None, :] < w[:, None]).astype(jnp.int32), axis=1)
    a_ref[...] = a.astype(jnp.int8)
    onehot = (a[:, None] == jnp.arange(n_dict, dtype=jnp.int32)[None, :]
              ).astype(jnp.float32)             # (bn, K)
    if n_valid % bn:
        # ragged tail: zero-pad entries (global index >= n_valid) must not
        # enter the statistics. n_valid is trace-static, so full blocks
        # compile with no masking at all. (2-D iota + squeeze: TPU has no
        # 1-D iota.)
        idx = i * bn + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)[:, 0]
        onehot = jnp.where((idx < n_valid)[:, None], onehot, 0.0)
    sums_ref[...] += onehot.T @ w
    counts_ref[...] += jnp.sum(onehot, axis=0)


def kmeans_stats(
    w: jax.Array,   # (N,) flat weights
    d: jax.Array,   # (K,) sorted dictionary
    *,
    bn: int = 4096,
    interpret: bool = False,
):
    """Returns (assignments int8 (N,), sums f32 (K,), counts f32 (K,)).

    Any N is accepted: the flat weights are zero-padded onto the block
    grid and the tail block masks padded entries out of the sums/counts
    (assignments for the pad are computed but sliced off), so the result
    is element-exact with the unpadded kernel.
    """
    N = w.shape[0]
    n_dict = d.shape[0]
    bn = min(bn, N)
    Np = -(-N // bn) * bn
    if Np != N:
        w = jnp.pad(w, (0, Np - N))
    grid = (Np // bn,)
    a, sums, counts = pl.pallas_call(
        functools.partial(_kernel, n_dict=n_dict, bn=bn, n_valid=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int8),
            jax.ShapeDtypeStruct((n_dict,), jnp.float32),
            jax.ShapeDtypeStruct((n_dict,), jnp.float32),
        ],
        interpret=interpret,
    )(w, d)
    return (a[:N] if Np != N else a), sums, counts
