"""Tiled k-means assignment + statistics Pallas kernel (paper step 4).

Step 4 runs over EVERY weight of EVERY layer each minibatch — the
training-time hot spot LUT-Q adds. For a sorted dictionary the nearest
entry of w is ``sum(mid < w)`` over the K-1 interval midpoints: a dense
(bn x K-1) compare + row-sum, which maps onto the VPU with no gather.
Per-entry sums/counts come from a one-hot matmul; both accumulate across
the sequential TPU grid into (K,)-shaped outputs, so one pass of the
kernel yields everything the centroid recenter step needs.

HBM traffic: one read of w + one write of a (int8) per iteration —
the same arrays the training step already touches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, d_ref, a_ref, sums_ref, counts_ref, *, n_dict: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    w = w_ref[...].astype(jnp.float32)          # (bn,)
    d = d_ref[...].astype(jnp.float32)          # (n_dict,)
    mid = (d[:-1] + d[1:]) * 0.5                # (n_dict-1,)
    # assignment = number of midpoints strictly below w (ties -> lower)
    a = jnp.sum((mid[None, :] < w[:, None]).astype(jnp.int32), axis=1)
    a_ref[...] = a.astype(jnp.int8)
    onehot = (a[:, None] == jnp.arange(n_dict, dtype=jnp.int32)[None, :]
              ).astype(jnp.float32)             # (bn, K)
    sums_ref[...] += onehot.T @ w
    counts_ref[...] += jnp.sum(onehot, axis=0)


def kmeans_stats(
    w: jax.Array,   # (N,) flat weights
    d: jax.Array,   # (K,) sorted dictionary
    *,
    bn: int = 4096,
    interpret: bool = False,
):
    """Returns (assignments int8 (N,), sums f32 (K,), counts f32 (K,))."""
    N = w.shape[0]
    n_dict = d.shape[0]
    bn = min(bn, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    return pl.pallas_call(
        functools.partial(_kernel, n_dict=n_dict),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
            pl.BlockSpec((n_dict,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N,), jnp.int8),
            jax.ShapeDtypeStruct((n_dict,), jnp.float32),
            jax.ShapeDtypeStruct((n_dict,), jnp.float32),
        ],
        interpret=interpret,
    )(w, d)
