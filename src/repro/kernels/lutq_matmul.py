"""Fused LUT-decode + matmul Pallas TPU kernel.

Computes ``y = x @ d[A]`` without ever materializing the decoded weight
matrix in HBM: the int8 assignment block (bk x bn) streams HBM->VMEM
(1 byte/weight instead of 2-4 for bf16/f32), is decoded against the
(<=256-entry, VMEM-resident) dictionary, and feeds the MXU.

TPU adaptation of the paper's "K multiplications per output" claim: on
TPU the win is *memory traffic*, not multiplier count — weight bytes
drop 2-4x (4x more with the packed 4-bit variant in lutq_gemv_packed),
which moves the decode-phase memory roofline term directly.

Decode uses a one-hot matmul (indices -> one-hot (bk*bn, K) @ d) rather
than a gather: for K <= 256 this is MXU-friendly and avoids relying on
VMEM dynamic-gather lowering.

Grid: (M/bm, N/bn, Kin/bk), k innermost so the f32 output block stays
resident across the accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, d_ref, o_ref, *, n_dict: int, decode_onehot: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)  # (bk, bn)
    d = d_ref[...]                    # (n_dict,)
    if decode_onehot:
        bk, bn = a.shape
        onehot = (a.reshape(bk * bn, 1) ==
                  jnp.arange(n_dict, dtype=jnp.int32)[None, :]).astype(d.dtype)
        w = (onehot @ d.reshape(n_dict, 1)).reshape(bk, bn)
    else:
        w = jnp.take(d, a, axis=0)
    x = x_ref[...]
    o_ref[...] += jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def lutq_matmul(
    x: jax.Array,       # (M, Kin)
    a: jax.Array,       # (Kin, N) int8
    d: jax.Array,       # (K,) float32
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    decode_onehot: bool = True,
    interpret: bool = False,
) -> jax.Array:
    M, Kin = x.shape
    Kin2, N = a.shape
    assert Kin == Kin2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, Kin)
    assert M % bm == 0 and N % bn == 0 and Kin % bk == 0, (M, N, Kin, bm, bn, bk)
    n_dict = d.shape[0]

    grid = (M // bm, N // bn, Kin // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_dict=n_dict, decode_onehot=decode_onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_dict,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, a, d)
