"""Shift-and-add LUT matmul Pallas TPU kernel (multiplier-less path).

Computes the int32 accumulator ``acc = xq @ wsh[A]`` for pow2-constrained
dictionaries: ``xq`` are int8-quantized activations, ``A`` streams
HBM->VMEM as int8 assignments (1 byte/weight), and ``wsh`` is the
(<=256-entry, VMEM-resident) *shifted-integer* dictionary — each pow2
entry pre-lowered to ``sign * (1 << (exponent - min_exponent))`` by
``kernels.ref.pow2_shift_weights``, an O(K) exponent-add outside the hot
loop. The kernel therefore performs only integer adds/shifted adds (the
paper's multiplier-less claim); the caller applies the single fp
multiply — ``acc * (act_scale * 2^(min_exponent - 1 + POW2_MIN_EXP))`` —
at the O(M·N) epilogue.

Because accumulation is exact int32, the result is bit-identical to the
``kernels.ref.lutq_shift_ref`` oracle under ANY tile shape and any
K-shard/psum order — unlike the f32 fused kernel, no single-k-step
pinning is needed for interpret-mode bit-identity.

Overflow bound (checked at encode time in ``core.policy.serve_view``):
|acc| <= 127 * 2^span * Kin, so 7 + span + ceil(log2 Kin) <= 31 bits
must hold, where span = max-min nonzero exponent of the dictionary.

Grid: (M/bm, N/bn, Kin/bk), k innermost so the int32 output block stays
resident across the accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, w_ref, o_ref, *, n_dict: int, decode_onehot: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)  # (bk, bn)
    w = w_ref[...]                    # (n_dict,) int32 shifted integers
    if decode_onehot:
        bk, bn = a.shape
        onehot = (a.reshape(bk * bn, 1) ==
                  jnp.arange(n_dict, dtype=jnp.int32)[None, :]).astype(jnp.int32)
        wt = (onehot @ w.reshape(n_dict, 1)).reshape(bk, bn)
    else:
        wt = jnp.take(w, a, axis=0)
    xq = x_ref[...].astype(jnp.int32)
    o_ref[...] += jax.lax.dot_general(
        xq, wt,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def lutq_shift(
    xq: jax.Array,      # (M, Kin) int8 quantized activations
    a: jax.Array,       # (Kin, N) int8 assignments
    wsh: jax.Array,     # (K,) int32 shifted-integer dictionary
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    decode_onehot: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """int32 accumulator of the shift-add matmul (see module docstring)."""
    M, Kin = xq.shape
    Kin2, N = a.shape
    assert Kin == Kin2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, Kin)
    assert M % bm == 0 and N % bn == 0 and Kin % bk == 0, (M, N, Kin, bm, bn, bk)
    n_dict = wsh.shape[0]

    grid = (M // bm, N // bn, Kin // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_dict=n_dict, decode_onehot=decode_onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_dict,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(xq, a, wsh)
