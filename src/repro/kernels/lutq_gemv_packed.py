"""Packed 4-bit LUT-Q decode GEMV Pallas kernel (the decode-serving win).

Decode at batch B is HBM-bandwidth-bound: wall time ~ weight bytes / HBM
bw. LUT-Q with K=16 stores 4 bits/weight; this kernel keeps the
assignment matrix PACKED in HBM (two indices per byte), unpacks nibbles
in VMEM, decodes against the dictionary and runs the (small-M) matmul —
weight traffic is Kin*N/2 bytes vs 2*Kin*N for bf16: a 4x reduction of
the dominant roofline term for decode.

Grid: (N/bn, Kin/bk) with k innermost; x fits VMEM whole (B is small at
decode time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, p_ref, d_ref, o_ref, *, n_dict: int, decode_onehot: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    packed = p_ref[...]                     # (bk/2, bn) uint8/int8
    lo = (packed & 0xF).astype(jnp.int32)   # even rows
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    bk2, bn = packed.shape
    idx = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)
    d = d_ref[...]
    if decode_onehot:
        onehot = (idx.reshape(-1, 1) ==
                  jnp.arange(n_dict, dtype=jnp.int32)[None, :]).astype(d.dtype)
        w = (onehot @ d.reshape(n_dict, 1)).reshape(bk2 * 2, bn)
    else:
        w = jnp.take(d, idx, axis=0)        # Mosaic-friendly gather
    x = x_ref[...]                          # (B, bk)
    o_ref[...] += jax.lax.dot_general(
        x, w.astype(x.dtype),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def lutq_gemv_packed(
    x: jax.Array,        # (B, Kin)
    packed: jax.Array,   # (Kin/2, N) uint8 — two 4-bit indices per byte
    d: jax.Array,        # (16,) float32
    *,
    bn: int = 256,
    bk: int = 512,
    decode_onehot: bool = True,
    interpret: bool = False,
) -> jax.Array:
    B, Kin = x.shape
    Kin2, N = packed.shape
    assert Kin == Kin2 * 2
    n_dict = d.shape[0]
    # 4-bit packing caps the *live* dictionary at 16 entries; compiled
    # mode may lane-pad d to a 128 multiple (nibbles never index the pad)
    assert n_dict <= 16 or n_dict % 128 == 0, \
        "packed layout is 4-bit (K <= 16, or 128-lane-padded)"
    bn, bk = min(bn, N), min(bk, Kin)
    assert N % bn == 0 and Kin % bk == 0 and bk % 2 == 0

    grid = (N // bn, Kin // bk)
    return pl.pallas_call(
        functools.partial(_kernel, n_dict=n_dict,
                          decode_onehot=decode_onehot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk // 2, bn), lambda j, k: (k, j)),
            pl.BlockSpec((n_dict,), lambda j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(x, packed, d)
