"""Compiled-graph multiply audit for multiplier-less serving.

The pow2 backend's claim — dictionary applied by exponent-add/bit-shift,
int32 accumulation, one fp scale at the epilogue — is checked here
against what actually lowers, not against what the Python source says.
``lower_text`` captures the StableHLO for a jitted callable (the
pre-optimization module: deterministic, platform-independent, and with
interpret-mode Pallas every kernel op is inlined as plain StableHLO);
``multiply_report`` classifies every ``multiply`` / ``dot_general`` /
``convolution`` by element type and shape; ``audit_multiplierless``
asserts the quantized matmul path is integer:

* no floating-point ``dot_general``/``convolution`` touches a quantized
  weight shape (the decoded-weight matmul must not exist);
* no floating-point elementwise ``multiply`` is weight-shaped (no
  decoded-weight scaling either);
* at least one integer dot is present (the shift-add accumulation).

fp multiplies *are* allowed at the boundary — activation quantization
(M x Kin) and the epilogue scale (M x N) — and in unquantized layers
(norms, attention probs, fp-by-policy embed/head), which is exactly the
multiplier budget the paper's Table 2 counts.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp

FP_TYPES = ("f64", "f32", "f16", "bf16")

_TENSOR_RE = re.compile(r"tensor<([^>]*)>")
_DOT_RE = re.compile(
    r"stablehlo\.(dot_general|convolution)\b.*"
    r"\((tensor<[^>]*>),\s*(tensor<[^>]*>)\)\s*->\s*(tensor<[^>]*>)")
_MUL_RE = re.compile(r"stablehlo\.multiply\b.*:\s*(tensor<[^>]*>)\s*$")


def _parse(tensor: str) -> Tuple[Tuple[int, ...], str]:
    """'8x16xf32' -> ((8, 16), 'f32'); 'f32' (scalar) -> ((), 'f32')."""
    inner = _TENSOR_RE.match(tensor).group(1) if tensor.startswith("tensor") \
        else tensor
    parts = inner.split("x")
    dims = tuple(int(p) for p in parts[:-1] if p.isdigit())
    return dims, parts[-1]


def _elems(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def lower_text(fn, *args, **kwargs) -> str:
    """StableHLO text of ``jit(fn)`` lowered on the given args."""
    return jax.jit(fn).lower(*args, **kwargs).as_text()


def multiply_report(hlo_text: str) -> Dict[str, List[dict]]:
    """Classify every multiply-shaped op in a StableHLO module.

    Returns ``{"fp_dots": [...], "int_dots": [...], "fp_multiplies":
    [...]}``; each entry carries ``dtype``, operand/output ``dims`` and
    an element/flop count. Dot flops are estimated as
    ``sqrt(|lhs|*|rhs|*|out|)`` (exact for plain and shared-batch
    matmuls).
    """
    fp_dots, int_dots, fp_multiplies = [], [], []
    for line in hlo_text.splitlines():
        m = _DOT_RE.search(line)
        if m:
            ld, lt = _parse(m.group(2))
            rd, rt = _parse(m.group(3))
            od, ot = _parse(m.group(4))
            flops = int(round((_elems(ld) * _elems(rd) * _elems(od)) ** 0.5))
            rec = {"op": m.group(1), "dtype": ot, "lhs": ld, "rhs": rd,
                   "out": od, "flops": flops}
            (fp_dots if ot in FP_TYPES else int_dots).append(rec)
            continue
        m = _MUL_RE.search(line)
        if m:
            dims, dt = _parse(m.group(1))
            if dt in FP_TYPES:
                fp_multiplies.append({"dtype": dt, "dims": dims,
                                      "elems": _elems(dims)})
    return {"fp_dots": fp_dots, "int_dots": int_dots,
            "fp_multiplies": fp_multiplies}


def count_ops(hlo_text: str) -> Dict[str, int]:
    """Scalar multiply budget of a module (the Table 2 quantities)."""
    rep = multiply_report(hlo_text)
    return {
        "fp_dot_flops": sum(d["flops"] for d in rep["fp_dots"]),
        "int_dot_flops": sum(d["flops"] for d in rep["int_dots"]),
        "fp_multiply_elems": sum(m["elems"] for m in rep["fp_multiplies"]),
        "n_fp_dots": len(rep["fp_dots"]),
        "n_int_dots": len(rep["int_dots"]),
    }


def quantized_weight_dims(params) -> Set[Tuple[int, ...]]:
    """Trailing-2D shapes (and transposes) of every LUT-Q leaf's
    assignment plane — the shapes a multiplier-less lowering must never
    touch with an fp dot or fp weight-shaped multiply."""
    from repro.core.lutq import LutqState

    shapes: Set[Tuple[int, ...]] = set()

    def visit(leaf):
        st = getattr(leaf, "state", leaf)
        if isinstance(st, LutqState) and st.a.ndim >= 2:
            kin, n = int(st.a.shape[-2]), int(st.a.shape[-1])
            shapes.add((kin, n))
            shapes.add((n, kin))

    jax.tree_util.tree_map(
        visit, params,
        is_leaf=lambda x: isinstance(getattr(x, "a", None), jnp.ndarray)
        or hasattr(x, "state"))
    return shapes


def _touches(dims_list: Iterable[Tuple[int, ...]],
             weight_shapes: Set[Tuple[int, ...]]) -> bool:
    return any(d[-2:] in weight_shapes for d in dims_list if len(d) >= 2)


def audit_multiplierless(
    fn,
    *args,
    weight_shapes: Optional[Sequence[Tuple[int, ...]]] = None,
    params=None,
    require_int_dot: bool = True,
    **kwargs,
) -> Dict[str, List[dict]]:
    """Assert the quantized matmul path of ``fn`` lowers multiplier-less.

    ``weight_shapes`` (or ``params``, from which they are collected via
    :func:`quantized_weight_dims`) scope the claim to the quantized
    leaves: fp dots/convs and fp weight-shaped multiplies touching those
    shapes fail the audit; boundary fp multiplies and fp-by-policy
    layers pass. Returns the :func:`multiply_report` for inspection.

    Raises ``AssertionError`` with the offending ops on failure.
    """
    if weight_shapes is None:
        assert params is not None, "pass weight_shapes or params"
        wset = quantized_weight_dims(params)
    else:
        wset = {tuple(s) for s in weight_shapes}
        wset |= {s[::-1] for s in wset}
    rep = multiply_report(lower_text(fn, *args, **kwargs))
    bad_dots = [d for d in rep["fp_dots"]
                if _touches((d["lhs"], d["rhs"]), wset)]
    assert not bad_dots, (
        f"fp dot ops touch quantized weight shapes (decoded-weight matmul "
        f"survived): {bad_dots}")
    bad_muls = [m for m in rep["fp_multiplies"] if _touches((m["dims"],), wset)]
    assert not bad_muls, (
        f"fp weight-shaped multiplies present (decoded-weight scaling "
        f"survived): {bad_muls}")
    if require_int_dot:
        assert rep["int_dots"], (
            "no integer dot in the lowering — shift-add accumulation missing")
    return rep
