"""qwen3-moe-235b-a22b [moe]: 128 experts top-8, qk-norm.

94L d_model=4096 64H (GQA kv=4) d_ff(expert)=1536 vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    use_qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=False,
    n_experts=128,
    top_k=8,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
