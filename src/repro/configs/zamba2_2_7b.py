"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention block.

54L d_model=2560 (mamba d_inner=5120, ssm_state=64) + shared attn 32H
(kv=32) with d_ff=10240 MLP, vocab=32000 [arXiv:2411.15242; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,          # shared block applied 9x
    tie_embeddings=True,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
