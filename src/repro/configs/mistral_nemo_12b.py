"""mistral-nemo-12b [dense]: 128k-context dense transformer.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Mistral-Nemo-Base-2407; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,          # nemo: head_dim 128 (not d_model/n_heads=160)
    rope_theta=1000000.0,
    tie_embeddings=False,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
