"""mistral-large-123b [dense].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1000000.0,
    tie_embeddings=False,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
