"""rwkv6-1.6b (Finch) [ssm]: attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # wkv heads = d_model / ssm_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    ssm_head_dim=64,
    tie_embeddings=True,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
