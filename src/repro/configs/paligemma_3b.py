"""paligemma-3b [vlm]: SigLIP frontend (STUB: precomputed patch embeddings)
+ gemma LM backbone.

18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216
[arXiv:2407.07726; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,          # gemma-2b: 8 heads x 256
    tie_embeddings=True,
    n_prefix_tokens=256,   # 224x224 / 14x14 SigLIP patches
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
