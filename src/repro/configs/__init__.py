"""Assigned architecture registry: one module per architecture."""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        h2o_danube_1_8b,
        qwen1_5_110b,
        mistral_nemo_12b,
        mistral_large_123b,
        paligemma_3b,
        qwen3_moe_235b_a22b,
        deepseek_v2_lite_16b,
        seamless_m4t_medium,
        zamba2_2_7b,
        rwkv6_1_6b,
    )
