"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, 64 routed experts
top-6 + 2 shared, first layer dense [arXiv:2405.04434; hf]

Assignment note: the bracket text mentions "160 routed" (full V2); the
primary spec "MoE 64e top-6" (V2-Lite) is authoritative here.
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    tie_embeddings=False,
    use_mla=True,
    kv_lora=512,
    qk_nope=128,
    qk_rope=64,
    v_head=128,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_shared=2816,      # 2 shared experts x 1408
    first_dense=1,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
