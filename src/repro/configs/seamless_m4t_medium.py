"""seamless-m4t-medium [audio]: enc-dec; audio frontend is a STUB
(input_specs provides precomputed frame embeddings).

12L enc + 12L dec, d_model=1024 16H d_ff=4096 vocab=256206
[arXiv:2308.11596; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,           # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    tie_embeddings=True,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
