"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf]
"""
from repro.configs import register
from repro.core.spec import LUTQ_4BIT_POW2
from repro.models.config import ModelConfig

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    window=4096,           # sliding-window attention (mistral heritage)
    rope_theta=10000.0,
    tie_embeddings=False,
    quant=LUTQ_4BIT_POW2,
    act_bits=8,
))
