"""Train state + the full LUT-Q train step (paper Table 1, steps 1-4).

The step composes:
  1/2. forward with tied weights Q = d[A] (STE) + backward -> dC/dQ
  3.   optimizer update of the full-precision masters W
  4.   M k-means iterations refreshing every (d, A) pair
plus framework features: microbatch gradient accumulation (lax.scan),
global-norm clipping, and optional error-feedback gradient compression
state (installed by the distributed layer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import kmeans_tree, merge_trainable, split_trainable
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm


@dataclasses.dataclass
class TrainState:
    trainable: Any          # float master weights (paper's W) + fp params
    static: Any             # LUT-Q (d, A) + integer buffers
    opt_state: Any
    step: jax.Array
    ef: Any = None          # error-feedback residuals (grad compression)

    def params(self):
        return merge_trainable(self.trainable, self.static)


def state_flat(state: TrainState):
    out = {"trainable": state.trainable, "static": state.static,
           "opt_state": state.opt_state, "step": state.step}
    if state.ef is not None:
        out["ef"] = state.ef
    return out


def state_unflat(d) -> TrainState:
    return TrainState(d["trainable"], d["static"], d["opt_state"], d["step"],
                      ef=d.get("ef"))


def init_train_state(params, optimizer: Optimizer, *,
                     grad_compress: bool = False) -> TrainState:
    """``grad_compress=True`` adds the error-feedback residual tree
    (zeros shaped like the trainable masters) that the compressed-DP
    ``grad_transform`` carries across steps."""
    trainable, static = split_trainable(params)
    ef = None
    if grad_compress:
        from repro.distributed.compress import init_ef_state
        ef = init_ef_state(trainable)
    return TrainState(
        trainable=trainable,
        static=static,
        opt_state=optimizer.init(trainable),
        step=jnp.zeros((), jnp.int32),
        ef=ef,
    )


def make_train_step(
    cfg: ModelConfig,
    loss_fn: Callable,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    clip_norm: Optional[float] = 1.0,
    grad_transform: Optional[Callable] = None,
    shardings: Optional[Dict[str, Any]] = None,
    kmeans_impl: Optional[str] = None,
):
    """Build the train step; jit-able, or already jitted when meshed.

    loss_fn(params, cfg, batch) -> (loss, metrics).
    grad_transform: optional hook ``(grads, ef) -> (grads, ef)`` — the
    compressed-DP gradient exchange built by
    ``repro.distributed.compress.dp_grad_transform`` (``ef`` is the
    state-carried error-feedback tree, ``None`` when compression is
    off).
    shardings: the dict from ``repro.launch.partition.train_shardings``
    ({"state": ..., "batch": ...} NamedSharding trees). When given, the
    returned function is jitted with explicit in/out shardings — the
    mesh-parallel SPMD train step; otherwise the caller jits (solo
    path, unchanged).
    kmeans_impl: force the step-4 implementation per
    ``repro.core.lutq.resolve_kmeans_impl`` (None = structural).
    """

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(state: Dict[str, Any], batch) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        trainable, static = state["trainable"], state["static"]

        def loss_of(t, mb):
            params = merge_trainable(t, static)
            loss, metrics = loss_fn(params, cfg, mb)
            return loss, metrics

        if microbatches > 1:
            micro = split_micro(batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(trainable, mb)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g,
                                     is_leaf=lambda x: x is None)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(lambda p: None if p is None else jnp.zeros_like(p),
                                 trainable, is_leaf=lambda x: x is None)
            (grads, loss_sum), _ = jax.lax.scan(accum, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: None if g is None else g / microbatches,
                                 grads, is_leaf=lambda x: x is None)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                trainable, batch)

        new_ef = state.get("ef")
        if grad_transform is not None:
            grads, new_ef = grad_transform(grads, new_ef)

        gn = jnp.zeros((), jnp.float32)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)

        # step 3: optimizer update of the masters
        new_trainable, new_opt = optimizer.update(grads, state["opt_state"],
                                                  trainable, state["step"])

        # step 4: k-means refresh of every (d, A), per-leaf spec via the
        # config's resolved policy (rule ids must line up with the ones
        # stamped at quantize time, hence resolved_policy not cfg.quant).
        # Under a mesh the segsum/stats formulations keep every op
        # elementwise-or-full-reduction, so the partitioner runs them on
        # the master's shards and combines per-shard sums/counts with one
        # psum — the dictionary update is exact with no gather.
        new_static = static
        if cfg.quant is not None:
            from repro.models.api import resolved_policy
            merged = merge_trainable(new_trainable, static)
            merged = kmeans_tree(merged, resolved_policy(cfg),
                                 impl=kmeans_impl)
            _, new_static = split_trainable(merged)

        new_state = {"trainable": new_trainable, "static": new_static,
                     "opt_state": new_opt, "step": state["step"] + 1}
        if "ef" in state:
            new_state["ef"] = new_ef
        out_metrics = {"loss": loss, "grad_norm": gn, **{k: v for k, v in
                       (metrics.items() if isinstance(metrics, dict) else [])}}
        return new_state, out_metrics

    if shardings is not None:
        return jax.jit(train_step,
                       in_shardings=(shardings["state"], shardings["batch"]),
                       out_shardings=(shardings["state"], None))
    return train_step
