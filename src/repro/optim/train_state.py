"""Train state + the full LUT-Q train step (paper Table 1, steps 1-4).

The step composes:
  1/2. forward with tied weights Q = d[A] (STE) + backward -> dC/dQ
  3.   optimizer update of the full-precision masters W
  4.   M k-means iterations refreshing every (d, A) pair
plus framework features: microbatch gradient accumulation (lax.scan),
global-norm clipping, and optional error-feedback gradient compression
state (installed by the distributed layer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import kmeans_tree, merge_trainable, split_trainable
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer, clip_by_global_norm


@dataclasses.dataclass
class TrainState:
    trainable: Any          # float master weights (paper's W) + fp params
    static: Any             # LUT-Q (d, A) + integer buffers
    opt_state: Any
    step: jax.Array

    def params(self):
        return merge_trainable(self.trainable, self.static)


def state_flat(state: TrainState):
    return {"trainable": state.trainable, "static": state.static,
            "opt_state": state.opt_state, "step": state.step}


def state_unflat(d) -> TrainState:
    return TrainState(d["trainable"], d["static"], d["opt_state"], d["step"])


def init_train_state(params, optimizer: Optimizer) -> TrainState:
    trainable, static = split_trainable(params)
    return TrainState(
        trainable=trainable,
        static=static,
        opt_state=optimizer.init(trainable),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg: ModelConfig,
    loss_fn: Callable,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
    clip_norm: Optional[float] = 1.0,
    grad_transform: Optional[Callable] = None,
):
    """Build the jit-able train step.

    loss_fn(params, cfg, batch) -> (loss, metrics).
    grad_transform: optional hook (grads -> grads), e.g. compressed
    all-reduce installed by the distributed layer.
    """

    def split_micro(batch):
        def r(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(r, batch)

    def train_step(state: Dict[str, Any], batch) -> Tuple[Dict[str, Any], Dict[str, jax.Array]]:
        trainable, static = state["trainable"], state["static"]

        def loss_of(t, mb):
            params = merge_trainable(t, static)
            loss, metrics = loss_fn(params, cfg, mb)
            return loss, metrics

        if microbatches > 1:
            micro = split_micro(batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(trainable, mb)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g,
                                     is_leaf=lambda x: x is None)
                return (g_acc, l_acc + l), None

            zeros = jax.tree.map(lambda p: None if p is None else jnp.zeros_like(p),
                                 trainable, is_leaf=lambda x: x is None)
            (grads, loss_sum), _ = jax.lax.scan(accum, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: None if g is None else g / microbatches,
                                 grads, is_leaf=lambda x: x is None)
            loss = loss_sum / microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(
                trainable, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        gn = jnp.zeros((), jnp.float32)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)

        # step 3: optimizer update of the masters
        new_trainable, new_opt = optimizer.update(grads, state["opt_state"],
                                                  trainable, state["step"])

        # step 4: k-means refresh of every (d, A), per-leaf spec via the
        # config's resolved policy (rule ids must line up with the ones
        # stamped at quantize time, hence resolved_policy not cfg.quant)
        new_static = static
        if cfg.quant is not None:
            from repro.models.api import resolved_policy
            merged = merge_trainable(new_trainable, static)
            merged = kmeans_tree(merged, resolved_policy(cfg))
            _, new_static = split_trainable(merged)

        new_state = {"trainable": new_trainable, "static": new_static,
                     "opt_state": new_opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gn, **{k: v for k, v in
                       (metrics.items() if isinstance(metrics, dict) else [])}}
        return new_state, out_metrics

    return train_step
