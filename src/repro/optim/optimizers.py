"""Functional optimizers (no optax dependency).

The paper's Table 1 uses SGD for step 3 ("here: SGD"); AdamW is provided
for the smaller archs. Optimizers operate on the *trainable* tree (LUT-Q
master weights + unquantized floats) produced by
``repro.core.policy.split_trainable``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, opt_state, params, step) -> (new_params, new_state)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees, is_leaf=lambda x: x is None)


def sgd(lr: Schedule, momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": _tmap(lambda p: None if p is None else jnp.zeros_like(p), params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)

        def eff_grad(g, p):
            return g + weight_decay * p if weight_decay else g

        if momentum == 0.0:
            new_p = _tmap(lambda g, p: p if p is None else p - lr_t * eff_grad(g, p),
                          grads, params)
            return new_p, state

        def new_m(g, m, p):
            return None if p is None else momentum * m + eff_grad(g, p)

        m2 = _tmap(new_m, grads, state["m"], params)

        def new_p(g, m, p):
            if p is None:
                return None
            d = eff_grad(g, p) + momentum * m if nesterov else m
            return p - lr_t * d

        p2 = _tmap(new_p, grads, m2, params)
        return p2, {"m": m2}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: None if p is None else jnp.zeros_like(p)
        return {"m": _tmap(z, params), "v": _tmap(z, params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        m2 = _tmap(lambda g, m: None if m is None else b1 * m + (1 - b1) * g,
                   grads, state["m"])
        v2 = _tmap(lambda g, v: None if v is None else b2 * v + (1 - b2) * g * g,
                   grads, state["v"])

        def new_p(p, m, v):
            if p is None:
                return None
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p
            return p - lr_t * upd

        p2 = _tmap(new_p, params, m2, v2)
        return p2, {"m": m2, "v": v2}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [g for g in jax.tree.leaves(grads) if g is not None]
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return _tmap(lambda g: None if g is None else g * scale, grads), gn


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return fn
