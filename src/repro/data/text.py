"""Byte-level text pipeline over local files (offline-friendly).

Concatenates files into one byte stream, yields deterministic host-
sharded (tokens, labels) windows. Vocab = 256 bytes (+ optional offset).
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Dict, Iterable, List

import numpy as np


def load_corpus(paths: Iterable[str], max_bytes: int = 8 << 20) -> np.ndarray:
    bufs: List[bytes] = []
    total = 0
    for p in sorted(map(str, paths)):
        try:
            b = Path(p).read_bytes()
        except OSError:
            continue
        bufs.append(b)
        total += len(b)
        if total >= max_bytes:
            break
    data = b"\n".join(bufs)[:max_bytes]
    if not data:
        raise ValueError("empty corpus")
    return np.frombuffer(data, dtype=np.uint8).astype(np.int32)


def default_corpus(root: str = ".") -> np.ndarray:
    """The framework's own source tree as a corpus (always available)."""
    paths = []
    for dirpath, _, files in os.walk(root):
        if any(part.startswith(".") for part in Path(dirpath).parts):
            continue
        for f in files:
            if f.endswith((".py", ".md", ".toml", ".txt")):
                paths.append(os.path.join(dirpath, f))
    return load_corpus(paths)


def byte_batch(corpus: np.ndarray, step: int, batch_size: int, seq_len: int,
               *, host_id: int = 0, num_hosts: int = 1, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic window sampling: sample i of step s is a pure
    function of (seed, s, i) -> resumable without state."""
    assert batch_size % num_hosts == 0
    per_host = batch_size // num_hosts
    n = len(corpus) - seq_len - 1
    idx = (np.arange(per_host) + host_id * per_host + step * batch_size)
    rs = np.random.Generator(np.random.PCG64(seed))
    # fixed random permutation base offset
    base = rs.integers(0, n)
    starts = (base + idx * 2654435761) % n  # Knuth multiplicative hash walk
    toks = np.stack([corpus[s:s + seq_len + 1] for s in starts])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
