"""Deterministic synthetic datasets with learnable structure.

LM stream: tokens follow a fixed random first-order Markov chain with a
low-entropy transition matrix, so the achievable CE is well below
log(V) and training curves show real learning. Vision set: procedurally
rendered shapes (class = shape x color quadrant) for the CIFAR-style
paper experiments. Both are pure-numpy, seeded, and infinitely indexable
(sample i is a pure function of (seed, i) -> deterministic resume).
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterator, Tuple

import numpy as np


def _rng(seed: int, *salts: int) -> np.random.Generator:
    h = hashlib.sha256(("/".join(map(str, (seed,) + salts))).encode()).digest()
    return np.random.Generator(np.random.PCG64(int.from_bytes(h[:8], "little")))


class MarkovLM:
    """First-order Markov chain over `vocab` tokens, temperature-controlled."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 4):
        self.vocab = vocab
        g = _rng(seed, 0xA)
        # each token transitions to `branching` preferred successors
        self.succ = g.integers(0, vocab, size=(vocab, branching))
        self.branching = branching

    def entropy_floor(self) -> float:
        """Achievable CE: uniform over `branching` successors (minus eps noise)."""
        return float(np.log(self.branching))

    def sample(self, seed: int, index: int, seq_len: int) -> np.ndarray:
        g = _rng(seed, 0xB, index)
        out = np.empty(seq_len + 1, np.int64)
        t = int(g.integers(0, self.vocab))
        for i in range(seq_len + 1):
            out[i] = t
            # 95% follow the chain, 5% jump uniformly (noise floor)
            if g.random() < 0.95:
                t = int(self.succ[t, int(g.integers(0, self.branching))])
            else:
                t = int(g.integers(0, self.vocab))
        return out

    def batch(self, seed: int, step: int, batch_size: int, seq_len: int,
              host_id: int = 0, num_hosts: int = 1) -> Dict[str, np.ndarray]:
        """Deterministic, host-sharded batch for global step `step`."""
        assert batch_size % num_hosts == 0
        per_host = batch_size // num_hosts
        toks = np.stack([
            self.sample(seed, step * batch_size + host_id * per_host + j, seq_len)
            for j in range(per_host)
        ])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def shapes_dataset(n: int, seed: int = 0, res: int = 16,
                   n_classes: int = 8) -> Tuple[np.ndarray, np.ndarray]:
    """Procedural image classification: class = (shape in {square, disc,
    cross, stripes}) x (color in {warm, cold}). Returns (x: (n,res,res,3)
    in [0,1], y: (n,))."""
    g = _rng(seed, 0xC)
    xs = np.zeros((n, res, res, 3), np.float32)
    ys = np.zeros((n,), np.int64)
    yy, xx = np.mgrid[0:res, 0:res]
    for i in range(n):
        cls = int(g.integers(0, n_classes))
        shape, warm = cls % 4, cls // 4
        cx, cy = g.uniform(res * 0.3, res * 0.7, 2)
        r = g.uniform(res * 0.18, res * 0.32)
        if shape == 0:
            m = (np.abs(xx - cx) < r) & (np.abs(yy - cy) < r)
        elif shape == 1:
            m = (xx - cx) ** 2 + (yy - cy) ** 2 < r ** 2
        elif shape == 2:
            m = (np.abs(xx - cx) < r * 0.35) | (np.abs(yy - cy) < r * 0.35)
            m &= ((xx - cx) ** 2 + (yy - cy) ** 2) < (1.6 * r) ** 2
        else:
            m = ((xx + yy) % 4 < 2) & (np.abs(xx - cx) < r) & (np.abs(yy - cy) < r)
        col = np.array([0.9, 0.3, 0.1]) if warm else np.array([0.1, 0.4, 0.9])
        col = col + g.normal(0, 0.05, 3)
        img = g.normal(0.45, 0.08, (res, res, 3))
        img[m] = col + g.normal(0, 0.03, (int(m.sum()), 3))
        xs[i] = np.clip(img, 0, 1)
        ys[i] = cls
    return xs, ys


def class_batches(xs, ys, batch: int, seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    g = _rng(seed, 0xD)
    n = len(xs)
    while True:
        idx = g.permutation(n)
        for s in range(0, n - batch + 1, batch):
            sel = idx[s:s + batch]
            yield {"x": xs[sel], "y": ys[sel].astype(np.int32)}
