"""Prefetching loader: overlaps host-side batch assembly with device compute."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator

import numpy as np


class Prefetcher:
    """Pulls batches from `make_batch(step)` on a background thread.

    Deterministic: batch for step s is always make_batch(s), whatever the
    prefetch depth — safe to resume after checkpoint restore by starting
    at the restored step.
    """

    def __init__(self, make_batch: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int = 0, depth: int = 2):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put((s, self._make(s)), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
