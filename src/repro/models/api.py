"""Family-dispatching model API.

One uniform surface over the five model families:
    init(key, cfg)                  -> (params, logical_axes)
    loss_fn(params, cfg, batch)     -> (loss, metrics)        [train_*]
    prefill(params, cfg, batch)     -> (logits, cache)        [prefill_*]
    decode_step(params, cfg, token, cache) -> (logits, cache) [decode_*/long_*]
    init_cache(cfg, batch, max_len) -> cache pytree
    input_specs(cfg, shape)         -> ShapeDtypeStruct batch for lowering

plus ``quantize`` to install LUT-Q state per the config's QuantSpec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import quantize_tree
from repro.models import encdec as m_encdec
from repro.models import lm as m_lm
from repro.models import rwkv as m_rwkv
from repro.models import zamba as m_zamba
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def init(key, cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return m_lm.init_lm(key, cfg)
    if cfg.family == "encdec":
        return m_encdec.init_encdec(key, cfg)
    if cfg.family == "hybrid":
        return m_zamba.init_zamba(key, cfg)
    if cfg.family == "ssm":
        return m_rwkv.init_rwkv(key, cfg)
    raise ValueError(cfg.family)


def init_struct(cfg: ModelConfig):
    """(params ShapeDtypeStruct tree, logical-axes tree) via eval_shape.

    One pass, no allocation — the shared capture for every consumer
    that needs structure without values (sharding assembly, checkpoint
    shape validation).
    """
    cap = {}

    def f(key):
        p, a = init(key, cfg)
        cap["axes"] = a
        return p

    struct = jax.eval_shape(f, jax.random.PRNGKey(0))
    return struct, cap["axes"]


def init_axes(cfg: ModelConfig):
    """Logical-axes tree only (see :func:`init_struct`) — used when
    params come from a checkpoint rather than :func:`init` but sharding
    decisions still need the logical names."""
    return init_struct(cfg)[1]


def resolved_policy(cfg: ModelConfig):
    """The effective QuantPolicy for a config (None = fp baseline).

    ``cfg.quant`` may be a bare QuantSpec (wrapped as a uniform policy)
    or a QuantPolicy; ``quantize_embed=False`` folds into a leading
    exclusion rule for embedding tables.
    """
    from repro.core.rules import EMBED_PATTERN, QuantRule, as_policy

    policy = as_policy(cfg.quant)
    if policy is None:
        return None
    if not cfg.quantize_embed:
        policy = policy.prepend(QuantRule(EMBED_PATTERN, None, name="embed-fp"))
    return policy


def quantize(params, cfg: ModelConfig, axes=None):
    """Install LUT-Q state on every eligible kernel (paper step 0)."""
    policy = resolved_policy(cfg)
    if policy is None:
        return params
    return quantize_tree(params, policy, axes=axes)


def calibrate(params, cfg: ModelConfig, batch, *, lengths=None):
    """Freeze activation scales from one short calibration batch.

    Tags every quantized leaf with its path, runs a prefill forward under
    :func:`repro.core.actquant.capture_act_scales` (per-leaf max|x| at
    each matmul boundary, recorded via runtime callbacks), then installs
    ``[scale, qmax]`` pairs into ``LutqState.act`` for every rule with
    ``act_frozen=True`` and ``act_bits < 32``. The pairs persist through
    ``serve_view`` and checkpoints; the pow2 backend uses them to
    int8-quantize activations without a runtime max-reduction.
    """
    from repro.core.actquant import (
        apply_act_scales,
        capture_act_scales,
        tag_act_capture,
    )

    tagged = tag_act_capture(params)
    with capture_act_scales() as record:
        out = prefill(tagged, cfg, batch, lengths=lengths)
        jax.block_until_ready(out)  # callbacks must land before we read
    return apply_act_scales(params, record, quant=resolved_policy(cfg))


def serve_state(key, cfg: ModelConfig, *, pack4: bool = False, mesh=None,
                with_manifest: bool = False, calib_batch=None,
                draft_bits: Optional[int] = None):
    """One-call deployment state: init -> quantize -> serve_view.

    Returns ``(serve_params, axes)`` (plus the backend manifest with
    ``with_manifest=True``). ``axes`` is the logical-axes tree — keep it
    around for sharding decisions. With ``mesh`` the tree comes back
    already placed on its serving NamedShardings (indices partitioned
    on the model axis, dictionaries replicated; see docs/sharding.md),
    ready for ``generate(..., mesh=)`` / ``Engine(..., mesh=)``.

    ``calib_batch``: optional prefill-shaped batch run through
    :func:`calibrate` before the serve view, freezing activation scales
    for ``act_frozen`` rules (the ``serving_pow2`` preset).

    ``draft_bits``: additionally build the coarse speculative-decoding
    view (:func:`draft_view`) of the serve tree and append it as the
    LAST element of the returned tuple — existing unpackings stay valid
    when the kwarg is omitted.
    """
    from repro.core.policy import serve_view

    params, axes = init(key, cfg)
    qparams = quantize(params, cfg, axes)
    if calib_batch is not None:
        qparams = calibrate(qparams, cfg, calib_batch)
    out = serve_view(qparams, pack4=pack4, policy=resolved_policy(cfg),
                     with_manifest=with_manifest, mesh=mesh, axes=axes)
    tree = out[0] if with_manifest else out
    res = [tree, axes]
    if with_manifest:
        res.append(out[1])
    if draft_bits is not None:
        res.append(draft_view(tree, draft_bits=draft_bits))
    return tuple(res)


def draft_view(params, *, draft_bits: int = 3, with_report: bool = False):
    """Coarse ``2**draft_bits``-entry view of a serve tree for
    self-speculative decoding (see :func:`repro.core.policy.draft_view`):
    same assignment indices, re-clustered dictionary — the draft model
    costs only a second tiny dictionary plus remapped/packed indices.
    fp trees pass through unchanged (draft == target)."""
    from repro.core.policy import draft_view as _draft_view

    return _draft_view(params, draft_bits=draft_bits, with_report=with_report)


def loss_fn(params, cfg: ModelConfig, batch):
    if cfg.family in ("dense", "moe", "vlm"):
        return m_lm.lm_loss(params, cfg, batch)
    if cfg.family == "encdec":
        return m_encdec.encdec_loss(params, cfg, batch)
    if cfg.family == "hybrid":
        return m_zamba.zamba_loss(params, cfg, batch)
    if cfg.family == "ssm":
        return m_rwkv.rwkv_loss(params, cfg, batch)
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, batch, *, max_len: Optional[int] = None,
            lengths=None):
    """Run the prompt, return (last_logits, cache).

    ``lengths``: optional per-stream (B,) prompt lengths for ragged
    (right-padded) batches — the returned logits are gathered at each
    stream's last *real* token and ``cache["len"]`` records the true
    lengths. Recurrent families (ssm/hybrid) gather logits correctly but
    their state still integrates padding tokens; ragged batches for
    those families should be prefilled per stream at exact length (see
    ``runtime.engine``).
    """
    if cfg.family in ("dense", "moe", "vlm"):
        return m_lm.lm_prefill(params, cfg, batch["tokens"],
                               prefix_embeds=batch.get("prefix_embeds"),
                               lengths=lengths)
    if cfg.family == "encdec":
        return m_encdec.encdec_prefill(params, cfg, batch["frames"],
                                       batch["tokens"], lengths=lengths)
    if cfg.family == "hybrid":
        return m_zamba.zamba_prefill(params, cfg, batch["tokens"],
                                     max_len or batch["tokens"].shape[1],
                                     lengths=lengths)
    if cfg.family == "ssm":
        return m_rwkv.rwkv_prefill(params, cfg, batch["tokens"],
                                   lengths=lengths)
    raise ValueError(cfg.family)


def decode_step(params, cfg: ModelConfig, token, cache):
    if cfg.family in ("dense", "moe", "vlm"):
        return m_lm.lm_decode_step(params, cfg, token, cache)
    if cfg.family == "encdec":
        return m_encdec.encdec_decode_step(params, cfg, token, cache)
    if cfg.family == "hybrid":
        return m_zamba.zamba_decode_step(params, cfg, token, cache)
    if cfg.family == "ssm":
        return m_rwkv.rwkv_decode_step(params, cfg, token, cache)
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, src_len: int = 0):
    if cfg.family in ("dense", "moe", "vlm"):
        return m_lm.init_cache(cfg, batch, max_len)
    if cfg.family == "encdec":
        return m_encdec.init_encdec_cache(cfg, batch, max_len, src_len or max_len)
    if cfg.family == "hybrid":
        return m_zamba.init_zamba_cache(cfg, batch, max_len)
    if cfg.family == "ssm":
        return m_rwkv.init_rwkv_state(cfg, batch)
    raise ValueError(cfg.family)


def paged_supported(cfg: ModelConfig) -> bool:
    """Families the paged KV subsystem serves (see runtime/paged_kv.py).

    Dense-attention LMs (GQA/SWA/qk-norm, fp or int8 KV) and encdec page
    their growing self-attn KV. Everything else — fixed-size recurrent
    state (ssm/hybrid), MLA's latent cache, MoE/prefix-layer caches —
    keeps the slot path behind the same Engine API; the engine falls
    back silently and reports it in ``stats()["paged"]``.
    """
    if cfg.family == "encdec":
        return True
    return (cfg.family == "dense" and not cfg.use_mla and cfg.n_experts == 0
            and cfg.first_dense == 0 and cfg.n_prefix_tokens == 0)


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, n_blocks: int, *, src_len: int = 0):
    if cfg.family == "encdec":
        return m_encdec.init_paged_encdec_cache(cfg, batch, n_pages,
                                                page_size, n_blocks, src_len)
    if paged_supported(cfg):
        return m_lm.init_paged_cache(cfg, batch, n_pages, page_size, n_blocks)
    raise ValueError(f"paged KV unsupported for family={cfg.family} "
                     "(use api.paged_supported to gate)")


def paged_decode_step(params, cfg: ModelConfig, token, cache, mesh=None):
    # ``mesh`` (meshed serving jits only) lets the paged-attention kernel
    # shard_map over ("data","model") so KV-head-sharded pools stay local
    if cfg.family == "encdec":
        return m_encdec.encdec_paged_decode_step(params, cfg, token, cache,
                                                 mesh=mesh)
    return m_lm.lm_paged_decode_step(params, cfg, token, cache, mesh=mesh)


def speculative_supported(cfg: ModelConfig) -> Tuple[bool, str]:
    """Whether self-speculative decoding can serve this config.

    Speculation needs (a) rollback to be a pure cache-length truncation
    — recurrent state (ssm/hybrid) and MLA's latent cache cannot rewind
    a rejected token — and (b) the k+1-token verify window to be
    row/position-independent so one batched forward is bitwise identical
    to chained single-token steps: MoE routing/capacity couples the
    flattened token batch, and dynamic activation quantization takes
    per-*tensor* fake-quant scales that couple draft and verify rows
    (exactly the packed-prefill coupling PR 9 found). Returns
    ``(ok, reason)`` — the Engine raises the reason.
    """
    if cfg.act_bits < 32:
        return False, ("speculative decoding refused under activation "
                       "quantization: per-tensor act scales couple draft "
                       "and verify rows (act_bits < 32)")
    if cfg.family == "encdec":
        return True, ""
    if cfg.n_experts > 0 or cfg.family == "moe":
        return False, ("speculative decoding unsupported with MoE: "
                       "routing/capacity couples the verify-window token "
                       "batch, breaking per-position parity")
    if cfg.family not in ("dense", "vlm"):
        return False, (f"speculative decoding unsupported for family="
                       f"{cfg.family}: recurrent/hybrid state cannot rewind "
                       "rejected tokens")
    if cfg.use_mla:
        return False, ("speculative decoding unsupported with MLA: the "
                       "latent cache is not length-truncatable bitwise")
    return True, ""


def decode_window(params, cfg: ModelConfig, tokens, cache):
    """Verify-window forward: (B, W) tokens against a cache at length n.

    Returns ``(logits (B, W, V), cache)`` with ``cache["len"] = n + W``
    and positions n..n+W-1 holding the window's KV — bitwise identical
    to W chained :func:`decode_step` calls (weight matmuls are batched
    over the window, ONE weight stream; attention is replayed
    per-position against the incrementally scattered cache).
    """
    if cfg.family == "encdec":
        return m_encdec.encdec_decode_window(params, cfg, tokens, cache)
    return m_lm.lm_decode_window(params, cfg, tokens, cache)


def paged_decode_window(params, cfg: ModelConfig, tokens, cache, mesh=None):
    """Paged-pool variant of :func:`decode_window`."""
    if cfg.family == "encdec":
        return m_encdec.encdec_paged_decode_window(params, cfg, tokens, cache,
                                                   mesh=mesh)
    return m_lm.lm_paged_decode_window(params, cfg, tokens, cache, mesh=mesh)


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k requires sub-quadratic attention (see DESIGN.md).

    Runs for SSM/hybrid/SWA archs AND for MLA (deepseek): the latent
    cache is rank-512, so the 524k-token decode state and per-token
    compute stay small. Pure full-attention archs are skipped per the
    assignment (noted in DESIGN.md §4)."""
    if shape.name == "long_500k" and not (cfg.subquadratic or cfg.use_mla):
        return False, ("full quadratic attention: 524k-token KV cache decode "
                       "is skipped per assignment (see DESIGN.md §4)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for lowering (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"frames": sds((B, S, cfg.d_model), cfg.dtype),
                    "tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "vlm":
            # text seq shortened so prefix + text = S
            batch = {"tokens": sds((B, S - cfg.n_prefix_tokens), i32),
                     "labels": sds((B, S - cfg.n_prefix_tokens), i32),
                     "prefix_embeds": sds((B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)}
        return batch
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": sds((B, S, cfg.d_model), cfg.dtype),
                    "tokens": sds((B, S), i32)}
        if cfg.family == "vlm":
            return {"tokens": sds((B, S - cfg.n_prefix_tokens), i32),
                    "prefix_embeds": sds((B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)}
        return {"tokens": sds((B, S), i32)}
    # decode: one token + cache of seq_len
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, src_len=S if cfg.family == "encdec" else 0))
    return {"token": sds((B, 1), i32), "cache": cache}
