"""RWKV6 language model (attention-free, O(1) decode state)."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.linear import embedding_apply, embedding_init, embedding_logits
from repro.nn.norms import rmsnorm_apply, rmsnorm_init
from repro.nn.rwkv6 import rwkv6_channel_mix, rwkv6_init, rwkv6_time_mix
from repro.nn.tree import rng_stream


def _prepend(ax):
    if isinstance(ax, dict):
        return {k: _prepend(v) for k, v in ax.items()}
    return ("layer",) + tuple(ax)


def init_rwkv(key, cfg: ModelConfig):
    rs = rng_stream(key)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(next(rs), cfg.vocab, cfg.d_model)
    cap = {}

    def one(k):
        p, a = {}, {}
        p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model)
        p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model)
        p["mix"], a["mix"] = rwkv6_init(k, cfg.d_model, head_dim=cfg.ssm_head_dim,
                                        d_ff=cfg.d_ff)
        cap["ax"] = a
        return p

    params["layers"] = jax.vmap(one)(jax.random.split(next(rs), cfg.n_layers))
    axes["layers"] = _prepend(cap["ax"])
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, axes


def _layer(lp, cfg, h, state):
    t_out, t_state = rwkv6_time_mix(lp["mix"], rmsnorm_apply(lp["ln1"], h), state,
                                    head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                                    backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    h = h + t_out
    c_state = None if state is None else {"shift_c": state["shift_c"]}
    c_out, c_state = rwkv6_channel_mix(lp["mix"], rmsnorm_apply(lp["ln2"], h), c_state,
                                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    h = h + c_out
    return h, (t_state, c_state)


def rwkv_forward(params, cfg: ModelConfig, tokens):
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype)
    h = h.astype(jnp.float32)  # wkv runs f32; cheap at CPU-test scale

    def body(h, lp):
        h, _ = _layer(lp, cfg, h, None)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["layers"])
    h = rmsnorm_apply(params["final_norm"], h.astype(cfg.dtype))
    from repro.distributed.sharding import constrain
    return constrain(embedding_logits(params["embed"], h, backend=cfg.kernel_backend),
                     (("pod", "data"), None, "model"))


def rwkv_loss(params, cfg: ModelConfig, batch):
    logits = rwkv_forward(params, cfg, batch["tokens"]).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    loss = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


def init_rwkv_state(cfg: ModelConfig, batch: int):
    H = cfg.d_model // cfg.ssm_head_dim
    one = {
        "shift_t": jnp.zeros((batch, 1, cfg.d_model), jnp.float32),
        "shift_c": jnp.zeros((batch, 1, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32),
    }
    return {
        "layers": jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def rwkv_prefill(params, cfg: ModelConfig, tokens, *, lengths=None):
    """Consume prompt, return (last_logits, state).

    ``lengths``: per-stream real prompt lengths — logits are gathered at
    each stream's last real token. NOTE: the recurrent state still
    integrates right-padding tokens (there is no position to mask after
    the fact), so ragged batches should be prefilled per stream at exact
    length (``runtime.engine`` does this)."""
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype).astype(jnp.float32)
    B = h.shape[0]

    def body(h, lp):
        hs = {"shift_t": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
              "shift_c": jnp.zeros((B, 1, cfg.d_model), jnp.float32),
              "wkv": jnp.zeros((B, cfg.d_model // cfg.ssm_head_dim,
                                cfg.ssm_head_dim, cfg.ssm_head_dim), jnp.float32)}
        h, (ts, cs) = _layer(lp, cfg, h, hs)
        return h, {**ts, **cs}

    h, states = jax.lax.scan(body, h, params["layers"])
    from repro.models.lm import last_real_slice
    h_last = h[:, -1:] if lengths is None else last_real_slice(h, lengths)
    h_last = rmsnorm_apply(params["final_norm"], h_last.astype(cfg.dtype))
    logits = embedding_logits(params["embed"], h_last, backend=cfg.kernel_backend)
    cache_len = (jnp.full((B,), tokens.shape[1], jnp.int32) if lengths is None
                 else jnp.asarray(lengths, jnp.int32))
    return logits, {"layers": states, "len": cache_len}


def rwkv_decode_step(params, cfg: ModelConfig, token, state):
    h = embedding_apply(params["embed"], token, dtype=cfg.dtype).astype(jnp.float32)

    def body(h, xs):
        lp, ls = xs
        h, (ts, cs) = _layer(lp, cfg, h, ls)
        return h, {**ts, **cs}

    h, new_states = jax.lax.scan(body, h, (params["layers"], state["layers"]))
    logits = embedding_logits(params["embed"],
                              rmsnorm_apply(params["final_norm"], h.astype(cfg.dtype)),
                              backend=cfg.kernel_backend)
    return logits, {"layers": new_states, "len": state["len"] + 1}
