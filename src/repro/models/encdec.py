"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_src, D). We build the full enc-dec
stack: bidirectional encoder, causal decoder with cross-attention, shared
LUT-Q quantization policy across all projections.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import (attn_decode, attn_forward, attn_init, mlp_apply,
                             mlp_init, _qkv)
from repro.nn.attention import (decode_attention, flash_attention,
                                gather_pages, scatter_token_pages)
from repro.nn.linear import embedding_apply, embedding_init, embedding_logits, linear_apply, linear_init
from repro.nn.norms import rmsnorm_apply, rmsnorm_init
from repro.nn.tree import rng_stream


def cross_attn_init(key, cfg: ModelConfig):
    rs = rng_stream(key)
    dh = cfg.resolved_head_dim
    p, ax = {}, {}
    p["q"], ax["q"] = linear_init(next(rs), cfg.d_model, cfg.n_heads * dh, axes=("embed", "heads"))
    p["k"], ax["k"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh, axes=("embed", "kv_heads"))
    p["v"], ax["v"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh, axes=("embed", "kv_heads"))
    p["o"], ax["o"] = linear_init(next(rs), cfg.n_heads * dh, cfg.d_model, axes=("heads", "embed"))
    return p, ax


def cross_kv(p, cfg: ModelConfig, memory):
    B, Sm, _ = memory.shape
    dh = cfg.resolved_head_dim
    kb = cfg.kernel_backend
    k = linear_apply(p["k"], memory, backend=kb,
                     act_bits=cfg.act_bits).reshape(B, Sm, cfg.n_kv_heads, dh)
    v = linear_apply(p["v"], memory, backend=kb,
                     act_bits=cfg.act_bits).reshape(B, Sm, cfg.n_kv_heads, dh)
    return k, v


def cross_attn_apply(p, cfg: ModelConfig, x, k, v, *, src_len=None):
    """``src_len``: per-stream (B,) count of real memory positions —
    decode against a right-padded cross KV (engine slot pool) must not
    attend the zero padding."""
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = linear_apply(p["q"], x, backend=cfg.kernel_backend,
                     act_bits=cfg.act_bits).reshape(B, S, cfg.n_heads, dh)
    if S == 1:
        if src_len is None:
            src_len = jnp.full((B,), k.shape[1], jnp.int32)
        o = decode_attention(q, k, v, src_len)
    else:
        o = flash_attention(q, k, v, causal=False,
                            q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    return linear_apply(p["o"], o.reshape(B, S, -1),
                        backend=cfg.kernel_backend, act_bits=cfg.act_bits)


def _enc_layer_init(key, cfg: ModelConfig):
    rs = rng_stream(key)
    p, ax = {}, {}
    p["ln1"], ax["ln1"] = rmsnorm_init(cfg.d_model)
    p["ln2"], ax["ln2"] = rmsnorm_init(cfg.d_model)
    p["attn"], ax["attn"] = attn_init(next(rs), cfg)
    p["mlp"], ax["mlp"] = mlp_init(next(rs), cfg)
    return p, ax


def _dec_layer_init(key, cfg: ModelConfig):
    rs = rng_stream(key)
    p, ax = _enc_layer_init(next(rs), cfg)
    p["ln_x"], ax["ln_x"] = rmsnorm_init(cfg.d_model)
    p["xattn"], ax["xattn"] = cross_attn_init(next(rs), cfg)
    return p, ax


def _prepend(ax, name="layer"):
    if isinstance(ax, dict):
        return {k: _prepend(v, name) for k, v in ax.items()}
    return (name,) + tuple(ax)


def init_encdec(key, cfg: ModelConfig):
    rs = rng_stream(key)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(next(rs), cfg.vocab, cfg.d_model)
    cap = {}

    def enc_only(k):
        p, a = _enc_layer_init(k, cfg)
        cap["enc"] = a
        return p

    def dec_only(k):
        p, a = _dec_layer_init(k, cfg)
        cap["dec"] = a
        return p

    n_enc = cfg.enc_layers or cfg.n_layers
    params["encoder"] = jax.vmap(enc_only)(jax.random.split(next(rs), n_enc))
    axes["encoder"] = _prepend(cap["enc"])
    params["decoder"] = jax.vmap(dec_only)(jax.random.split(next(rs), cfg.n_layers))
    axes["decoder"] = _prepend(cap["dec"])
    params["enc_norm"], axes["enc_norm"] = rmsnorm_init(cfg.d_model)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, axes


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, S_src, D) precomputed embeddings (stub frontend)."""
    h = frames.astype(cfg.dtype)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(h, lp):
        a = attn_forward(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], h), positions)[0]
        h = h + a
        h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["encoder"])
    return rmsnorm_apply(params["enc_norm"], h)


def _dec_layer(lp, cfg, h, positions, xk, xv):
    a, cache = attn_forward(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], h), positions)
    h = h + a
    h = h + cross_attn_apply(lp["xattn"], cfg, rmsnorm_apply(lp["ln_x"], h), xk, xv)
    h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
    return h, cache


def encdec_loss(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: frames (B,Ss,D), tokens (B,St), labels (B,St)."""
    memory = encode(params, cfg, batch["frames"])
    h = embedding_apply(params["embed"], batch["tokens"], dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    positions = jnp.arange(h.shape[1])[None, :]

    def body(h, lp):
        xk, xv = cross_kv(lp["xattn"], cfg, memory)
        h, _ = _dec_layer(lp, cfg, h, positions, xk, xv)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["decoder"])
    from repro.distributed.sharding import constrain
    logits = embedding_logits(params["embed"], rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    logits = constrain(logits, (("pod", "data"), None, "model"))
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    loss = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, *, lengths=None):
    """Encode + run target prefix; returns (last_logits, cache).

    cache: self-attn KV per decoder layer + precomputed cross KV.
    ``lengths``: per-stream real *target* prompt lengths for ragged
    (right-padded) token batches — logits come from each stream's last
    real token. Frames are taken at face value (the encoder is
    bidirectional, so padded frames would corrupt real positions; ragged
    sources go through per-request prefill in ``runtime.engine``, which
    records the true width in ``cache["src_len"]``)."""
    memory = encode(params, cfg, frames)
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    B, St, _ = h.shape
    positions = jnp.arange(St)[None, :]

    def body(h, lp):
        xk, xv = cross_kv(lp["xattn"], cfg, memory)
        h, cache = _dec_layer(lp, cfg, h, positions, xk, xv)
        return h, {"k": cache["k"], "v": cache["v"], "xk": xk, "xv": xv}

    h, caches = jax.lax.scan(body, h, params["decoder"])
    from repro.models.lm import last_real_slice
    h_last = h[:, -1:] if lengths is None else last_real_slice(h, lengths)
    logits = embedding_logits(params["embed"],
                              rmsnorm_apply(params["final_norm"], h_last),
                              backend=cfg.kernel_backend)
    cache_len = (jnp.full((B,), St, jnp.int32) if lengths is None
                 else jnp.asarray(lengths, jnp.int32))
    return logits, {"layers": caches, "len": cache_len,
                    "src_len": jnp.full((B,), memory.shape[1], jnp.int32)}


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int):
    dh = cfg.resolved_head_dim
    one = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
        "xk": jnp.zeros((batch, src_len, cfg.n_kv_heads, dh), cfg.dtype),
        "xv": jnp.zeros((batch, src_len, cfg.n_kv_heads, dh), cfg.dtype),
    }
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
    return {"layers": stacked, "len": jnp.zeros((batch,), jnp.int32),
            "src_len": jnp.full((batch,), src_len, jnp.int32)}


def encdec_decode_step(params, cfg: ModelConfig, token, cache):
    h = embedding_apply(params["embed"], token, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    cache_len = cache["len"]
    src_len = cache.get("src_len")

    def body(h, xs):
        lp, lc = xs
        a, new_sc = attn_decode(lp["attn"], cfg, rmsnorm_apply(lp["ln1"], h), lc, cache_len)
        h = h + a
        h = h + cross_attn_apply(lp["xattn"], cfg, rmsnorm_apply(lp["ln_x"], h),
                                 lc["xk"], lc["xv"], src_len=src_len)
        h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
        return h, {**new_sc, "xk": lc["xk"], "xv": lc["xv"]}

    h, new_caches = jax.lax.scan(body, h, (params["decoder"], cache["layers"]))
    logits = embedding_logits(params["embed"], rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    out = {"layers": new_caches, "len": cache_len + 1}
    if src_len is not None:
        out["src_len"] = src_len
    return logits, out


def _cross_attn_window(p, cfg: ModelConfig, x, k, v, *, src_len=None):
    """W-token cross-attention for the speculative verify window: the
    q projection batches over the window; attention replays the S==1
    ``decode_attention`` branch of :func:`cross_attn_apply` per position
    so each row is bitwise identical to the single-token path (flash
    attention would not be)."""
    B, W, _ = x.shape
    dh = cfg.resolved_head_dim
    q = linear_apply(p["q"], x, backend=cfg.kernel_backend,
                     act_bits=cfg.act_bits).reshape(B, W, cfg.n_heads, dh)
    if src_len is None:
        src_len = jnp.full((B,), k.shape[1], jnp.int32)
    o = jnp.concatenate(
        [decode_attention(q[:, i:i + 1], k, v, src_len) for i in range(W)],
        axis=1)
    return linear_apply(p["o"], o.reshape(B, W, -1),
                        backend=cfg.kernel_backend, act_bits=cfg.act_bits)


def encdec_decode_window(params, cfg: ModelConfig, tokens, cache):
    """tokens: (B, W) -> (logits (B, W, V), cache at len+W) — the
    speculative verify window (see models/lm.py for the parity
    argument: batched weight matmuls, per-position attention replay)."""
    from repro.models.lm import attn_decode_window

    h = embedding_apply(params["embed"], tokens,
                        dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    cache_len = cache["len"]
    src_len = cache.get("src_len")
    W = tokens.shape[1]

    def body(h, xs):
        lp, lc = xs
        a, new_sc = attn_decode_window(lp["attn"], cfg,
                                       rmsnorm_apply(lp["ln1"], h), lc,
                                       cache_len)
        h = h + a
        h = h + _cross_attn_window(lp["xattn"], cfg,
                                   rmsnorm_apply(lp["ln_x"], h),
                                   lc["xk"], lc["xv"], src_len=src_len)
        h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
        return h, {**new_sc, "xk": lc["xk"], "xv": lc["xv"]}

    h, new_caches = jax.lax.scan(body, h, (params["decoder"], cache["layers"]))
    logits = embedding_logits(params["embed"],
                              rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    out = {"layers": new_caches, "len": cache_len + W}
    if src_len is not None:
        out["src_len"] = src_len
    return logits, out


# ---------------------------------------------------------------------------
# paged serving: self-attn KV in the page pool, cross KV dense per slot
# ---------------------------------------------------------------------------
#
# Cross-attention KV depends on the source frames, so it is never
# shareable across requests — it stays a (Ls, B, src_len, Hkv, dh)
# per-slot slab while the growing self-attn KV is paged. Pages hold
# cfg.dtype values (the encdec slot cache never quantizes either), so
# paged decode is bitwise-identical to the slot path. Prompts are
# admitted through one bucket-padded full prefill (the causal decoder
# makes right-padding exact), then spliced to pages.


def init_paged_encdec_cache(cfg: ModelConfig, batch: int, n_pages: int,
                            page_size: int, n_blocks: int, src_len: int):
    dh = cfg.resolved_head_dim
    one_page = jnp.zeros((n_pages, page_size, cfg.n_kv_heads, dh), cfg.dtype)
    one_x = jnp.zeros((batch, src_len, cfg.n_kv_heads, dh), cfg.dtype)
    stack = lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape)
    return {
        "pool": {"k": stack(one_page), "v": stack(one_page)},
        "xk": stack(one_x), "xv": stack(one_x),
        "block": jnp.zeros((batch, n_blocks), jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
        "src_len": jnp.zeros((batch,), jnp.int32),
    }


def encdec_paged_decode_step(params, cfg: ModelConfig, token, cache,
                             mesh=None):
    from repro.kernels.ops import paged_attention

    h = embedding_apply(params["embed"], token, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    cache_len, block, src_len = cache["len"], cache["block"], cache["src_len"]
    B = token.shape[0]

    def body(h, xs):
        lp, lpool, xk, xv = xs
        a_in = rmsnorm_apply(lp["ln1"], h)
        pos = jnp.broadcast_to(cache_len.reshape(-1), (B,)).reshape(B, 1)
        q, k, v = _qkv(lp["attn"], cfg, a_in, pos)
        idx = pos[:, 0]
        new_pool = {
            "k": scatter_token_pages(lpool["k"], block, idx, k[:, 0]),
            "v": scatter_token_pages(lpool["v"], block, idx, v[:, 0]),
        }
        # block-table walk (kernels/paged_attn.py) — the linear
        # (B, NB*page, ...) self-attn view is never assembled
        o = paged_attention(q, new_pool["k"], new_pool["v"], block, idx + 1,
                            mesh=mesh)
        a = linear_apply(lp["attn"]["o"], o.reshape(B, 1, -1),
                         backend=cfg.kernel_backend, act_bits=cfg.act_bits)
        h = h + a
        h = h + cross_attn_apply(lp["xattn"], cfg, rmsnorm_apply(lp["ln_x"], h),
                                 xk, xv, src_len=src_len)
        h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
        return h, new_pool

    h, new_pools = jax.lax.scan(
        body, h, (params["decoder"], cache["pool"], cache["xk"], cache["xv"]))
    logits = embedding_logits(params["embed"],
                              rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    out = dict(cache)
    out.update(pool=new_pools, len=cache_len + 1)
    return logits, out


def encdec_paged_decode_window(params, cfg: ModelConfig, tokens, cache,
                               mesh=None):
    """tokens: (B, W) -> (logits (B, W, V), cache at len+W) — paged
    speculative verify window (self-attn scatters + attends through the
    block table per position; cross-attn replays per position)."""
    from repro.kernels.ops import paged_attention

    h = embedding_apply(params["embed"], tokens,
                        dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    cache_len, block, src_len = cache["len"], cache["block"], cache["src_len"]
    B, W = tokens.shape
    pos = (jnp.broadcast_to(cache_len.reshape(-1), (B,)).reshape(B, 1)
           + jnp.arange(W)[None, :])

    def body(h, xs):
        lp, lpool, xk, xv = xs
        a_in = rmsnorm_apply(lp["ln1"], h)
        q, k, v = _qkv(lp["attn"], cfg, a_in, pos)
        new_pool = dict(lpool)
        outs = []
        for i in range(W):
            idx = pos[:, i]
            new_pool["k"] = scatter_token_pages(new_pool["k"], block, idx,
                                                k[:, i])
            new_pool["v"] = scatter_token_pages(new_pool["v"], block, idx,
                                                v[:, i])
            outs.append(paged_attention(q[:, i:i + 1], new_pool["k"],
                                        new_pool["v"], block, idx + 1,
                                        mesh=mesh))
        o = jnp.concatenate(outs, axis=1)
        a = linear_apply(lp["attn"]["o"], o.reshape(B, W, -1),
                         backend=cfg.kernel_backend, act_bits=cfg.act_bits)
        h = h + a
        h = h + _cross_attn_window(lp["xattn"], cfg,
                                   rmsnorm_apply(lp["ln_x"], h), xk, xv,
                                   src_len=src_len)
        h = h + mlp_apply(lp["mlp"], cfg, rmsnorm_apply(lp["ln2"], h))
        return h, new_pool

    h, new_pools = jax.lax.scan(
        body, h, (params["decoder"], cache["pool"], cache["xk"], cache["xv"]))
    logits = embedding_logits(params["embed"],
                              rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    out = dict(cache)
    out.update(pool=new_pools, len=cache_len + W)
    return logits, out


def encdec_paged_splice(cfg: ModelConfig, cache, prefill_layers, block_row,
                        length, slot):
    """Commit one request's prefill to the paged cache.

    prefill_layers: the (Ls, 1, St, ...) cache leaves from a
    bucket-padded ``encdec_prefill``; self-attn K/V positions
    [0, length) scatter through ``block_row`` (padding lands on the
    trash page), cross KV is right-padded into the slot's row of the
    dense slab. Returns the updated cache pytree (block/len/src_len rows
    are installed host-side by the engine)."""
    pool = cache["pool"]
    page = pool["k"].shape[2]
    NB = block_row.shape[0]
    St = prefill_layers["k"].shape[2]
    pos = jnp.arange(St)
    valid = pos < jnp.asarray(length, jnp.int32)
    phys = jnp.where(valid, block_row[jnp.clip(pos // page, 0, NB - 1)], 0)
    flat_idx = phys * page + pos % page

    def per_layer(pk, pv, k, v):
        P = pk.shape[0]
        def scat(leaf, vals):
            flat = leaf.reshape((P * page,) + leaf.shape[2:])
            return flat.at[flat_idx].set(vals.astype(leaf.dtype)).reshape(
                leaf.shape)
        return scat(pk, k[0]), scat(pv, v[0])

    nk, nv = jax.vmap(per_layer)(pool["k"], pool["v"],
                                 prefill_layers["k"], prefill_layers["v"])
    S_slab = cache["xk"].shape[2]
    s = prefill_layers["xk"].shape[2]
    pad = ((0, 0), (0, 0), (0, S_slab - s), (0, 0), (0, 0))
    xk = jax.lax.dynamic_update_slice(
        cache["xk"], jnp.pad(prefill_layers["xk"], pad).astype(
            cache["xk"].dtype), (0, jnp.asarray(slot, jnp.int32), 0, 0, 0))
    xv = jax.lax.dynamic_update_slice(
        cache["xv"], jnp.pad(prefill_layers["xv"], pad).astype(
            cache["xv"].dtype), (0, jnp.asarray(slot, jnp.int32), 0, 0, 0))
    out = dict(cache)
    out.update(pool={"k": nk, "v": nv}, xk=xk, xv=xv)
    return out
