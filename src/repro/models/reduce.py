"""Reduced configs: same family/features, tiny dims — for CPU smoke tests."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig


def reduced(cfg: ModelConfig, *, seq_friendly: bool = True) -> ModelConfig:
    """Shrink a config to CPU scale while preserving every structural
    feature (GQA ratio, SWA, MLA, MoE routing, shared blocks, enc-dec...)."""
    kw = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 4)) or 1,
        d_ff=96,
        vocab=211,
        head_dim=16,
        dtype=jnp.float32,
        attn_q_block=16,
        attn_kv_block=16,
        ssm_chunk=8,
        remat=cfg.remat,
    )
    if cfg.window is not None:
        kw["window"] = 24
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), capacity_factor=4.0)
        if cfg.n_shared_experts:
            kw.update(n_shared_experts=1, d_ff_shared=96)
        if cfg.first_dense:
            kw.update(first_dense=1, n_layers=3)
    if cfg.use_mla:
        kw.update(kv_lora=24, qk_nope=16, qk_rope=8, v_head=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, attn_every=2, d_inner=128, ssm_state=16,
                  ssm_head_dim=16, n_kv_heads=4)
    if cfg.family == "ssm":
        kw.update(ssm_head_dim=16, d_ff=128)
    if cfg.family == "encdec":
        kw.update(enc_layers=2)
    if cfg.family == "vlm":
        kw.update(n_prefix_tokens=8)
    return cfg.replace(**kw)
