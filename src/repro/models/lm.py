"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Assembled from the nn layer library with scan-over-layers (stacked
params) so HLO size and compile time are O(1) in depth. Every projection
kernel and the embedding table are LUT-Q quantizable via the policy in
``repro.core.policy``; activation fake-quant (paper: uniform 8-bit) is
applied at the input of each quantized matmul.

Three entry points per the launch shapes:
  lm_loss         -> train_4k       (next-token CE, full seq)
  lm_prefill      -> prefill_32k    (builds the KV cache)
  lm_decode_step  -> decode_32k / long_500k (one token vs. cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.actquant import fake_quant
from repro.models.config import ModelConfig
from repro.nn.attention import decode_attention, flash_attention
from repro.nn.linear import (
    embedding_apply,
    embedding_init,
    embedding_logits,
    linear_apply,
    linear_init,
)
from repro.nn.mla import mla_decode, mla_forward, mla_init
from repro.nn.moe import moe_apply, moe_init
from repro.nn.norms import rmsnorm_apply, rmsnorm_init
from repro.nn.rotary import apply_rope
from repro.nn.tree import rng_stream


def _aq(x, cfg: ModelConfig):
    return fake_quant(x, cfg.act_bits) if cfg.act_bits < 32 else x


# ---------------------------------------------------------------------------
# attention sub-block (GQA / SWA / qk-norm / bias); MLA handled separately
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    rs = rng_stream(key)
    dh = cfg.resolved_head_dim
    p, ax = {}, {}
    p["q"], ax["q"] = linear_init(next(rs), cfg.d_model, cfg.n_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "heads"))
    p["k"], ax["k"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "kv_heads"))
    p["v"], ax["v"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "kv_heads"))
    p["o"], ax["o"] = linear_init(next(rs), cfg.n_heads * dh, cfg.d_model,
                                  axes=("heads", "embed"))
    if cfg.use_qk_norm:
        p["q_norm"], ax["q_norm"] = rmsnorm_init(dh)
        p["k_norm"], ax["k_norm"] = rmsnorm_init(dh)
    return p, ax


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    x = _aq(x, cfg)
    kb = cfg.kernel_backend
    q = linear_apply(p["q"], x, backend=kb).reshape(B, S, cfg.n_heads, dh)
    k = linear_apply(p["k"], x, backend=kb).reshape(B, S, cfg.n_kv_heads, dh)
    v = linear_apply(p["v"], x, backend=kb).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.use_qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(p, cfg: ModelConfig, x, positions, *, prefix=None):
    """Training/prefill attention. Returns (out, {"k","v"})."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(
        q, k, v, causal=True, window=cfg.window, prefix=prefix,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    out = linear_apply(p["o"], _aq(o.reshape(B, S, -1), cfg),
                       backend=cfg.kernel_backend)
    return out, {"k": k, "v": v}


def _kv_quant(t, bits):
    """Per-(batch,pos,head) symmetric int8 quant of one new KV entry."""
    amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.bfloat16)


def attn_decode(p, cfg: ModelConfig, x, cache, cache_len):
    """One-token decode. With SWA the cache is a ring buffer of width
    `window` (slot = position % window) — O(window) memory at any context
    length, which is what makes danube's long_500k cell runnable.

    With ``kv_cache_bits=8`` the cache holds int8 KV + per-entry bf16
    scales: decode is HBM-bound on cache reads, so this halves the
    dominant roofline term (§Perf cell C) — the paper's 8-bit-activation
    policy applied to the KV cache."""
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    pos = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,)).reshape(B, 1)
    q, k, v = _qkv(p, cfg, x, pos)
    idx = pos[:, 0]
    eff = cache["k"].shape[1]
    quant = cfg.kv_cache_bits == 8
    if quant:
        k, k_s = _kv_quant(k, 8)
        v, v_s = _kv_quant(v, 8)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
    ring = cfg.window is not None and eff <= cfg.window
    slot = idx % eff if ring else idx
    kc = upd(cache["k"], k, slot)
    vc = upd(cache["v"], v, slot)
    new_cache = {"k": kc, "v": vc}
    if quant:
        ks = upd(cache["k_scale"], k_s, slot)
        vs = upd(cache["v_scale"], v_s, slot)
        new_cache.update(k_scale=ks, v_scale=vs)
        kc = kc.astype(jnp.bfloat16) * ks[..., None]
        vc = vc.astype(jnp.bfloat16) * vs[..., None]
    if ring:
        filled = jnp.minimum(idx + 1, eff)
        o = decode_attention(q, kc, vc, filled)  # all filled ring slots live
    else:
        o = decode_attention(q, kc, vc, idx + 1, window=cfg.window)
    out = linear_apply(p["o"], _aq(o.reshape(B, 1, -1), cfg),
                       backend=cfg.kernel_backend)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP / layer
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff=None):
    rs = rng_stream(key)
    d_ff = d_ff or cfg.d_ff
    p, ax = {}, {}
    p["wi"], ax["wi"] = linear_init(next(rs), cfg.d_model, d_ff, axes=("embed", "mlp"))
    p["wg"], ax["wg"] = linear_init(next(rs), cfg.d_model, d_ff, axes=("embed", "mlp"))
    p["wo"], ax["wo"] = linear_init(next(rs), d_ff, cfg.d_model, axes=("mlp", "embed"))
    return p, ax


def mlp_apply(p, cfg: ModelConfig, x):
    x = _aq(x, cfg)
    kb = cfg.kernel_backend
    h = (linear_apply(p["wi"], x, backend=kb)
         * jax.nn.silu(linear_apply(p["wg"], x, backend=kb)))
    return linear_apply(p["wo"], _aq(h, cfg), backend=kb)


def layer_init(key, cfg: ModelConfig, *, moe: bool):
    rs = rng_stream(key)
    p, ax = {}, {}
    p["ln1"], ax["ln1"] = rmsnorm_init(cfg.d_model)
    p["ln2"], ax["ln2"] = rmsnorm_init(cfg.d_model)
    if cfg.use_mla:
        p["attn"], ax["attn"] = mla_init(
            next(rs), cfg.d_model, cfg.n_heads, kv_lora=cfg.kv_lora,
            qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope, v_head=cfg.v_head)
    else:
        p["attn"], ax["attn"] = attn_init(next(rs), cfg)
    if moe:
        p["moe"], ax["moe"] = moe_init(
            next(rs), cfg.d_model, cfg.d_ff, cfg.n_experts,
            n_shared=cfg.n_shared_experts, d_ff_shared=cfg.d_ff_shared)
    else:
        p["mlp"], ax["mlp"] = mlp_init(key=next(rs), cfg=cfg,
                                       d_ff=cfg.d_ff if cfg.n_experts == 0 else None)
    return p, ax


def layer_forward(p, cfg: ModelConfig, h, positions, *, prefix=None):
    """Returns (h, cache, aux_loss)."""
    a_in = rmsnorm_apply(p["ln1"], h)
    if cfg.use_mla:
        a_out, cache = mla_forward(
            p["attn"], a_in, positions, n_heads=cfg.n_heads, kv_lora=cfg.kv_lora,
            qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope, v_head=cfg.v_head,
            backend=cfg.kernel_backend)
    else:
        a_out, cache = attn_forward(p["attn"], cfg, a_in, positions, prefix=prefix)
    h = h + a_out
    m_in = rmsnorm_apply(p["ln2"], h)
    if "moe" in p:
        m_out, aux = moe_apply(p["moe"], m_in, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               backend=cfg.kernel_backend)
    else:
        m_out, aux = mlp_apply(p["mlp"], cfg, m_in), jnp.zeros((), jnp.float32)
    return h + m_out, cache, aux


def layer_decode(p, cfg: ModelConfig, h, cache, cache_len):
    a_in = rmsnorm_apply(p["ln1"], h)
    if cfg.use_mla:
        a_out, new_cache = mla_decode(
            p["attn"], a_in, cache, cache_len, n_heads=cfg.n_heads,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_head=cfg.v_head, backend=cfg.kernel_backend)
    else:
        a_out, new_cache = attn_decode(p["attn"], cfg, a_in, cache, cache_len)
    h = h + a_out
    m_in = rmsnorm_apply(p["ln2"], h)
    if "moe" in p:
        m_out, _ = moe_apply(p["moe"], m_in, top_k=cfg.top_k,
                             capacity_factor=max(cfg.capacity_factor, 2.0),
                             backend=cfg.kernel_backend)
    else:
        m_out = mlp_apply(p["mlp"], cfg, m_in)
    return h + m_out, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _prepend_layer_axis(ax):
    if isinstance(ax, dict):
        return {k: _prepend_layer_axis(v) for k, v in ax.items()}
    return ("layer",) + tuple(ax)


def init_lm(key, cfg: ModelConfig):
    rs = rng_stream(key)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(next(rs), cfg.vocab, cfg.d_model)
    n_scan = cfg.n_layers - cfg.first_dense
    moe = cfg.n_experts > 0

    if cfg.first_dense:
        sub_p, sub_a = {}, {}
        for i in range(cfg.first_dense):
            sub_p[str(i)], sub_a[str(i)] = layer_init(next(rs), cfg, moe=False)
        params["prefix_layers"], axes["prefix_layers"] = sub_p, sub_a

    keys = jax.random.split(next(rs), n_scan)
    captured = {}

    def only_params(k):
        p, a = layer_init(k, cfg, moe=moe)
        captured["axes"] = a  # metadata identical across layers
        return p

    params["layers"] = jax.vmap(only_params)(keys)
    axes["layers"] = _prepend_layer_axis(captured["axes"])

    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = linear_init(
            next(rs), cfg.d_model, cfg.vocab, axes=("embed", "vocab"))
    return params, axes


def _embed_tokens(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    from repro.distributed.sharding import constrain
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype)
    h = h * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(cfg.dtype), h], axis=1)
    # pin the gather output to batch-sharded before the layer stack —
    # avoids SPMD's replicate-then-repartition fallback at the gather
    return constrain(h, (("pod", "data"), None, None))


def _readout(params, cfg: ModelConfig, h):
    from repro.distributed.sharding import constrain
    h = rmsnorm_apply(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = embedding_logits(params["embed"], h,
                                  backend=cfg.kernel_backend)
    else:
        logits = linear_apply(params["lm_head"], h,
                              backend=cfg.kernel_backend)
    # vocab-shard the logits (softmax/CE partition fine over a sharded
    # vocab); crucial for tied embeddings whose table keeps vocab
    # unsharded for gather friendliness
    return constrain(logits, (("pod", "data"), None, "model"))


def remat_wrap(body, cfg: ModelConfig):
    """Apply the config's remat policy to a scan body.

    'full': recompute everything in backward (min memory, +1x fwd FLOPs);
    'dots': save matmul outputs, recompute elementwise only (§Perf cell A
    — cuts the 4x-fwd train FLOP factor to ~3x for matmul-dominated
    layers at the cost of storing per-layer dot outputs);
    'none': no remat (store everything)."""
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _scan_layers(params, cfg: ModelConfig, h, positions, prefix=None,
                 want_cache: bool = False):
    """Returns (h, stacked_cache | None, total_aux)."""

    def body(carry, layer_p):
        h, aux = carry
        h, cache, a = layer_forward(layer_p, cfg, h, positions, prefix=prefix)
        return (h, aux + a), (cache if want_cache else None)

    body_fn = remat_wrap(body, cfg)
    (h, aux), caches = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    return h, caches, aux


def lm_forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None):
    """Full forward -> (logits, aux). tokens: (B, S_text)."""
    prefix = cfg.n_prefix_tokens if prefix_embeds is not None else None
    h = _embed_tokens(params, cfg, tokens, prefix_embeds)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.first_dense:
        for i in range(cfg.first_dense):
            h, _, a = layer_forward(params["prefix_layers"][str(i)], cfg, h,
                                    positions, prefix=prefix)
            aux_total += a
    h, _, aux = _scan_layers(params, cfg, h, positions, prefix=prefix)
    return _readout(params, cfg, h), aux_total + aux


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE. batch: tokens (B,S), labels (B,S) with -100 = ignore,
    optional prefix_embeds (B,P,D)."""
    logits, aux = lm_forward(params, cfg, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"))
    labels = batch["labels"]
    if cfg.n_prefix_tokens and "prefix_embeds" in batch:
        logits = logits[:, cfg.n_prefix_tokens:]
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / ntok
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "ntok": ntok}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked decode cache for the scanned layers (+ per-prefix-layer)."""
    n_scan = cfg.n_layers - cfg.first_dense
    dh = cfg.resolved_head_dim
    if cfg.use_mla:
        one = {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), cfg.dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope), cfg.dtype),
        }
    else:
        eff = min(max_len, cfg.window) if cfg.window else max_len
        kv_dt = jnp.int8 if cfg.kv_cache_bits == 8 else cfg.dtype
        one = {
            "k": jnp.zeros((batch, eff, cfg.n_kv_heads, dh), kv_dt),
            "v": jnp.zeros((batch, eff, cfg.n_kv_heads, dh), kv_dt),
        }
        if cfg.kv_cache_bits == 8:
            one["k_scale"] = jnp.zeros((batch, eff, cfg.n_kv_heads), jnp.bfloat16)
            one["v_scale"] = jnp.zeros((batch, eff, cfg.n_kv_heads), jnp.bfloat16)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one)
    out = {"layers": stacked, "len": jnp.zeros((batch,), jnp.int32)}
    if cfg.first_dense:
        out["prefix_layers"] = {str(i): jax.tree.map(jnp.copy, one)
                                for i in range(cfg.first_dense)}
    return out


def last_real_slice(h, lengths, offset: int = 0):
    """Gather (B,1,D) hidden states at each stream's last real token.

    ``lengths`` (B,) counts real text tokens; ``offset`` shifts for a
    prepended modality prefix that occupies leading positions."""
    idx = offset + jnp.asarray(lengths, jnp.int32) - 1
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)


def lm_prefill(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
               lengths=None):
    """Run the full prompt, return (last_logits, cache).

    ``lengths``: per-stream real prompt lengths for ragged (right-padded)
    batches — logits come from each stream's own last real position and
    ``cache["len"]`` records the true per-stream lengths, so decode
    continues every stream correctly, not just the longest one."""
    prefix = cfg.n_prefix_tokens if prefix_embeds is not None else None
    h = _embed_tokens(params, cfg, tokens, prefix_embeds)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    if lengths is None:
        cache_len = jnp.full((B,), S, jnp.int32)
    else:
        cache_len = jnp.asarray(lengths, jnp.int32) + (prefix or 0)
    cache: Dict[str, Any] = {"len": cache_len}
    if cfg.first_dense:
        pc = {}
        for i in range(cfg.first_dense):
            h, c, _ = layer_forward(params["prefix_layers"][str(i)], cfg, h,
                                    positions, prefix=prefix)
            pc[str(i)] = c
        cache["prefix_layers"] = pc
    h, caches, _ = _scan_layers(params, cfg, h, positions, prefix=prefix,
                                want_cache=True)
    cache["layers"] = caches
    h_last = (h[:, -1:] if lengths is None
              else last_real_slice(h, lengths, offset=prefix or 0))
    logits = _readout(params, cfg, h_last)
    return logits, cache


def lm_decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B,1) -> (logits (B,1,V), new cache)."""
    h = _embed_tokens(params, cfg, token)
    cache_len = cache["len"]
    if cfg.first_dense:
        new_pc = {}
        for i in range(cfg.first_dense):
            h, c = layer_decode(params["prefix_layers"][str(i)], cfg, h,
                                cache["prefix_layers"][str(i)], cache_len)
            new_pc[str(i)] = c
    def body(h, xs):
        layer_p, layer_c = xs
        h, new_c = layer_decode(layer_p, cfg, h, layer_c, cache_len)
        return h, new_c

    h, new_caches = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    logits = _readout(params, cfg, h)
    out = {"layers": new_caches, "len": cache_len + 1}
    if cfg.first_dense:
        out["prefix_layers"] = new_pc
    return logits, out
