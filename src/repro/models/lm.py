"""Decoder-only transformer LM (dense / MoE / VLM backbones).

Assembled from the nn layer library with scan-over-layers (stacked
params) so HLO size and compile time are O(1) in depth. Every projection
kernel and the embedding table are LUT-Q quantizable via the policy in
``repro.core.policy``; activation fake-quant (paper: uniform 8-bit) is
applied at the input of each quantized matmul.

Three entry points per the launch shapes:
  lm_loss         -> train_4k       (next-token CE, full seq)
  lm_prefill      -> prefill_32k    (builds the KV cache)
  lm_decode_step  -> decode_32k / long_500k (one token vs. cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.attention import (
    decode_attention,
    flash_attention,
    gather_pages,
    scatter_token_pages,
)
from repro.nn.linear import (
    embedding_apply,
    embedding_init,
    embedding_logits,
    linear_apply,
    linear_init,
)
from repro.nn.mla import mla_decode, mla_forward, mla_init
from repro.nn.moe import moe_apply, moe_init
from repro.nn.norms import rmsnorm_apply, rmsnorm_init
from repro.nn.rotary import apply_rope
from repro.nn.tree import rng_stream


# Activation quantization moved into the layer contract: model code
# passes ``act_bits=cfg.act_bits`` to linear/moe/conv applies and the
# kernel boundary (nn.linear._quant_act) picks dynamic fake-quant,
# frozen calibrated scales, or the pow2 backends' internal int8 path
# per leaf. Bit-identical to the old hand-placed ``_aq`` calls for
# dynamic scales (fake_quant is pure).


# ---------------------------------------------------------------------------
# attention sub-block (GQA / SWA / qk-norm / bias); MLA handled separately
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    rs = rng_stream(key)
    dh = cfg.resolved_head_dim
    p, ax = {}, {}
    p["q"], ax["q"] = linear_init(next(rs), cfg.d_model, cfg.n_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "heads"))
    p["k"], ax["k"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "kv_heads"))
    p["v"], ax["v"] = linear_init(next(rs), cfg.d_model, cfg.n_kv_heads * dh,
                                  bias=cfg.qkv_bias, axes=("embed", "kv_heads"))
    p["o"], ax["o"] = linear_init(next(rs), cfg.n_heads * dh, cfg.d_model,
                                  axes=("heads", "embed"))
    if cfg.use_qk_norm:
        p["q_norm"], ax["q_norm"] = rmsnorm_init(dh)
        p["k_norm"], ax["k_norm"] = rmsnorm_init(dh)
    return p, ax


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    kb, ab = cfg.kernel_backend, cfg.act_bits
    q = linear_apply(p["q"], x, backend=kb,
                     act_bits=ab).reshape(B, S, cfg.n_heads, dh)
    k = linear_apply(p["k"], x, backend=kb,
                     act_bits=ab).reshape(B, S, cfg.n_kv_heads, dh)
    v = linear_apply(p["v"], x, backend=kb,
                     act_bits=ab).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.use_qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(p, cfg: ModelConfig, x, positions, *, prefix=None):
    """Training/prefill attention. Returns (out, {"k","v"})."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    o = flash_attention(
        q, k, v, causal=True, window=cfg.window, prefix=prefix,
        q_block=cfg.attn_q_block, kv_block=cfg.attn_kv_block)
    out = linear_apply(p["o"], o.reshape(B, S, -1),
                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    return out, {"k": k, "v": v}


def _kv_quant(t, bits):
    """Per-(batch,pos,head) symmetric int8 quant of one new KV entry."""
    amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0].astype(jnp.bfloat16)


def attn_decode(p, cfg: ModelConfig, x, cache, cache_len):
    """One-token decode. With SWA the cache is a ring buffer of width
    `window` (slot = position % window) — O(window) memory at any context
    length, which is what makes danube's long_500k cell runnable.

    With ``kv_cache_bits=8`` the cache holds int8 KV + per-entry bf16
    scales: decode is HBM-bound on cache reads, so this halves the
    dominant roofline term (§Perf cell C) — the paper's 8-bit-activation
    policy applied to the KV cache."""
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    pos = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,)).reshape(B, 1)
    q, k, v = _qkv(p, cfg, x, pos)
    idx = pos[:, 0]
    eff = cache["k"].shape[1]
    quant = cfg.kv_cache_bits == 8
    if quant:
        k, k_s = _kv_quant(k, 8)
        v, v_s = _kv_quant(v, 8)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
    ring = cfg.window is not None and eff <= cfg.window
    slot = idx % eff if ring else idx
    kc = upd(cache["k"], k, slot)
    vc = upd(cache["v"], v, slot)
    new_cache = {"k": kc, "v": vc}
    if quant:
        ks = upd(cache["k_scale"], k_s, slot)
        vs = upd(cache["v_scale"], v_s, slot)
        new_cache.update(k_scale=ks, v_scale=vs)
        kc = kc.astype(jnp.bfloat16) * ks[..., None]
        vc = vc.astype(jnp.bfloat16) * vs[..., None]
    if ring:
        filled = jnp.minimum(idx + 1, eff)
        o = decode_attention(q, kc, vc, filled)  # all filled ring slots live
    else:
        o = decode_attention(q, kc, vc, idx + 1, window=cfg.window)
    out = linear_apply(p["o"], o.reshape(B, 1, -1),
                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP / layer
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff=None):
    rs = rng_stream(key)
    d_ff = d_ff or cfg.d_ff
    p, ax = {}, {}
    p["wi"], ax["wi"] = linear_init(next(rs), cfg.d_model, d_ff, axes=("embed", "mlp"))
    p["wg"], ax["wg"] = linear_init(next(rs), cfg.d_model, d_ff, axes=("embed", "mlp"))
    p["wo"], ax["wo"] = linear_init(next(rs), d_ff, cfg.d_model, axes=("mlp", "embed"))
    return p, ax


def mlp_apply(p, cfg: ModelConfig, x):
    kb, ab = cfg.kernel_backend, cfg.act_bits
    h = (linear_apply(p["wi"], x, backend=kb, act_bits=ab)
         * jax.nn.silu(linear_apply(p["wg"], x, backend=kb, act_bits=ab)))
    return linear_apply(p["wo"], h, backend=kb, act_bits=ab)


def layer_init(key, cfg: ModelConfig, *, moe: bool):
    rs = rng_stream(key)
    p, ax = {}, {}
    p["ln1"], ax["ln1"] = rmsnorm_init(cfg.d_model)
    p["ln2"], ax["ln2"] = rmsnorm_init(cfg.d_model)
    if cfg.use_mla:
        p["attn"], ax["attn"] = mla_init(
            next(rs), cfg.d_model, cfg.n_heads, kv_lora=cfg.kv_lora,
            qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope, v_head=cfg.v_head)
    else:
        p["attn"], ax["attn"] = attn_init(next(rs), cfg)
    if moe:
        p["moe"], ax["moe"] = moe_init(
            next(rs), cfg.d_model, cfg.d_ff, cfg.n_experts,
            n_shared=cfg.n_shared_experts, d_ff_shared=cfg.d_ff_shared)
    else:
        p["mlp"], ax["mlp"] = mlp_init(key=next(rs), cfg=cfg,
                                       d_ff=cfg.d_ff if cfg.n_experts == 0 else None)
    return p, ax


def layer_forward(p, cfg: ModelConfig, h, positions, *, prefix=None):
    """Returns (h, cache, aux_loss)."""
    a_in = rmsnorm_apply(p["ln1"], h)
    if cfg.use_mla:
        a_out, cache = mla_forward(
            p["attn"], a_in, positions, n_heads=cfg.n_heads, kv_lora=cfg.kv_lora,
            qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope, v_head=cfg.v_head,
            backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    else:
        a_out, cache = attn_forward(p["attn"], cfg, a_in, positions, prefix=prefix)
    h = h + a_out
    m_in = rmsnorm_apply(p["ln2"], h)
    if "moe" in p:
        m_out, aux = moe_apply(p["moe"], m_in, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               backend=cfg.kernel_backend,
                               act_bits=cfg.act_bits)
    else:
        m_out, aux = mlp_apply(p["mlp"], cfg, m_in), jnp.zeros((), jnp.float32)
    return h + m_out, cache, aux


def layer_decode(p, cfg: ModelConfig, h, cache, cache_len):
    a_in = rmsnorm_apply(p["ln1"], h)
    if cfg.use_mla:
        a_out, new_cache = mla_decode(
            p["attn"], a_in, cache, cache_len, n_heads=cfg.n_heads,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_head=cfg.v_head, backend=cfg.kernel_backend,
            act_bits=cfg.act_bits)
    else:
        a_out, new_cache = attn_decode(p["attn"], cfg, a_in, cache, cache_len)
    h = h + a_out
    m_in = rmsnorm_apply(p["ln2"], h)
    if "moe" in p:
        m_out, _ = moe_apply(p["moe"], m_in, top_k=cfg.top_k,
                             capacity_factor=max(cfg.capacity_factor, 2.0),
                             backend=cfg.kernel_backend,
                             act_bits=cfg.act_bits)
    else:
        m_out = mlp_apply(p["mlp"], cfg, m_in)
    return h + m_out, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _prepend_layer_axis(ax):
    if isinstance(ax, dict):
        return {k: _prepend_layer_axis(v) for k, v in ax.items()}
    return ("layer",) + tuple(ax)


def init_lm(key, cfg: ModelConfig):
    rs = rng_stream(key)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(next(rs), cfg.vocab, cfg.d_model)
    n_scan = cfg.n_layers - cfg.first_dense
    moe = cfg.n_experts > 0

    if cfg.first_dense:
        sub_p, sub_a = {}, {}
        for i in range(cfg.first_dense):
            sub_p[str(i)], sub_a[str(i)] = layer_init(next(rs), cfg, moe=False)
        params["prefix_layers"], axes["prefix_layers"] = sub_p, sub_a

    keys = jax.random.split(next(rs), n_scan)
    captured = {}

    def only_params(k):
        p, a = layer_init(k, cfg, moe=moe)
        captured["axes"] = a  # metadata identical across layers
        return p

    params["layers"] = jax.vmap(only_params)(keys)
    axes["layers"] = _prepend_layer_axis(captured["axes"])

    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = linear_init(
            next(rs), cfg.d_model, cfg.vocab, axes=("embed", "vocab"))
    return params, axes


def _embed_tokens(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    from repro.distributed.sharding import constrain
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype)
    h = h * (cfg.d_model ** 0.5)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(cfg.dtype), h], axis=1)
    # pin the gather output to batch-sharded before the layer stack —
    # avoids SPMD's replicate-then-repartition fallback at the gather
    return constrain(h, (("pod", "data"), None, None))


def _readout(params, cfg: ModelConfig, h):
    from repro.distributed.sharding import constrain
    h = rmsnorm_apply(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = embedding_logits(params["embed"], h,
                                  backend=cfg.kernel_backend)
    else:
        logits = linear_apply(params["lm_head"], h,
                              backend=cfg.kernel_backend)
    # vocab-shard the logits (softmax/CE partition fine over a sharded
    # vocab); crucial for tied embeddings whose table keeps vocab
    # unsharded for gather friendliness
    return constrain(logits, (("pod", "data"), None, "model"))


def remat_wrap(body, cfg: ModelConfig):
    """Apply the config's remat policy to a scan body.

    'full': recompute everything in backward (min memory, +1x fwd FLOPs);
    'dots': save matmul outputs, recompute elementwise only (§Perf cell A
    — cuts the 4x-fwd train FLOP factor to ~3x for matmul-dominated
    layers at the cost of storing per-layer dot outputs);
    'none': no remat (store everything)."""
    if not cfg.remat or cfg.remat_policy == "none":
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _scan_layers(params, cfg: ModelConfig, h, positions, prefix=None,
                 want_cache: bool = False):
    """Returns (h, stacked_cache | None, total_aux)."""

    def body(carry, layer_p):
        h, aux = carry
        h, cache, a = layer_forward(layer_p, cfg, h, positions, prefix=prefix)
        return (h, aux + a), (cache if want_cache else None)

    body_fn = remat_wrap(body, cfg)
    (h, aux), caches = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    return h, caches, aux


def lm_forward(params, cfg: ModelConfig, tokens, *, prefix_embeds=None):
    """Full forward -> (logits, aux). tokens: (B, S_text)."""
    prefix = cfg.n_prefix_tokens if prefix_embeds is not None else None
    h = _embed_tokens(params, cfg, tokens, prefix_embeds)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.first_dense:
        for i in range(cfg.first_dense):
            h, _, a = layer_forward(params["prefix_layers"][str(i)], cfg, h,
                                    positions, prefix=prefix)
            aux_total += a
    h, _, aux = _scan_layers(params, cfg, h, positions, prefix=prefix)
    return _readout(params, cfg, h), aux_total + aux


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            *, aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE. batch: tokens (B,S), labels (B,S) with -100 = ignore,
    optional prefix_embeds (B,P,D)."""
    logits, aux = lm_forward(params, cfg, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"))
    labels = batch["labels"]
    if cfg.n_prefix_tokens and "prefix_embeds" in batch:
        logits = logits[:, cfg.n_prefix_tokens:]
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    ntok = jnp.maximum(mask.sum(), 1.0)
    loss = ce.sum() / ntok
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux, "ntok": ntok}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked decode cache for the scanned layers (+ per-prefix-layer)."""
    n_scan = cfg.n_layers - cfg.first_dense
    dh = cfg.resolved_head_dim
    if cfg.use_mla:
        one = {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora), cfg.dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope), cfg.dtype),
        }
    else:
        eff = min(max_len, cfg.window) if cfg.window else max_len
        kv_dt = jnp.int8 if cfg.kv_cache_bits == 8 else cfg.dtype
        one = {
            "k": jnp.zeros((batch, eff, cfg.n_kv_heads, dh), kv_dt),
            "v": jnp.zeros((batch, eff, cfg.n_kv_heads, dh), kv_dt),
        }
        if cfg.kv_cache_bits == 8:
            one["k_scale"] = jnp.zeros((batch, eff, cfg.n_kv_heads), jnp.bfloat16)
            one["v_scale"] = jnp.zeros((batch, eff, cfg.n_kv_heads), jnp.bfloat16)
    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one)
    out = {"layers": stacked, "len": jnp.zeros((batch,), jnp.int32)}
    if cfg.first_dense:
        out["prefix_layers"] = {str(i): jax.tree.map(jnp.copy, one)
                                for i in range(cfg.first_dense)}
    return out


def last_real_slice(h, lengths, offset: int = 0):
    """Gather (B,1,D) hidden states at each stream's last real token.

    ``lengths`` (B,) counts real text tokens; ``offset`` shifts for a
    prepended modality prefix that occupies leading positions."""
    idx = offset + jnp.asarray(lengths, jnp.int32) - 1
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)


def lm_prefill(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
               lengths=None):
    """Run the full prompt, return (last_logits, cache).

    ``lengths``: per-stream real prompt lengths for ragged (right-padded)
    batches — logits come from each stream's own last real position and
    ``cache["len"]`` records the true per-stream lengths, so decode
    continues every stream correctly, not just the longest one."""
    prefix = cfg.n_prefix_tokens if prefix_embeds is not None else None
    h = _embed_tokens(params, cfg, tokens, prefix_embeds)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]
    if lengths is None:
        cache_len = jnp.full((B,), S, jnp.int32)
    else:
        cache_len = jnp.asarray(lengths, jnp.int32) + (prefix or 0)
    cache: Dict[str, Any] = {"len": cache_len}
    if cfg.first_dense:
        pc = {}
        for i in range(cfg.first_dense):
            h, c, _ = layer_forward(params["prefix_layers"][str(i)], cfg, h,
                                    positions, prefix=prefix)
            pc[str(i)] = c
        cache["prefix_layers"] = pc
    h, caches, _ = _scan_layers(params, cfg, h, positions, prefix=prefix,
                                want_cache=True)
    cache["layers"] = caches
    h_last = (h[:, -1:] if lengths is None
              else last_real_slice(h, lengths, offset=prefix or 0))
    logits = _readout(params, cfg, h_last)
    return logits, cache


def lm_decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B,1) -> (logits (B,1,V), new cache)."""
    h = _embed_tokens(params, cfg, token)
    cache_len = cache["len"]
    if cfg.first_dense:
        new_pc = {}
        for i in range(cfg.first_dense):
            h, c = layer_decode(params["prefix_layers"][str(i)], cfg, h,
                                cache["prefix_layers"][str(i)], cache_len)
            new_pc[str(i)] = c
    def body(h, xs):
        layer_p, layer_c = xs
        h, new_c = layer_decode(layer_p, cfg, h, layer_c, cache_len)
        return h, new_c

    h, new_caches = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    logits = _readout(params, cfg, h)
    out = {"layers": new_caches, "len": cache_len + 1}
    if cfg.first_dense:
        out["prefix_layers"] = new_pc
    return logits, out


# ---------------------------------------------------------------------------
# speculative verify window (multi-token decode; see runtime/speculative.py)
# ---------------------------------------------------------------------------
#
# The verify pass of self-speculative decoding runs W = k+1 tokens
# through ONE forward — the whole point: the target weights stream from
# HBM once per round instead of once per token. Weight matmuls (embed,
# qkv, o-proj, mlp, readout) batch over the window; XLA matmul rows are
# independent, so each window row is bit-identical to the (B, 1, ...)
# single-token call (the same row-independence argument the packed
# prefill path pins in its parity suite). Attention cannot batch — each
# window position attends a cache that includes the positions before it
# — so it replays the decode path per position: scatter position i's KV
# entry, then run the exact ``decode_attention`` sequence with that
# position's cache_len. The unrolled python loop is W iterations of
# O(1)-token work (W = k+1, small by construction).


def attn_decode_window(p, cfg: ModelConfig, x, cache, cache_len):
    """W-token decode window. x: (B, W, D); cache_len: (B,) base length.

    Writes positions cache_len..cache_len+W-1 into the cache (ring slots
    under SWA — callers snapshot/restore rolled-over columns, see
    runtime/speculative.py) and returns per-position attention outputs,
    each bitwise identical to W chained :func:`attn_decode` calls.
    """
    B, W, _ = x.shape
    pos = (jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,))
           .reshape(B, 1) + jnp.arange(W)[None, :])
    q, k, v = _qkv(p, cfg, x, pos)
    eff = cache["k"].shape[1]
    quant = cfg.kv_cache_bits == 8
    if quant:
        k, k_s = _kv_quant(k, 8)
        v, v_s = _kv_quant(v, 8)
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
    ring = cfg.window is not None and eff <= cfg.window
    kc, vc = cache["k"], cache["v"]
    ks = cache.get("k_scale")
    vs = cache.get("v_scale")
    outs = []
    for i in range(W):
        idx = pos[:, i]
        slot = idx % eff if ring else idx
        kc = upd(kc, k[:, i:i + 1], slot)
        vc = upd(vc, v[:, i:i + 1], slot)
        if quant:
            ks = upd(ks, k_s[:, i:i + 1], slot)
            vs = upd(vs, v_s[:, i:i + 1], slot)
            kd = kc.astype(jnp.bfloat16) * ks[..., None]
            vd = vc.astype(jnp.bfloat16) * vs[..., None]
        else:
            kd, vd = kc, vc
        if ring:
            o = decode_attention(q[:, i:i + 1], kd, vd,
                                 jnp.minimum(idx + 1, eff))
        else:
            o = decode_attention(q[:, i:i + 1], kd, vd, idx + 1,
                                 window=cfg.window)
        outs.append(o)
    o = jnp.concatenate(outs, axis=1)  # (B, W, H, dh)
    out = linear_apply(p["o"], o.reshape(B, W, -1),
                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    new_cache = {"k": kc, "v": vc}
    if quant:
        new_cache.update(k_scale=ks, v_scale=vs)
    return out, new_cache


def layer_decode_window(p, cfg: ModelConfig, h, cache, cache_len):
    """Window twin of :func:`layer_decode` (plain attention + MLP only —
    ``api.speculative_supported`` gates out MLA/MoE upstream)."""
    a_in = rmsnorm_apply(p["ln1"], h)
    a_out, new_cache = attn_decode_window(p["attn"], cfg, a_in, cache,
                                          cache_len)
    h = h + a_out
    m_in = rmsnorm_apply(p["ln2"], h)
    return h + mlp_apply(p["mlp"], cfg, m_in), new_cache


def lm_decode_window(params, cfg: ModelConfig, tokens, cache):
    """tokens: (B, W) -> (logits (B, W, V), cache at len+W)."""
    h = _embed_tokens(params, cfg, tokens)
    cache_len = cache["len"]
    W = tokens.shape[1]
    if cfg.first_dense:
        new_pc = {}
        for i in range(cfg.first_dense):
            h, c = layer_decode_window(params["prefix_layers"][str(i)], cfg, h,
                                       cache["prefix_layers"][str(i)],
                                       cache_len)
            new_pc[str(i)] = c

    def body(h, xs):
        layer_p, layer_c = xs
        h, new_c = layer_decode_window(layer_p, cfg, h, layer_c, cache_len)
        return h, new_c

    h, new_caches = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
    logits = _readout(params, cfg, h)
    out = {"layers": new_caches, "len": cache_len + W}
    if cfg.first_dense:
        out["prefix_layers"] = new_pc
    return logits, out


# ---------------------------------------------------------------------------
# paged serving (block-table KV; see runtime/paged_kv.py + docs/serving.md)
# ---------------------------------------------------------------------------
#
# The paged layout replaces per-slot (B, max_len, ...) caches with a
# global page pool (P, page, Hkv, dh) per stacked layer plus an int32
# block table (B, NB). Decode scatters the new K/V entry through the
# block table and gathers a linear (B, NB*page, ...) view for the same
# ``decode_attention`` the slot path uses — positions past ``len`` read
# the trash page and are masked, which on this backend is *bitwise*
# neutral (masked scores hit -1e30 before the row max, so their exp is
# exactly 0.0), making paged decode token-identical to the slot path.
#
# Chunked prefill never attends quantized pages: chunks write fp K/V
# into a transient workspace (Ls, 1, Wws, Hkv, dh) and attend that via
# ``flash_attention(..., q_offset=start)``, so the prompt numerics match
# solo prefill exactly even with ``kv_cache_bits=8`` — quantization
# happens once, in ``lm_paged_splice``, exactly where the slot path's
# ``adapt_prefill_cache`` quantizes.


def _paged_quant(cfg: ModelConfig) -> bool:
    return cfg.kv_cache_bits == 8


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, n_blocks: int):
    """Paged decode cache: {"pool", "block", "len"}.

    pool leaves are stacked over the scanned layers:
    (Ls, P, page, Hkv, dh) K/V (+ (Ls, P, page, Hkv) bf16 scales for
    int8 KV). block: (B, NB) int32, all-zero = every entry points at the
    trash page. ``paged_supported`` gates the families that reach here
    (dense attention, no MLA/MoE/prefix layers).
    """
    dh = cfg.resolved_head_dim
    kv_dt = jnp.int8 if _paged_quant(cfg) else cfg.dtype
    one = {
        "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, dh), kv_dt),
        "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, dh), kv_dt),
    }
    if _paged_quant(cfg):
        one["k_scale"] = jnp.zeros((n_pages, page_size, cfg.n_kv_heads),
                                   jnp.bfloat16)
        one["v_scale"] = jnp.zeros((n_pages, page_size, cfg.n_kv_heads),
                                   jnp.bfloat16)
    pool = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)
    return {
        "pool": pool,
        "block": jnp.zeros((batch, n_blocks), jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def init_paged_workspace(cfg: ModelConfig, wws: int):
    """fp chunk-prefill workspace, one request wide."""
    dh = cfg.resolved_head_dim
    z = jnp.zeros((cfg.n_layers, 1, wws, cfg.n_kv_heads, dh), cfg.dtype)
    return {"k": z, "v": z}


def paged_attn_decode(p, cfg: ModelConfig, x, pool, block, cache_len,
                      mesh=None):
    """One-token decode against the paged pool (one layer).

    Dead slots keep ``cache_len`` pinned at 0 with an all-trash block
    row, so their scatter lands on the trash page and their (garbage)
    output is discarded by the engine.

    Attention dispatches through ``kernels.ops.paged_attention``: the
    Pallas kernel walks the block table page by page (dequantizing int8
    pages in-kernel), so the full ``(B, NB*page, Hkv, dh)`` gathered —
    and, for int8 KV, dequantized — cache is never materialized. The
    gather oracle stays available as the ``"gather"`` backend and is
    bit-identical by contract (tests/test_paged_attn.py).
    """
    from repro.kernels.ops import paged_attention

    B = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1),
                           (B,)).reshape(B, 1)
    q, k, v = _qkv(p, cfg, x, pos)
    idx = pos[:, 0]
    quant = _paged_quant(cfg)
    if quant:
        k, k_s = _kv_quant(k, 8)
        v, v_s = _kv_quant(v, 8)
    new_pool = dict(pool)
    new_pool["k"] = scatter_token_pages(pool["k"], block, idx, k[:, 0])
    new_pool["v"] = scatter_token_pages(pool["v"], block, idx, v[:, 0])
    if quant:
        new_pool["k_scale"] = scatter_token_pages(
            pool["k_scale"], block, idx, k_s[:, 0])
        new_pool["v_scale"] = scatter_token_pages(
            pool["v_scale"], block, idx, v_s[:, 0])
    o = paged_attention(q, new_pool["k"], new_pool["v"], block, idx + 1,
                        window=cfg.window,
                        k_scale=new_pool.get("k_scale"),
                        v_scale=new_pool.get("v_scale"), mesh=mesh)
    out = linear_apply(p["o"], o.reshape(B, 1, -1),
                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    return out, new_pool


def lm_paged_decode_step(params, cfg: ModelConfig, token, cache, mesh=None):
    """token: (B,1) -> (logits (B,1,V), new paged cache)."""
    h = _embed_tokens(params, cfg, token)
    cache_len, block = cache["len"], cache["block"]

    def body(h, xs):
        layer_p, layer_pool = xs
        a_in = rmsnorm_apply(layer_p["ln1"], h)
        a_out, new_pool = paged_attn_decode(layer_p["attn"], cfg, a_in,
                                            layer_pool, block, cache_len,
                                            mesh=mesh)
        h = h + a_out
        m_in = rmsnorm_apply(layer_p["ln2"], h)
        return h + mlp_apply(layer_p["mlp"], cfg, m_in), new_pool

    h, new_pools = jax.lax.scan(body, h, (params["layers"], cache["pool"]))
    logits = _readout(params, cfg, h)
    return logits, {"pool": new_pools, "block": block, "len": cache_len + 1}


def paged_attn_decode_window(p, cfg: ModelConfig, x, pool, block, cache_len,
                             mesh=None):
    """W-token window against the paged pool (one layer) — the paged
    twin of :func:`attn_decode_window`: batched qkv, then per position
    scatter-through-the-block-table + ``ops.paged_attention`` replay.
    Dead slots scatter to the trash page exactly as single-token decode
    does."""
    from repro.kernels.ops import paged_attention

    B, W, _ = x.shape
    pos = (jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,))
           .reshape(B, 1) + jnp.arange(W)[None, :])
    q, k, v = _qkv(p, cfg, x, pos)
    quant = _paged_quant(cfg)
    if quant:
        k, k_s = _kv_quant(k, 8)
        v, v_s = _kv_quant(v, 8)
    new_pool = dict(pool)
    outs = []
    for i in range(W):
        idx = pos[:, i]
        new_pool["k"] = scatter_token_pages(new_pool["k"], block, idx, k[:, i])
        new_pool["v"] = scatter_token_pages(new_pool["v"], block, idx, v[:, i])
        if quant:
            new_pool["k_scale"] = scatter_token_pages(
                new_pool["k_scale"], block, idx, k_s[:, i])
            new_pool["v_scale"] = scatter_token_pages(
                new_pool["v_scale"], block, idx, v_s[:, i])
        o = paged_attention(q[:, i:i + 1], new_pool["k"], new_pool["v"],
                            block, idx + 1, window=cfg.window,
                            k_scale=new_pool.get("k_scale"),
                            v_scale=new_pool.get("v_scale"), mesh=mesh)
        outs.append(o)
    o = jnp.concatenate(outs, axis=1)
    out = linear_apply(p["o"], o.reshape(B, W, -1),
                       backend=cfg.kernel_backend, act_bits=cfg.act_bits)
    return out, new_pool


def lm_paged_decode_window(params, cfg: ModelConfig, tokens, cache,
                           mesh=None):
    """tokens: (B, W) -> (logits (B, W, V), new paged cache at len+W)."""
    h = _embed_tokens(params, cfg, tokens)
    cache_len, block = cache["len"], cache["block"]
    W = tokens.shape[1]

    def body(h, xs):
        layer_p, layer_pool = xs
        a_in = rmsnorm_apply(layer_p["ln1"], h)
        a_out, new_pool = paged_attn_decode_window(
            layer_p["attn"], cfg, a_in, layer_pool, block, cache_len,
            mesh=mesh)
        h = h + a_out
        m_in = rmsnorm_apply(layer_p["ln2"], h)
        return h + mlp_apply(layer_p["mlp"], cfg, m_in), new_pool

    h, new_pools = jax.lax.scan(body, h, (params["layers"], cache["pool"]))
    logits = _readout(params, cfg, h)
    return logits, {"pool": new_pools, "block": block, "len": cache_len + W}


def lm_paged_prefill_chunk(params, cfg: ModelConfig, tokens, ws, start,
                           n_real):
    """One prompt chunk. tokens: (1, C) — C is an AOT-warmed bucket
    width; ws: fp workspace holding K/V of positions [0, start) (from
    earlier chunks or a prefix-cache hydrate); ``start`` / ``n_real``
    are traced int32 scalars, so every chunk of a given width shares one
    trace.

    Returns (logits (1,1,V) read at chunk row n_real-1 — only the final
    chunk's logits are consumed — and the workspace now covering
    [0, start + C)). Padded rows past ``n_real`` compute garbage that is
    never read: their logits are ignored and the splice masks their
    workspace entries out.
    """
    h = _embed_tokens(params, cfg, tokens)
    C = tokens.shape[1]
    start = jnp.asarray(start, jnp.int32)
    n_real = jnp.asarray(n_real, jnp.int32)
    positions = start + jnp.arange(C)[None, :]

    def body(h, xs):
        layer_p, wk, wv = xs
        a_in = rmsnorm_apply(layer_p["ln1"], h)
        q, k, v = _qkv(layer_p["attn"], cfg, a_in, positions)
        wk = jax.lax.dynamic_update_slice_in_dim(wk, k.astype(wk.dtype),
                                                 start, axis=1)
        wv = jax.lax.dynamic_update_slice_in_dim(wv, v.astype(wv.dtype),
                                                 start, axis=1)
        o = flash_attention(q, wk, wv, causal=True, window=cfg.window,
                            q_offset=start, q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block)
        a_out = linear_apply(layer_p["attn"]["o"], o.reshape(1, C, -1),
                             backend=cfg.kernel_backend,
                             act_bits=cfg.act_bits)
        h = h + a_out
        m_in = rmsnorm_apply(layer_p["ln2"], h)
        return h + mlp_apply(layer_p["mlp"], cfg, m_in), (wk, wv)

    h, (wks, wvs) = jax.lax.scan(body, h, (params["layers"], ws["k"],
                                           ws["v"]))
    h_last = jax.lax.dynamic_slice_in_dim(h, n_real - 1, 1, axis=1)
    logits = _readout(params, cfg, h_last)
    return logits, {"k": wks, "v": wvs}


def lm_paged_splice(cfg: ModelConfig, pool, ws, block_row, start, length):
    """Commit workspace positions [start, length) to the page pool
    through ``block_row`` (NB,); everything else scatters to the trash
    page. ``start`` is the prefix-cache hit length: shared hit pages are
    live for other slots (and already hold the bit-exact content), so
    the splice never rewrites them. int8 KV quantizes here — per-entry,
    the same ``_kv_quant`` the slot path's cache adaptation applies, so
    stored bits match the slot pool.
    """
    page = pool["k"].shape[2]
    NB = block_row.shape[0]
    wws = ws["k"].shape[2]
    pos = jnp.arange(wws)
    valid = ((pos >= jnp.asarray(start, jnp.int32))
             & (pos < jnp.asarray(length, jnp.int32)))
    phys = jnp.where(valid, block_row[jnp.clip(pos // page, 0, NB - 1)], 0)
    flat_idx = phys * page + pos % page
    quant = _paged_quant(cfg)

    def scatter(p_leaf, vals):
        P = p_leaf.shape[0]
        flat = p_leaf.reshape((P * page,) + p_leaf.shape[2:])
        return flat.at[flat_idx].set(vals.astype(p_leaf.dtype)).reshape(
            p_leaf.shape)

    def per_layer(*leaves):
        if quant:
            pk, pv, pks, pvs, wk, wv = leaves
            kq, ks = _kv_quant(wk[0], 8)
            vq, vs = _kv_quant(wv[0], 8)
            return (scatter(pk, kq), scatter(pv, vq),
                    scatter(pks, ks), scatter(pvs, vs))
        pk, pv, wk, wv = leaves
        return scatter(pk, wk[0]), scatter(pv, wv[0])

    if quant:
        nk, nv, nks, nvs = jax.vmap(per_layer)(
            pool["k"], pool["v"], pool["k_scale"], pool["v_scale"],
            ws["k"], ws["v"])
        return {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
    nk, nv = jax.vmap(per_layer)(pool["k"], pool["v"], ws["k"], ws["v"])
    return {"k": nk, "v": nv}


def lm_paged_hydrate(cfg: ModelConfig, pool, block_row, hist_len, wws: int):
    """Rebuild the fp workspace prefix [0, hist_len) from cached pages
    (prefix-cache hit), zeroed beyond. Exact for fp pools; for int8
    pools the hydrated prefix is the dequantized cache (the lossy step
    already paid at splice time) — see docs/serving.md for the numerics
    note."""
    page = pool["k"].shape[2]
    hist_len = jnp.asarray(hist_len, jnp.int32)
    quant = _paged_quant(cfg)

    def per_layer(*leaves):
        if quant:
            pk, pv, pks, pvs = leaves
        else:
            pk, pv = leaves
        kc = gather_pages(pk, block_row[None])    # (1, NB*page, Hkv, dh)
        vc = gather_pages(pv, block_row[None])
        if quant:
            kc = kc.astype(jnp.bfloat16) * gather_pages(
                pks, block_row[None])[..., None]
            vc = vc.astype(jnp.bfloat16) * gather_pages(
                pvs, block_row[None])[..., None]
        W = kc.shape[1]
        if W < wws:
            kc = jnp.pad(kc, ((0, 0), (0, wws - W), (0, 0), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, wws - W), (0, 0), (0, 0)))
        live = (jnp.arange(wws) < hist_len)[None, :, None, None]
        zero = jnp.zeros((), cfg.dtype)
        return (jnp.where(live, kc.astype(cfg.dtype), zero),
                jnp.where(live, vc.astype(cfg.dtype), zero))

    leaves = ((pool["k"], pool["v"], pool["k_scale"], pool["v_scale"])
              if quant else (pool["k"], pool["v"]))
    wk, wv = jax.vmap(per_layer)(*leaves)
    return {"k": wk, "v": wv}


def lm_paged_prefill_packed(params, cfg: ModelConfig, tokens, pool, blocks,
                            bases, hists, lens, wws: int):
    """Packed prefill: several short prompts' tails in ONE chunk call.

    tokens: (1, C) — the segments' tail tokens concatenated tightly (C
    is an AOT-warmed bucket width; padded rows are masked everywhere).
    blocks: (S, NB) int32 block rows; bases: (S,) workspace base per
    segment, **aligned to ``cfg.attn_kv_block``** and non-decreasing
    (inactive segments park at the total span); hists/lens: (S,) prefix
    hit / total prompt lengths (0 for inactive segments). All arrays are
    traced, so every group of a given bucket width shares one trace.

    Fuses what the unpacked path runs as hydrate + chunk + splice:
    per layer it rebuilds each segment's hydrated prefix from the pool,
    writes the chunk K/V at ``base + position``, attends with per-token
    position/segment masking (``flash_attention`` overrides), and
    splices [hist, len) back through each block row. Returns
    ``(logits (S, 1, V) — row s read at segment s's last tail row — and
    the updated pool)``.

    Numerics: masked kv blocks are exact no-ops of the flash
    accumulator and XLA matmul rows are independent, so each segment's
    tokens are **bit-identical** to an unpacked hydrate+chunk+splice of
    the same request (the packed-parity suite pins this; see
    docs/serving.md for why bases must be kv_block-aligned).
    """
    C = tokens.shape[1]
    S, NB = blocks.shape
    page = pool["k"].shape[2]
    quant = _paged_quant(cfg)
    blocks = jnp.asarray(blocks, jnp.int32)
    bases = jnp.asarray(bases, jnp.int32)
    hists = jnp.asarray(hists, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)

    # packed-q geometry: row i belongs to segment q_seg[i] at in-prompt
    # position q_pos[i] = hist + offset-within-tail
    tails = lens - hists
    ends = jnp.cumsum(tails)
    qi = jnp.arange(C, dtype=jnp.int32)
    q_valid = qi < ends[S - 1]
    q_seg = jnp.clip(jnp.searchsorted(ends, qi, side="right"), 0, S - 1)
    q_pos = hists[q_seg] + (qi - (ends - tails)[q_seg])
    q_pos = jnp.where(q_valid, q_pos, -1)
    q_seg_m = jnp.where(q_valid, q_seg, -2)   # never matches any kv
    positions = jnp.maximum(q_pos, 0)[None, :]
    # padded rows scatter out of bounds -> dropped
    q_ws_idx = jnp.where(q_valid, bases[q_seg] + q_pos, wws)

    # workspace geometry: position w belongs to the last segment whose
    # base is <= w (bases are non-decreasing; beyond-span positions land
    # on an inactive segment or past the owner's length — masked either
    # way)
    wpos = jnp.arange(wws, dtype=jnp.int32)
    w_seg = jnp.clip(jnp.searchsorted(bases, wpos, side="right") - 1,
                     0, S - 1)
    w_local = wpos - bases[w_seg]
    w_blk = blocks[w_seg, jnp.clip(w_local // page, 0, NB - 1)]
    gather_idx = w_blk * page + w_local % page
    hyd_live = w_local < hists[w_seg]
    spl_valid = (w_local >= hists[w_seg]) & (w_local < lens[w_seg])
    spl_idx = jnp.where(spl_valid, w_blk, 0) * page + w_local % page

    h = _embed_tokens(params, cfg, tokens)

    def scatter(p_leaf, vals):
        P = p_leaf.shape[0]
        flat = p_leaf.reshape((P * page,) + p_leaf.shape[2:])
        return flat.at[spl_idx].set(vals.astype(p_leaf.dtype)).reshape(
            p_leaf.shape)

    def body(h, xs):
        if quant:
            layer_p, pk, pv, pks, pvs = xs
        else:
            layer_p, pk, pv = xs
        # hydrate (same gather -> dequant -> cast -> mask as
        # lm_paged_hydrate, per position)
        kc = pk.reshape((-1,) + pk.shape[2:])[gather_idx]
        vc = pv.reshape((-1,) + pv.shape[2:])[gather_idx]
        if quant:
            kc = kc.astype(jnp.bfloat16) * pks.reshape(-1, pks.shape[2])[
                gather_idx][..., None]
            vc = vc.astype(jnp.bfloat16) * pvs.reshape(-1, pvs.shape[2])[
                gather_idx][..., None]
        zero = jnp.zeros((), cfg.dtype)
        live = hyd_live[:, None, None]
        wk = jnp.where(live, kc.astype(cfg.dtype), zero)[None]
        wv = jnp.where(live, vc.astype(cfg.dtype), zero)[None]

        a_in = rmsnorm_apply(layer_p["ln1"], h)
        q, k, v = _qkv(layer_p["attn"], cfg, a_in, positions)
        wk = wk.at[0, q_ws_idx].set(k[0].astype(wk.dtype), mode="drop")
        wv = wv.at[0, q_ws_idx].set(v[0].astype(wv.dtype), mode="drop")
        o = flash_attention(q, wk, wv, causal=True, window=cfg.window,
                            q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block,
                            q_positions=q_pos, kv_positions=w_local,
                            q_segments=q_seg_m, kv_segments=w_seg)
        a_out = linear_apply(layer_p["attn"]["o"], o.reshape(1, C, -1),
                             backend=cfg.kernel_backend,
                             act_bits=cfg.act_bits)
        h = h + a_out
        m_in = rmsnorm_apply(layer_p["ln2"], h)
        h = h + mlp_apply(layer_p["mlp"], cfg, m_in)
        if quant:
            kq, ks = _kv_quant(wk[0], 8)
            vq, vs = _kv_quant(wv[0], 8)
            return h, (scatter(pk, kq), scatter(pv, vq),
                       scatter(pks, ks), scatter(pvs, vs))
        return h, (scatter(pk, wk[0]), scatter(pv, wv[0]))

    leaves = ((params["layers"], pool["k"], pool["v"], pool["k_scale"],
               pool["v_scale"]) if quant
              else (params["layers"], pool["k"], pool["v"]))
    h, new = jax.lax.scan(body, h, leaves)
    new_pool = ({"k": new[0], "v": new[1], "k_scale": new[2],
                 "v_scale": new[3]} if quant
                else {"k": new[0], "v": new[1]})

    # per-segment logits, one (1, 1, d) readout each so the trace
    # shapes (and therefore the bits) match the unpacked chunk readout
    idx_last = jnp.clip(ends - 1, 0, C - 1)
    logits = jnp.concatenate(
        [_readout(params, cfg,
                  jax.lax.dynamic_slice_in_dim(h, idx_last[s], 1, axis=1))
         for s in range(S)], axis=0)
    return logits, new_pool
