"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

Structure (simplified from Zamba2, documented in DESIGN.md): the model is
``n_super`` super-blocks, each = ``attn_every`` Mamba2 layers followed by
one application of a single shared transformer block (attention + MLP,
parameters reused across all applications — Zamba's parameter-sharing
trick). Mamba params are stacked (n_super, attn_every, ...) so the whole
model is a scan-of-scans; the attention KV caches are per application
(n_super of them).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import attn_decode, attn_forward, attn_init, mlp_apply, mlp_init
from repro.nn.linear import embedding_apply, embedding_init, embedding_logits
from repro.nn.mamba2 import mamba2_decode, mamba2_forward, mamba2_init
from repro.nn.norms import rmsnorm_apply, rmsnorm_init
from repro.nn.tree import rng_stream


def _prepend(ax, names):
    if isinstance(ax, dict):
        return {k: _prepend(v, names) for k, v in ax.items()}
    return tuple(names) + tuple(ax)


def init_zamba(key, cfg: ModelConfig):
    assert cfg.n_layers % cfg.attn_every == 0
    n_super = cfg.n_layers // cfg.attn_every
    rs = rng_stream(key)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(next(rs), cfg.vocab, cfg.d_model)

    cap = {}

    def one_mamba(k):
        p, a = {}, {}
        p["ln"], a["ln"] = rmsnorm_init(cfg.d_model)
        p["mamba"], a["mamba"] = mamba2_init(
            k, cfg.d_model, d_inner=cfg.resolved_d_inner,
            n_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
        cap["ax"] = a
        return p

    keys = jax.random.split(next(rs), cfg.n_layers).reshape(
        n_super, cfg.attn_every, 2)
    params["mamba_layers"] = jax.vmap(jax.vmap(one_mamba))(keys)
    axes["mamba_layers"] = _prepend(cap["ax"], ("super", "inner"))

    sp, sa = {}, {}
    sp["ln1"], sa["ln1"] = rmsnorm_init(cfg.d_model)
    sp["ln2"], sa["ln2"] = rmsnorm_init(cfg.d_model)
    sp["attn"], sa["attn"] = attn_init(next(rs), cfg)
    sp["mlp"], sa["mlp"] = mlp_init(next(rs), cfg)
    params["shared"], axes["shared"] = sp, sa

    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, axes


def _shared_block(params, cfg, h, positions):
    sp = params["shared"]
    a, cache = attn_forward(sp["attn"], cfg, rmsnorm_apply(sp["ln1"], h), positions)
    h = h + a
    h = h + mlp_apply(sp["mlp"], cfg, rmsnorm_apply(sp["ln2"], h))
    return h, cache


def zamba_forward(params, cfg: ModelConfig, tokens):
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    S = h.shape[1]
    positions = jnp.arange(S)[None, :]

    def inner(h, mp):
        out, _ = mamba2_forward(mp["mamba"], rmsnorm_apply(mp["ln"], h),
                                d_inner=cfg.resolved_d_inner, n_state=cfg.ssm_state,
                                head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                                backend=cfg.kernel_backend, act_bits=cfg.act_bits)
        return h + out, None

    def superblock(h, sp_params):
        from repro.models.lm import remat_wrap
        h, _ = jax.lax.scan(remat_wrap(inner, cfg), h, sp_params)
        h, _ = _shared_block(params, cfg, h, positions)
        return h, None

    h, _ = jax.lax.scan(superblock, h, params["mamba_layers"])
    h = rmsnorm_apply(params["final_norm"], h)
    from repro.distributed.sharding import constrain
    return constrain(embedding_logits(params["embed"], h, backend=cfg.kernel_backend),
                     (("pod", "data"), None, "model"))


def zamba_loss(params, cfg: ModelConfig, batch):
    logits = zamba_forward(params, cfg, batch["tokens"]).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    loss = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


def init_zamba_cache(cfg: ModelConfig, batch: int, max_len: int):
    n_super = cfg.n_layers // cfg.attn_every
    H = cfg.resolved_d_inner // cfg.ssm_head_dim
    conv_dim = cfg.resolved_d_inner + 2 * cfg.ssm_state
    dh = cfg.resolved_head_dim
    mamba_state = {
        "ssm": jnp.zeros((n_super, cfg.attn_every, batch, H, cfg.ssm_state,
                          cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((n_super, cfg.attn_every, batch, 3, conv_dim), cfg.dtype),
    }
    attn_cache = {
        "k": jnp.zeros((n_super, batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
        "v": jnp.zeros((n_super, batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
    }
    return {"mamba": mamba_state, "attn": attn_cache,
            "len": jnp.zeros((batch,), jnp.int32)}


def zamba_prefill(params, cfg: ModelConfig, tokens, max_len: int, *,
                  lengths=None):
    """Run the prompt, return (last_logits, decode cache).

    ``lengths``: per-stream real prompt lengths — logits are gathered at
    each stream's last real token and the attention cache continues per
    stream. NOTE: the Mamba2 state still integrates right-padding
    tokens, so ragged batches should be prefilled per stream at exact
    length (``runtime.engine`` does this)."""
    h = embedding_apply(params["embed"], tokens, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    B, S, _ = h.shape
    positions = jnp.arange(S)[None, :]

    def inner(h, mp):
        out, st = mamba2_forward(mp["mamba"], rmsnorm_apply(mp["ln"], h),
                                 d_inner=cfg.resolved_d_inner, n_state=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
                                 backend=cfg.kernel_backend, act_bits=cfg.act_bits)
        return h + out, st

    def superblock(h, sp_params):
        h, mstates = jax.lax.scan(inner, h, sp_params)
        h, cache = _shared_block(params, cfg, h, positions)
        pad = max_len - S
        cache = jax.tree.map(
            lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2)), cache)
        return h, {"mamba": mstates, "attn": cache}

    h, st = jax.lax.scan(superblock, h, params["mamba_layers"])
    from repro.models.lm import last_real_slice
    h_last = h[:, -1:] if lengths is None else last_real_slice(h, lengths)
    h_last = rmsnorm_apply(params["final_norm"], h_last)
    logits = embedding_logits(params["embed"], h_last, backend=cfg.kernel_backend)
    cache_len = (jnp.full((B,), S, jnp.int32) if lengths is None
                 else jnp.asarray(lengths, jnp.int32))
    cache = {"mamba": st["mamba"], "attn": st["attn"], "len": cache_len}
    return logits, cache


def zamba_decode_step(params, cfg: ModelConfig, token, cache):
    h = embedding_apply(params["embed"], token, dtype=cfg.dtype) * (cfg.d_model ** 0.5)
    cache_len = cache["len"]
    sp = params["shared"]

    def inner(h, xs):
        mp, mstate = xs
        out, st = mamba2_decode(mp["mamba"], rmsnorm_apply(mp["ln"], h), mstate,
                                d_inner=cfg.resolved_d_inner, n_state=cfg.ssm_state,
                                head_dim=cfg.ssm_head_dim,
                                backend=cfg.kernel_backend, act_bits=cfg.act_bits)
        return h + out, st

    def superblock(h, xs):
        mp, mstates, acache = xs
        h, new_m = jax.lax.scan(inner, h, (mp, mstates))
        a, new_a = attn_decode(sp["attn"], cfg, rmsnorm_apply(sp["ln1"], h),
                               acache, cache_len)
        h = h + a
        h = h + mlp_apply(sp["mlp"], cfg, rmsnorm_apply(sp["ln2"], h))
        return h, {"mamba": new_m, "attn": new_a}

    h, st = jax.lax.scan(superblock, h,
                         (params["mamba_layers"], cache["mamba"], cache["attn"]))
    logits = embedding_logits(params["embed"], rmsnorm_apply(params["final_norm"], h),
                              backend=cfg.kernel_backend)
    return logits, {"mamba": st["mamba"], "attn": st["attn"], "len": cache_len + 1}
