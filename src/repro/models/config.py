"""Unified architecture config covering all assigned families."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.rules import QuantPolicy
from repro.core.spec import QuantSpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | encdec | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    use_qk_norm: bool = False
    window: Optional[int] = None          # sliding-window attention width
    rope_theta: float = 10000.0
    tie_embeddings: bool = True

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_shared: Optional[int] = None
    first_dense: int = 0                  # leading dense-FFN layers (deepseek)
    capacity_factor: float = 1.25

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128

    # SSM / hybrid
    ssm_state: int = 64
    ssm_head_dim: int = 64
    d_inner: Optional[int] = None
    attn_every: int = 6                   # zamba2: shared attn period

    # enc-dec
    enc_layers: int = 0

    # modality stub (vlm/audio): prepended precomputed embeddings
    n_prefix_tokens: int = 0

    # compute
    dtype: Any = jnp.bfloat16
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs) | none
    attn_q_block: int = 512
    attn_kv_block: int = 512
    ssm_chunk: int = 128

    # serving optimizations (beyond-paper; see EXPERIMENTS.md §Perf)
    kv_cache_bits: int = 16     # 8 = int8 KV cache with per-step scales
    pack_assignments: bool = False  # two 4-bit LUT indices per byte (K<=16)
    # kernel execution backend for quantized matmuls (kernels/ops.lutq_dot):
    # "auto" resolves per leaf (train/STE -> decode, serve int8 -> fused,
    # serve packed -> packed4); "decode"/"fused"/"packed4" force one path
    # model-wide (infeasible leaves degrade down the same ladder).
    kernel_backend: str = "auto"

    # quantization (the paper's technique; None = fp baseline).
    # A bare QuantSpec means "uniform policy" (auto-wrapped); a
    # QuantPolicy gives rule-based mixed precision (see core/rules.py).
    quant: Optional[Union[QuantSpec, QuantPolicy]] = None
    act_bits: int = 32
    quantize_embed: bool = True

    # sub-quadratic? (drives long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.family in ("hybrid", "ssm") or self.window is not None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_d_inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
