"""ResNets: the paper's reference networks.

Two roles:
  * analytic layer inventories for ResNet-18/34/50 (ImageNet) — drive the
    Table 2 memory/multiplication reproduction (97.5 MB -> 7.4 MB claim);
  * a trainable ResNet-20-style CIFAR CNN (LUT-Q aware convs, standard
    or multiplier-less BN, optional 8-bit activations) for the CIFAR-10
    quality-table and Fig. 2 pruning experiments at CPU scale.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.actquant import relu_fake_quant
from repro.core.mlbn import (
    BNParams,
    BNStats,
    apply_scale_offset_shift,
    batch_norm,
    inference_scale_offset,
    init_bn,
)
from repro.models.config import ModelConfig  # noqa: F401  (API parity)
from repro.nn.conv import conv_apply, conv_init
from repro.nn.tree import rng_stream


# ---------------------------------------------------------------------------
# analytic ImageNet ResNet inventories
# ---------------------------------------------------------------------------

def _basic_block(cin, cout, stride):
    layers = [("conv1", 3 * 3 * cin * cout), ("conv2", 3 * 3 * cout * cout)]
    if stride != 1 or cin != cout:
        layers.append(("down", 1 * 1 * cin * cout))
    return layers


def _bottleneck(cin, cmid, stride):
    cout = cmid * 4
    layers = [("conv1", 1 * 1 * cin * cmid), ("conv2", 3 * 3 * cmid * cmid),
              ("conv3", 1 * 1 * cmid * cout)]
    if stride != 1 or cin != cout:
        layers.append(("down", 1 * 1 * cin * cout))
    return layers


def resnet_layer_sizes(depth: int) -> List[Tuple[str, int]]:
    """(name, n_params) for every conv/fc weight tensor (ImageNet)."""
    cfgs = {18: ([2, 2, 2, 2], _basic_block, 1),
            34: ([3, 4, 6, 3], _basic_block, 1),
            50: ([3, 4, 6, 3], _bottleneck, 4)}
    blocks, mk, expansion = cfgs[depth]
    sizes = [("stem", 7 * 7 * 3 * 64)]
    cin = 64
    for stage, (n, cbase) in enumerate(zip(blocks, [64, 128, 256, 512])):
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            ls = mk(cin, cbase, stride)
            sizes += [(f"s{stage}b{b}_{n0}", p) for n0, p in ls]
            cin = cbase * expansion
    sizes.append(("fc", cin * 1000))
    return sizes


def resnet_activation_elems(depth: int, res: int = 224) -> int:
    """Peak live activation elements at inference, batch 1.

    Residual blocks need the block input + the working tensor alive
    simultaneously -> 2x the largest feature map (post-stem 64 x 112^2).
    """
    return 2 * 64 * (res // 2) ** 2


def _conv_inventory(depth: int, res: int = 224):
    """Yield (cin, cout, k, hw_out) for every conv + the final fc."""
    cfgs = {18: ([2, 2, 2, 2], "basic"), 34: ([3, 4, 6, 3], "basic"),
            50: ([3, 4, 6, 3], "bottleneck")}
    blocks, kind = cfgs[depth]
    convs = [(3, 64, 7, res // 2)]
    cin, hw = 64, res // 4
    for stage, (n, cbase) in enumerate(zip(blocks, [64, 128, 256, 512])):
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            hw = hw // stride
            if kind == "basic":
                convs += [(cin, cbase, 3, hw), (cbase, cbase, 3, hw)]
                cout = cbase
            else:
                cout = cbase * 4
                convs += [(cin, cbase, 1, hw), (cbase, cbase, 3, hw),
                          (cbase, cout, 1, hw)]
            if stride != 1 or cin != cout:
                convs.append((cin, cout, 1, hw))
            cin = cout
    return convs, cin


def resnet_mults(depth: int, res: int = 224, K: Optional[int] = None) -> int:
    """Multiplications for one inference (paper: K mults/output vs I)."""
    from repro.core.memory import affine_mults, conv_mults
    convs, cfinal = _conv_inventory(depth, res)
    total = sum(conv_mults(co, ci, k, k, hw, hw, K) for ci, co, k, hw in convs)
    total += affine_mults(1000, cfinal, K)
    return total


# ---------------------------------------------------------------------------
# trainable CIFAR-style ResNet-20 (reduced resolution for CPU)
# ---------------------------------------------------------------------------

def init_resnet20(key, *, widths=(16, 32, 64), blocks=2, n_classes=8,
                  dtype=jnp.float32):
    """ResNet-20-family: stem + 3 stages x `blocks` basic blocks + fc."""
    rs = rng_stream(key)
    params: Dict = {"stem": conv_init(next(rs), 3, 3, 3, widths[0], dtype=dtype)[0]}
    bn_p, bn_s = init_bn(widths[0])
    params["stem_bn"], stats = {"p": bn_p}, {"stem_bn": bn_s}
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {}
            blk["conv1"] = conv_init(next(rs), 3, 3, cin, w, dtype=dtype)[0]
            p1, s1 = init_bn(w)
            blk["bn1"] = {"p": p1}
            blk["conv2"] = conv_init(next(rs), 3, 3, w, w, dtype=dtype)[0]
            p2, s2 = init_bn(w)
            blk["bn2"] = {"p": p2}
            name = f"s{si}b{bi}"
            stats[f"{name}_bn1"], stats[f"{name}_bn2"] = s1, s2
            if stride != 1 or cin != w:
                blk["down"] = conv_init(next(rs), 1, 1, cin, w, dtype=dtype)[0]
            params[name] = blk
            cin = w
    params["fc"] = {"kernel": (jax.random.normal(next(rs), (cin, n_classes))
                               * (cin ** -0.5)).astype(dtype)}
    return params, stats


def resnet20_apply(params, stats, x, *, widths=(16, 32, 64), blocks=2,
                   training=False, multiplier_less=False, act_bits=32):
    """Returns (logits, new_stats)."""
    new_stats = {}

    def bn(p, s_key, h):
        if multiplier_less and not training:
            # serve path: fold BN to (a, b) and apply the exact-pow2 scale
            # as negate/shift/add — no multiplies (Appendix A, literally).
            a, b = inference_scale_offset(p["p"], stats[s_key],
                                          multiplier_less=True)
            new_stats[s_key] = stats[s_key]
            return apply_scale_offset_shift(h, a, b)
        y, ns = batch_norm(h, p["p"], stats[s_key], training=training,
                           multiplier_less=multiplier_less)
        new_stats[s_key] = ns
        return y

    def act(h):
        return relu_fake_quant(h, act_bits) if act_bits < 32 else jax.nn.relu(h)

    h = conv_apply(params["stem"], x)
    h = act(bn(params["stem_bn"], "stem_bn", h))
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            name = f"s{si}b{bi}"
            blk = params[name]
            idn = h
            y = conv_apply(blk["conv1"], h, stride=stride)
            y = act(bn(blk["bn1"], f"{name}_bn1", y))
            y = conv_apply(blk["conv2"], y)
            y = bn(blk["bn2"], f"{name}_bn2", y)
            if "down" in blk:
                idn = conv_apply(blk["down"], idn, stride=stride)
            h = act(y + idn)
            cin = w
    h = jnp.mean(h, axis=(1, 2))
    from repro.nn.linear import dot_kernel
    logits = dot_kernel(h, params["fc"]["kernel"])
    return logits, new_stats


def classify_loss(params, stats, batch, **kw):
    logits, new_stats = resnet20_apply(params, stats, batch["x"],
                                       training=True, **kw)
    labels = batch["y"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, (new_stats, acc)
