"""Small pytree utilities shared by the functional layer library.

Params are nested dicts of jax arrays (or LutqState leaves once
quantized). Every ``*_init`` function returns ``(params, axes)`` where
``axes`` mirrors ``params`` with tuples of *logical* axis names per
array dimension — the distribution layer maps logical names to mesh axes
(MaxText-style logical axis rules).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp

from repro.core.lutq import LutqState

Params = Dict[str, Any]
Axes = Dict[str, Any]


def rng_stream(key: jax.Array) -> Iterator[jax.Array]:
    """Infinite deterministic stream of rng keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


def is_leaf(x) -> bool:
    return isinstance(x, (jax.Array, LutqState)) or (
        hasattr(x, "shape") and hasattr(x, "dtype")
    )


def tree_paths(tree, prefix=()) -> Iterator[Tuple[Tuple[str, ...], Any]]:
    """Yield (path, leaf) pairs; LutqState counts as a single leaf."""
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from tree_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def map_with_path(fn: Callable, tree, prefix=()):
    if isinstance(tree, dict):
        return {k: map_with_path(fn, v, prefix + (k,)) for k, v in tree.items()}
    return fn(prefix, tree)


def zip_map(fn: Callable, a, b):
    """Map fn over two parallel trees (dict structure must match)."""
    if isinstance(a, dict):
        return {k: zip_map(fn, a[k], b[k]) for k in a}
    return fn(a, b)


def param_count(tree) -> int:
    total = 0
    for _, leaf in tree_paths(tree):
        if isinstance(leaf, LutqState):
            total += leaf.w.size
        elif leaf is not None:
            total += leaf.size
    return total


def cast_compute(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype) if x.dtype != dtype else x
