"""Attention: flash-style chunked (training/prefill) + decode paths.

Pure-JAX online-softmax attention tiled over (q-block, kv-block) with
``lax.scan`` — O(S * block) memory instead of O(S^2). Sliding-window
attention slices a *static-width* KV slab per q-block with
``dynamic_slice`` so SWA FLOPs scale with the window size, not S^2.

GQA is handled by folding query heads into (kv_head, group) so no KV
replication is materialized.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: Optional[jax.Array] = None,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    q_segments: Optional[jax.Array] = None,
    kv_segments: Optional[jax.Array] = None,
) -> jax.Array:
    """q: (B,S,H,Dh); k,v: (B,Skv,Hkv,Dh[v]) -> (B,S,H,Dv).

    ``q_offset``: absolute position of q's first row within the KV
    sequence (traced scalar ok). Chunked prefill attends a chunk of
    queries against the full workspace with ``q_offset=start`` so the
    causal/window masks see global positions. Defaults to 0 (prompt
    prefill, q and kv aligned).

    ``q_positions``/``kv_positions`` (S,)/(Skv,) int32 +
    ``q_segments``/``kv_segments``: per-token overrides for packed
    multi-sequence batches (paged prefill packing). When given (all
    four together), the mask becomes
    ``seg_q == seg_kv  &  pos_q >= pos_kv  [&  pos_q - pos_kv < window]``
    — a token only attends its own segment. Negative segment ids never
    match (use -1/-2 for padding). The sliding-window KV slab is
    disabled (positions are no longer monotone in buffer order), and
    ``prefix`` is unsupported. Masked-out kv blocks are exact numeric
    no-ops of the online accumulator, so a segment's rows are
    bit-identical to an unpacked call whose kv layout groups the same
    valid entries into the same kv blocks (i.e. segment bases aligned
    to ``kv_block``).
    """
    B, S, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    if scale is None:
        scale = Dh ** -0.5
    packed = q_positions is not None
    if packed:
        assert prefix is None, "prefix + packed segment overrides unsupported"
        assert (kv_positions is not None and q_segments is not None
                and kv_segments is not None)

    q_block = min(q_block, S)
    kv_block = min(kv_block, Skv)
    # pad to block multiples; padded keys are masked out, padded queries
    # are sliced off the output.
    S0, Skv0 = S, Skv
    pad_q = (-S) % q_block
    pad_kv = (-Skv) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        S += pad_q
        if packed:
            q_positions = jnp.pad(q_positions, (0, pad_q),
                                  constant_values=-1)
            q_segments = jnp.pad(q_segments, (0, pad_q), constant_values=-2)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        Skv += pad_kv
        if packed:
            kv_positions = jnp.pad(kv_positions, (0, pad_kv),
                                   constant_values=-1)
            kv_segments = jnp.pad(kv_segments, (0, pad_kv),
                                  constant_values=-1)
    nq = S // q_block
    if q_offset is None:
        q_offset = jnp.array(0, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    qr = q.reshape(B, nq, q_block, Hkv, G, Dh) * scale

    if window is not None and causal and not packed:
        # Static KV slab wide enough to cover [q_end - window, q_end).
        slab = ((window + kv_block - 1) // kv_block + 1) * kv_block
        slab = min(slab + (q_block // kv_block) * kv_block, Skv)
        slab = max(slab, kv_block)
        slab = (slab // kv_block) * kv_block
    else:
        slab = Skv
    nkv = slab // kv_block

    def per_qblock(qi):
        qblk = qr[:, qi]  # (B, bq, Hkv, G, Dh)
        q_start = q_offset + qi * q_block
        if slab < Skv:
            start = jnp.clip(q_start + q_block - slab, 0, Skv - slab)
        else:
            start = jnp.array(0, jnp.int32)
        kslab = jax.lax.dynamic_slice_in_dim(k, start, slab, axis=1)
        vslab = jax.lax.dynamic_slice_in_dim(v, start, slab, axis=1)
        if packed:
            q_pos = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_block,
                                                 q_block)
            q_seg = jax.lax.dynamic_slice_in_dim(q_segments, qi * q_block,
                                                 q_block)
        else:
            q_pos = q_start + jnp.arange(q_block)

        def inner(carry, j):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(kslab, j * kv_block, kv_block, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(vslab, j * kv_block, kv_block, axis=1)
            if packed:
                k_pos = jax.lax.dynamic_slice_in_dim(
                    kv_positions, j * kv_block, kv_block)
                k_seg = jax.lax.dynamic_slice_in_dim(
                    kv_segments, j * kv_block, kv_block)
            else:
                k_pos = start + j * kv_block + jnp.arange(kv_block)
            # scores: (B, Hkv, G, bq, bk) in f32
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kj).astype(jnp.float32)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            if packed:
                mask &= q_seg[:, None] == k_seg[None, :]
            if prefix is not None:
                # bidirectional attention inside the (image/audio) prefix
                mask |= (q_pos[:, None] < prefix) & (k_pos[None, :] < prefix)
            if pad_kv and not packed:
                mask &= (k_pos[None, :] < Skv0)
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            # vj: (B, bk, Hkv, Dv) -> (B, Hkv, bk, Dv)
            vj_t = vj.transpose(0, 2, 1, 3).astype(jnp.float32)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, vj_t)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_block, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(inner), (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)
        # (B, Hkv, G, bq, Dv) -> (B, bq, H, Dv)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, q_block, H, Dv)

    outs = jax.lax.map(per_qblock, jnp.arange(nq))  # (nq, B, bq, H, Dv)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dv)
    if pad_q:
        out = out[:, :S0]
    return out.astype(q.dtype)


def dense_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: Optional[int] = None, prefix: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference O(S^2) attention (oracle for tests, small shapes only)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if scale is None:
        scale = Dh ** -0.5
    qr = q.reshape(B, S, Hkv, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr * scale, k).astype(jnp.float32)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((S, k.shape[1]), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    if prefix is not None:
        mask |= (q_pos < prefix) & (k_pos < prefix)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(jnp.float32), v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, -1).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token decode. q: (B,1,H,Dh); caches: (B,Skv,Hkv,Dh[v]).

    cache_len: (B,) or scalar — number of valid cache positions.
    """
    B, _, H, Dh = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    if scale is None:
        scale = Dh ** -0.5
    qr = q.reshape(B, Hkv, G, Dh) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache).astype(jnp.float32)
    pos = jnp.arange(Skv)[None, :]
    cl = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,)).reshape(B, 1)
    valid = pos < cl
    if window is not None:
        valid &= pos >= cl - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.float32), v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, -1).astype(q.dtype)


def gather_pages(pool: jax.Array, block: jax.Array) -> jax.Array:
    """Assemble per-slot linear KV views from a paged pool.

    pool: (P, page, ...) physical pages; block: (B, NB) int32 block
    table rows -> (B, NB*page, ...) where row b's position p reads
    ``pool[block[b, p // page], p % page]``. Empty block entries point
    at the trash page (0); its contents are garbage and every consumer
    masks positions ``>= cache_len``, so no validity branch is needed.
    """
    P, page = pool.shape[:2]
    B, NB = block.shape
    flat = pool.reshape((P * page,) + pool.shape[2:])
    idx = (block * page)[:, :, None] + jnp.arange(page, dtype=block.dtype)
    return flat[idx.reshape(B, NB * page)]


def scatter_token_pages(pool: jax.Array, block: jax.Array, idx: jax.Array,
                        val: jax.Array) -> jax.Array:
    """Write one token's K or V through the block table.

    pool: (P, page, ...); block: (B, NB); idx: (B,) logical position to
    write; val: (B, ...) payload. Dead slots keep ``idx`` pinned at 0
    with an all-trash block row, so their writes land on the trash page.
    ``idx // page`` is clipped (JAX clamps out-of-range gathers anyway;
    the clip keeps the intent explicit).
    """
    P, page = pool.shape[:2]
    B, NB = block.shape
    blk = jnp.take_along_axis(
        block, jnp.clip(idx[:, None] // page, 0, NB - 1), axis=1)[:, 0]
    flat_idx = blk * page + idx % page
    flat = pool.reshape((P * page,) + pool.shape[2:])
    return flat.at[flat_idx].set(val.astype(pool.dtype)).reshape(pool.shape)
