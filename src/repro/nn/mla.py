"""Multi-head Latent Attention (DeepSeek-V2), LUT-Q aware.

The KV cache stores only the compressed latent ``c_kv`` (rank r) plus the
shared RoPE key — the MLA memory win. Decode uses the *absorbed* form:
q_nope is projected through W_uk so scores are taken directly against the
latent, and the attention output over latents is expanded through W_uv.
This keeps per-token decode FLOPs at O(H * r) instead of re-expanding the
whole cache every step.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.attention import NEG_INF, flash_attention
from repro.nn.linear import linear_apply, linear_init, materialize
from repro.nn.rotary import apply_rope
from repro.nn.tree import rng_stream


@functools.lru_cache(maxsize=16)
def _assemble_mats(nope: int, rope: int):
    """Host 0/1 selection matrices placing the nope / rope halves into
    the combined head dim.

    Sharding note (same hazard as nn/rotary.py): concatenating *computed*
    tensors along a dim the consumer shards miscompiles under the SPMD
    partitioner on the CPU backend whenever head-granular tensor
    parallelism shards the head dim. Assembling the combined q/k via
    matmuls against host constants keeps every traced op a contraction
    the partitioner handles, and stays bitwise identical to the concat:
    each output element is exactly one ``1 * value`` plus exact float
    zeros."""
    d = nope + rope
    en = np.zeros((nope, d), np.float32)
    en[:, :nope] = np.eye(nope)
    er = np.zeros((rope, d), np.float32)
    er[:, nope:] = np.eye(rope)
    return en, er


def mla_init(
    key,
    d_model: int,
    n_heads: int,
    *,
    kv_lora: int = 512,
    qk_nope: int = 128,
    qk_rope: int = 64,
    v_head: int = 128,
    dtype=jnp.float32,
):
    rs = rng_stream(key)
    params, axes = {}, {}
    for name, (i, o, ax) in {
        "q": (d_model, n_heads * (qk_nope + qk_rope), ("embed", "heads")),
        "dkv": (d_model, kv_lora + qk_rope, ("embed", "kv_lora")),
        "uk": (kv_lora, n_heads * qk_nope, ("kv_lora", "heads")),
        "uv": (kv_lora, n_heads * v_head, ("kv_lora", "heads")),
        "o": (n_heads * v_head, d_model, ("heads", "embed")),
    }.items():
        params[name], axes[name] = linear_init(next(rs), i, o, axes=ax, dtype=dtype)
    return params, axes


def _split_q(params, x, n_heads, qk_nope, qk_rope, backend="auto",
             act_bits=32):
    B, S, _ = x.shape
    q = linear_apply(params["q"], x, backend=backend,
                     act_bits=act_bits).reshape(
        B, S, n_heads, qk_nope + qk_rope)
    return q[..., :qk_nope], q[..., qk_nope:]


def mla_forward(
    params,
    x: jax.Array,
    positions: jax.Array,
    *,
    n_heads: int,
    kv_lora: int = 512,
    qk_nope: int = 128,
    qk_rope: int = 64,
    v_head: int = 128,
    backend: str = "auto",
    act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Training/prefill (expanded form). Returns (out, cache)."""
    B, S, D = x.shape
    qn, qr = _split_q(params, x, n_heads, qk_nope, qk_rope, backend,
                      act_bits)
    qr = apply_rope(qr, positions)

    dkv = linear_apply(params["dkv"], x, backend=backend,
                       act_bits=act_bits)
    c_kv, k_rope = dkv[..., :kv_lora], dkv[..., kv_lora:]
    k_rope = apply_rope(k_rope[..., None, :], positions)  # (B,S,1,qk_rope)

    kn = linear_apply(params["uk"], c_kv, backend=backend,
                      act_bits=act_bits).reshape(
        B, S, n_heads, qk_nope)
    v = linear_apply(params["uv"], c_kv, backend=backend,
                     act_bits=act_bits).reshape(
        B, S, n_heads, v_head)

    # combined key = [k_nope ; k_rope broadcast to all heads], assembled
    # concat-free (see _assemble_mats; bitwise identical to the concat)
    en, er = _assemble_mats(qk_nope, qk_rope)
    en = jnp.asarray(en, x.dtype)
    er = jnp.asarray(er, x.dtype)
    k = (jnp.einsum("bshn,nd->bshd", kn, en)
         + jnp.einsum("bsr,rd->bsd", k_rope[..., 0, :], er)[:, :, None, :])
    q = jnp.einsum("bshn,nd->bshd", qn, en) + jnp.einsum("bshr,rd->bshd", qr, er)
    scale = (qk_nope + qk_rope) ** -0.5
    o = flash_attention(q, k, v, causal=True, scale=scale)
    out = linear_apply(params["o"], o.reshape(B, S, n_heads * v_head),
                       backend=backend, act_bits=act_bits)
    cache = {"c_kv": c_kv, "k_rope": k_rope[..., 0, :]}
    return out, cache


def mla_decode(
    params,
    x: jax.Array,
    cache: Dict[str, jax.Array],
    cache_len: jax.Array,
    *,
    n_heads: int,
    kv_lora: int = 512,
    qk_nope: int = 128,
    qk_rope: int = 64,
    v_head: int = 128,
    backend: str = "auto",
    act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode against the latent cache (absorbed form).

    x: (B,1,D); cache: {"c_kv": (B,Skv,r), "k_rope": (B,Skv,qk_rope)}.

    The absorbed W_uk/W_uv einsums contract per-head 3-D reshapes of the
    up-projections — no (Kin, N) matmul for ``lutq_dot`` to take, so
    they stay on the dense decode path regardless of ``backend``.
    """
    B, _, D = x.shape
    Skv = cache["c_kv"].shape[1]
    pos = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (B,)).reshape(B, 1)

    qn, qr = _split_q(params, x, n_heads, qk_nope, qk_rope, backend,
                      act_bits)
    qr = apply_rope(qr, pos)  # new token at position cache_len

    dkv = linear_apply(params["dkv"], x, backend=backend,
                       act_bits=act_bits)
    c_new, kr_new = dkv[..., :kv_lora], dkv[..., kv_lora:]
    kr_new = apply_rope(kr_new[..., None, :], pos)[..., 0, :]

    # write into the cache at position cache_len
    idx = pos[:, 0]
    c_kv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        cache["c_kv"], c_new, idx
    )
    k_rope = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))(
        cache["k_rope"], kr_new, idx
    )

    # absorbed scores: q_nope W_uk^T -> latent space
    wuk = materialize(params["uk"]["kernel"], x.dtype).reshape(kv_lora, n_heads, qk_nope)
    q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, 0], wuk)  # (B,H,r)
    s = jnp.einsum("bhr,bkr->bhk", q_lat, c_kv)
    s = s + jnp.einsum("bhd,bkd->bhk", qr[:, 0], k_rope)
    s = (s * ((qk_nope + qk_rope) ** -0.5)).astype(jnp.float32)
    valid = jnp.arange(Skv)[None, :] <= idx[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)

    o_lat = jnp.einsum("bhk,bkr->bhr", p.astype(x.dtype), c_kv)  # (B,H,r)
    wuv = materialize(params["uv"]["kernel"], x.dtype).reshape(kv_lora, n_heads, v_head)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wuv).reshape(B, 1, n_heads * v_head)
    out = linear_apply(params["o"], o, backend=backend, act_bits=act_bits)
    return out, {"c_kv": c_kv, "k_rope": k_rope}
