"""Mamba2 (SSD) block — chunked scan, LUT-Q aware projections.

State-space recurrence per head (scalar decay, Mamba2):
    h_t = a_t * h_{t-1} + dt_t * B_t x_t^T        h in R^{N x P}
    y_t = C_t h_t + D * x_t
with a_t = exp(-dt_t * exp(A_log)).

Training/prefill uses the chunked SSD algorithm: within a chunk the
quadratic "attention-like" form via a segment-sum decay matrix, across
chunks a lax.scan carries the state — O(S*L) memory, sub-quadratic
compute. Decode is a single recurrence step (O(1) state), which is why
the hybrid/SSM architectures run the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.linear import linear_apply, linear_init, materialize
from repro.nn.tree import rng_stream

CONV_K = 4


def mamba2_init(
    key,
    d_model: int,
    *,
    d_inner: int,
    n_state: int = 64,
    head_dim: int = 64,
    dtype=jnp.float32,
):
    n_heads = d_inner // head_dim
    rs = rng_stream(key)
    d_in_proj = 2 * d_inner + 2 * n_state + n_heads  # z, x, B, C, dt
    params, axes = {}, {}
    params["in_proj"], axes["in_proj"] = linear_init(
        next(rs), d_model, d_in_proj, axes=("embed", "heads"), dtype=dtype)
    params["out_proj"], axes["out_proj"] = linear_init(
        next(rs), d_inner, d_model, axes=("heads", "embed"), dtype=dtype)
    conv_dim = d_inner + 2 * n_state
    params["conv_w"] = (jax.random.normal(next(rs), (CONV_K, conv_dim)) * 0.2).astype(dtype)
    params["conv_b"] = jnp.zeros((conv_dim,), dtype)
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32)
    params["D"] = jnp.ones((n_heads,), jnp.float32)
    params["dt_bias"] = jnp.log(jnp.expm1(jnp.full((n_heads,), 0.01))).astype(jnp.float32)
    axes.update({"conv_w": (None, "heads"), "conv_b": ("heads",),
                 "A_log": (None,), "D": (None,), "dt_bias": (None,)})
    return params, axes


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over seq. x: (B,S,C); w: (K,C). Returns (y, new_state)."""
    w = materialize(w, x.dtype)
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, CONV_K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, i : i + S, :] * w[i][None, None, :] for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):, :]
    return jax.nn.silu(y + b[None, None, :]), new_state


def _segsum(logd: jax.Array) -> jax.Array:
    """Lower-triangular pairwise decay exponents: out[t,s] = sum_{s<i<=t} logd_i.

    logd: (..., L). out: (..., L, L) with -inf above the diagonal.
    """
    L = logd.shape[-1]
    cs = jnp.cumsum(logd, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # t,s -> cs_t - cs_s
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,   # (B,S,H,P)
    dt: jax.Array,  # (B,S,H) after softplus
    A: jax.Array,   # (H,) positive decay rates
    Bm: jax.Array,  # (B,S,N)
    Cm: jax.Array,  # (B,S,N)
    *,
    chunk: int = 128,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y: (B,S,H,P), h_final: (B,H,N,P))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    # pad to a chunk multiple with identity steps (dt=0 -> decay 1, no input)
    S0 = S
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S += pad
    c = S // chunk

    # One scan over chunks: both the intra-chunk quadratic form and the
    # inter-chunk state recurrence live inside the scan body, so only ONE
    # chunk's (B,H,L,L) decay/score tensors are materialized at a time —
    # 1/c of the all-chunks-vectorized formulation's working set (the
    # §Perf cell-A memory fix; compute is identical).
    xc = x.reshape(B, c, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, c, chunk, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(B, c, chunk, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(B, c, chunk, N).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    @jax.checkpoint
    def chunk_body(h, inp):
        xk, dtk, Bk, Ck = inp                      # (B,L,H,P) (B,L,H) (B,L,N)
        logd = (-dtk * A[None, None, :]).astype(jnp.float32)  # (B,L,H)
        # intra-chunk: y[t] = sum_{s<=t} C_t.B_s exp(seg) dt_s x_s
        seg = _segsum(logd.transpose(0, 2, 1))     # (B,H,L,L)
        decay = jnp.exp(seg)
        cb = jnp.einsum("bln,bsn->bls", Ck, Bk)    # (B,L,L)
        scores = cb[:, None] * decay * dtk.transpose(0, 2, 1)[:, :, None, :]
        y = jnp.einsum("bhls,bshp->blhp", scores, xk)
        # inter-chunk: y[t] += C_t exp(cum_t) h_in
        cum = jnp.cumsum(logd, axis=1)             # (B,L,H)
        in_decay = jnp.exp(cum)
        y = y + jnp.einsum("bln,blh,bhnp->blhp", Ck, in_decay, h)
        # state update: h' = (chunk decay) h + sum_s exp(cum_L - cum_s) dt_s B_s x_s^T
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)
        state = jnp.einsum("blh,blh,bln,blhp->bhnp", decay_to_end, dtk, Bk, xk)
        h_new = h * jnp.exp(cum[:, -1, :])[..., None, None] + state
        return h_new, y

    hT, ys = jax.lax.scan(chunk_body, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    if pad:
        y = y[:, :S0]
    return y.astype(x.dtype), hT


def mamba2_forward(
    params,
    u: jax.Array,  # (B,S,D)
    *,
    d_inner: int,
    n_state: int = 64,
    head_dim: int = 64,
    chunk: int = 128,
    backend: str = "auto", act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = u.shape
    H = d_inner // head_dim
    zxbcdt = linear_apply(params["in_proj"], u, backend=backend, act_bits=act_bits)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n_state], axis=-1)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + n_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = jnp.exp(params["A_log"])
    y, hT = ssd_chunked(x.reshape(B, S, H, head_dim), dt, A, Bm, Cm, chunk=chunk)
    y = y + x.reshape(B, S, H, head_dim) * params["D"][None, None, :, None]
    y = (y.reshape(B, S, d_inner) * jax.nn.silu(z)).astype(u.dtype)
    out = linear_apply(params["out_proj"], y, backend=backend, act_bits=act_bits)
    return out, {"ssm": hT, "conv": conv_state}


def mamba2_decode(
    params,
    u: jax.Array,  # (B,1,D)
    state: Dict[str, jax.Array],
    *,
    d_inner: int,
    n_state: int = 64,
    head_dim: int = 64,
    backend: str = "auto", act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, _, D = u.shape
    H = d_inner // head_dim
    zxbcdt = linear_apply(params["in_proj"], u, backend=backend, act_bits=act_bits)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n_state], axis=-1)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"], params["conv_b"], state["conv"])
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + n_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,1,H)
    A = jnp.exp(params["A_log"])
    a = jnp.exp(-dt[:, 0] * A[None, :])  # (B,H)
    xh = x.reshape(B, H, head_dim)
    h = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt[:, 0], Bm[:, 0], xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0], h) + xh * params["D"][None, :, None]
    y = (y.reshape(B, 1, d_inner) * jax.nn.silu(z)).astype(u.dtype)
    out = linear_apply(params["out_proj"], y, backend=backend, act_bits=act_bits)
    return out, {"ssm": h, "conv": conv_state}
