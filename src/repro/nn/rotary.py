"""Rotary position embeddings (GPT-NeoX convention, half-split).

Sharding note: the obvious half-split implementation (split the Dh dim,
rotate, concatenate) miscompiles under the SPMD partitioner when Dh is
sharded — concatenating *computed* tensors along a dim the consumer
shards produces wrong values (not an error) on the CPU backend, and at
head-granular tensor parallelism Dh does get sharded whenever the
mesh's model axis exceeds the head count (e.g. a 1-KV-head GQA k
projection on a 4-way model axis). The implementation below therefore
keeps every traced op elementwise on the full-width tensor: the
frequency/sign tables are built full-width as *host* (numpy) constants,
and the rotate-half is a ``roll`` + sign mask. Per-element arithmetic is
unchanged — bit-identical to the half-split form on a single device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=64)
def _rope_tables(head_dim: int, theta: float):
    """(inv_freq doubled, rotate-half sign mask) as host constants."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    inv2 = np.concatenate([inv, inv])
    sign = np.concatenate([-np.ones(half, np.float32),
                           np.ones(half, np.float32)])
    return inv2, sign


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    inv2, sign = _rope_tables(dh, float(theta))
    ang = positions[..., None].astype(jnp.float32) * inv2  # (..., S, dh)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    xf = x.astype(jnp.float32)
    rot = jnp.roll(xf, half, axis=-1) * sign  # (-x2, x1)
    return (xf * jnp.cos(ang) + rot * jnp.sin(ang)).astype(x.dtype)
