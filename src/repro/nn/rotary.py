"""Rotary position embeddings (GPT-NeoX convention, half-split)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)  # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, dh/2)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
