"""Quantization-aware dense / embedding layers.

A kernel leaf is either a plain array (unquantized) or a
:class:`LutqState` — in which case the forward pass uses the paper's
tied weights ``Q = d[A]`` with the straight-through estimator.

Matmul-shaped uses dispatch through the kernel execution-backend layer
(:func:`repro.kernels.ops.lutq_dot`): train-form leaves keep the dense
STE decode, serve-form leaves hit the fused Pallas LUT-Q kernels so the
decoded weight matrix is never materialized in HBM. ``materialize``
remains for gather-style uses (embedding lookup, convolutions).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.actquant import (
    TaggedLutqState,
    fake_quant,
    fake_quant_frozen,
    record_amax,
)
from repro.core.lutq import LutqState, decode_any, quantize_ste_any
from repro.kernels.ops import SpmdLutqState, lutq_dot, lutq_dot_sharded
from repro.kernels.ref import unpack4_kin


def materialize(kernel, dtype=None) -> jax.Array:
    """Decoded (quantized, STE) or raw kernel, cast for compute.

    A LutqState with ``w=None`` is the *deployment* form (paper: store
    only dictionary + assignments): decode without the STE master.
    Gather-style consumers only — matmuls go through :func:`dot_kernel`
    / :func:`repro.kernels.ops.lutq_dot` instead.
    """
    if isinstance(kernel, (SpmdLutqState, TaggedLutqState)):
        kernel = kernel.state  # annotation/calibration wrappers
    if isinstance(kernel, LutqState):
        a = kernel.a
        if a.dtype == jnp.uint8:  # packed 4-bit pairs (serve_view pack4)
            a = unpack4_kin(a)
        if kernel.w is None:
            k = decode_any(kernel.d, a)
        else:
            k = quantize_ste_any(kernel.w, kernel.d, a)
    else:
        k = kernel
    return k.astype(dtype) if dtype is not None and k.dtype != dtype else k


def _quant_act(x: jax.Array, kernel, act_bits: int) -> jax.Array:
    """Activation quantization at the matmul boundary (the regime).

    * pow2-*encoded* leaves (``d.dtype == int8``) quantize internally in
      the shift-add backend (real int8, frozen or dynamic scale) — pass
      ``x`` through untouched so activations are not double-quantized;
    * leaves carrying a frozen calibration pair use it
      (:func:`fake_quant_frozen`), matching the pow2 path's clip;
    * otherwise ``act_bits < 32`` applies the paper's dynamic max-abs
      fake-quant — bit-identical to the historical hand-placed
      ``fake_quant`` calls inside model code (fake_quant is pure, so
      quantize-at-the-boundary == quantize-before-the-call).
    """
    st = kernel.state if isinstance(
        kernel, (SpmdLutqState, TaggedLutqState)) else kernel
    if isinstance(st, LutqState):
        if st.d.dtype == jnp.int8:
            return x
        if st.act is not None:
            return fake_quant_frozen(x, st.act)
    if act_bits < 32:
        return fake_quant(x, act_bits)
    return x


def dot_kernel(x: jax.Array, kernel, *, dtype=None, backend: str = "auto",
               transpose_rhs: bool = False, act_bits: int = 32) -> jax.Array:
    """``x @ kernel`` (or ``x @ kernel.T``) with LUT-Q-aware dispatch.

    LutqState leaves route through the backend layer (train-form keeps
    the dense STE path inside ``lutq_dot``; serve-form hits the fused
    kernels). Leaves annotated by ``ops.annotate_spmd`` inside a meshed
    serving jit dispatch to the shard_map path so each device runs the
    Pallas kernel on its local index shard. Plain arrays are a plain
    matmul.

    ``act_bits`` is the activation-quant regime (model configs pass
    ``cfg.act_bits``): activations are quantized here, at the kernel
    boundary, per the leaf's structure — see :func:`_quant_act`.
    """
    if isinstance(kernel, TaggedLutqState):  # calibration capture
        record_amax(kernel.tag, x)
        kernel = kernel.state
    x = _quant_act(x, kernel, act_bits)
    if isinstance(kernel, SpmdLutqState):
        return lutq_dot_sharded(x, kernel, backend=backend,
                                transpose_rhs=transpose_rhs,
                                out_dtype=dtype or x.dtype)
    if isinstance(kernel, LutqState):
        return lutq_dot(x, kernel, backend=backend,
                        transpose_rhs=transpose_rhs,
                        out_dtype=dtype or x.dtype)
    k = materialize(kernel, dtype or x.dtype)
    return jnp.matmul(x, jnp.swapaxes(k, -1, -2) if transpose_rhs else k)


def linear_init(
    key: jax.Array,
    in_dim: int,
    out_dim: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
    axes: Tuple[str, str] = ("embed", "mlp"),
    scale: Optional[float] = None,
):
    if scale is None:
        scale = in_dim ** -0.5
    params = {"kernel": (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)}
    ax = {"kernel": axes}
    if bias:
        params["bias"] = jnp.zeros((out_dim,), dtype)
        ax["bias"] = (axes[1],)
    return params, ax


def linear_apply(params, x: jax.Array, *, dtype=None,
                 backend: str = "auto", act_bits: int = 32) -> jax.Array:
    y = dot_kernel(x, params["kernel"], dtype=dtype, backend=backend,
                   act_bits=act_bits)
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def embedding_init(
    key: jax.Array,
    vocab: int,
    dim: int,
    *,
    dtype=jnp.float32,
    axes: Tuple[str, str] = ("vocab_in", "embed"),
):
    params = {"table": (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)}
    return params, {"table": axes}


def embedding_apply(params, ids: jax.Array, *, dtype=None) -> jax.Array:
    t = materialize(params["table"], dtype)
    return jnp.take(t, ids, axis=0)


def embedding_logits(params, x: jax.Array, *, backend: str = "auto",
                     act_bits: int = 32) -> jax.Array:
    """Tied-softmax readout: x @ table.T (fused kernels via transposed
    assignments when the table is a serve-form LutqState)."""
    return dot_kernel(x, params["table"], backend=backend,
                      transpose_rhs=True, act_bits=act_bits)
