"""RWKV6 (Finch) — attention-free time-mix with data-dependent decay.

Per head with state S in R^{dk x dv}:
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
where the decay w_t = exp(-exp(w0 + lora(x_t))) is *data-dependent*
(Finch's hallmark). Token-shift mixing uses static learned lerp
coefficients (simplification vs. the paper's data-dependent mix LoRA —
documented in DESIGN.md; the data-dependent decay is kept).

Training runs a two-level scan: an outer scan over chunks stores only
the inter-chunk state, the inner per-step scan is rematerialized
(jax.checkpoint) — O(S/L) stored state instead of O(S). Exact (no
exp-ratio chunking), numerically safe for any decay. Decode is a single
O(1) recurrence step, which is why rwkv6 runs the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.linear import dot_kernel, linear_apply, linear_init
from repro.nn.norms import rmsnorm_apply
from repro.nn.tree import rng_stream


def rwkv6_init(
    key,
    d_model: int,
    *,
    head_dim: int = 64,
    decay_lora: int = 64,
    d_ff: Optional[int] = None,
    dtype=jnp.float32,
):
    """One full RWKV6 layer: time-mix (attention analogue) + channel-mix (FFN)."""
    H = d_model // head_dim
    rs = rng_stream(key)
    params, axes = {}, {}
    for name in ("r", "k", "v", "g", "o"):
        params[name], axes[name] = linear_init(
            next(rs), d_model, d_model, axes=("embed", "heads"), dtype=dtype)
    # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x@w1)@w2))
    params["w0"] = jnp.zeros((d_model,), jnp.float32) - 0.6
    params["w1"] = (jax.random.normal(next(rs), (d_model, decay_lora)) * 0.02).astype(dtype)
    params["w2"] = (jax.random.normal(next(rs), (decay_lora, d_model)) * 0.02).astype(dtype)
    params["u"] = (jax.random.normal(next(rs), (d_model,)) * 0.1).astype(jnp.float32)
    # token-shift lerp coefficients
    for m in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w"):
        params[m] = jnp.full((d_model,), 0.5, jnp.float32)
    params["ln_x"] = jnp.ones((d_model,), jnp.float32)  # per-head norm gain
    axes.update({"w0": ("embed",), "w1": ("embed", None), "w2": (None, "embed"),
                 "u": ("embed",), "ln_x": ("embed",),
                 **{m: ("embed",) for m in ("mix_r", "mix_k", "mix_v", "mix_g", "mix_w")}})
    # channel-mix
    dff = d_ff or 4 * d_model
    params["cm_k"], axes["cm_k"] = linear_init(next(rs), d_model, dff, axes=("embed", "mlp"), dtype=dtype)
    params["cm_v"], axes["cm_v"] = linear_init(next(rs), dff, d_model, axes=("mlp", "embed"), dtype=dtype)
    params["cm_r"], axes["cm_r"] = linear_init(next(rs), d_model, d_model, axes=("embed", "heads"), dtype=dtype)
    params["mix_ck"] = jnp.full((d_model,), 0.5, jnp.float32)
    params["mix_cr"] = jnp.full((d_model,), 0.5, jnp.float32)
    axes["mix_ck"] = ("embed",)
    axes["mix_cr"] = ("embed",)
    return params, axes


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Token shift: x_{t-1} per position; `prev` is the last token of the
    previous segment (decode state). Returns (shifted, new_prev)."""
    B, S, D = x.shape
    if prev is None:
        prev = jnp.zeros((B, 1, D), x.dtype)
    shifted = jnp.concatenate([prev, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _mix(x, xs, m):
    return x + (xs - x) * m[None, None, :].astype(x.dtype)


def _wkv_scan(r, k, v, w, u, s0, *, chunk: int = 64):
    """Exact two-level WKV scan.

    r,k,w: (B,S,H,dk); v: (B,S,H,dv); u: (H,dk); s0: (B,H,dk,dv).
    Returns (y: (B,S,H,dv), sT).
    """
    B, S, H, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    # pad to a chunk multiple with identity steps (w=1, k=v=r=0)
    S0 = S
    pad = (-S) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, z) for t in (r, k, v))
        w = jnp.pad(w, z, constant_values=1.0)
        S += pad
    c = S // chunk

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,dk),(B,H,dk),(B,H,dv),(B,H,dk)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    @jax.checkpoint
    def chunk_fn(s, inp):
        rc, kc, vc, wc = inp  # (L,B,H,*)
        s, ys = jax.lax.scan(step, s, (rc, kc, vc, wc))
        return s, ys

    def to_chunks(x):
        return x.reshape(B, c, chunk, H, -1).transpose(1, 2, 0, 3, 4)  # (c,L,B,H,*)

    sT, ys = jax.lax.scan(chunk_fn, s0, (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w)))
    # ys: (c, L, B, H, dv)
    y = ys.transpose(2, 0, 1, 3, 4).reshape(B, S, H, dv)
    if pad:
        y = y[:, :S0]
    return y, sT


def rwkv6_time_mix(
    params, x: jax.Array, state: Optional[Dict[str, jax.Array]],
    *, head_dim: int = 64, chunk: int = 64, backend: str = "auto", act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, S, D = x.shape
    H = D // head_dim
    prev = None if state is None else state["shift_t"]
    xs, new_prev = _shift(x, prev)

    xr = _mix(x, xs, params["mix_r"])
    xk = _mix(x, xs, params["mix_k"])
    xv = _mix(x, xs, params["mix_v"])
    xg = _mix(x, xs, params["mix_g"])
    xw = _mix(x, xs, params["mix_w"])

    r = linear_apply(params["r"], xr, backend=backend, act_bits=act_bits).reshape(B, S, H, head_dim)
    k = linear_apply(params["k"], xk, backend=backend, act_bits=act_bits).reshape(B, S, H, head_dim)
    v = linear_apply(params["v"], xv, backend=backend, act_bits=act_bits).reshape(B, S, H, head_dim)
    g = linear_apply(params["g"], xg, backend=backend, act_bits=act_bits)

    xw32 = xw.astype(jnp.float32)
    lora = dot_kernel(jnp.tanh(dot_kernel(xw32, params["w1"], backend=backend, act_bits=act_bits)),
                      params["w2"], backend=backend, act_bits=act_bits)
    logw = -jnp.exp(jnp.clip(params["w0"][None, None, :] + lora, -8.0, 4.0))
    w = jnp.exp(logw).reshape(B, S, H, head_dim)  # decay in (0,1)

    u = params["u"].reshape(H, head_dim)
    s0 = jnp.zeros((B, H, head_dim, head_dim), jnp.float32) if state is None else state["wkv"]
    y, sT = _wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u, s0, chunk=chunk)

    # per-head normalization then gate
    y = y.reshape(B, S, H, head_dim)
    y = rmsnorm_apply({"scale": params["ln_x"].reshape(H, head_dim)[None, None]},
                      y).reshape(B, S, D).astype(x.dtype)
    out = linear_apply(params["o"], y * jax.nn.silu(g), backend=backend, act_bits=act_bits)
    return out, {"shift_t": new_prev, "wkv": sT}


def rwkv6_channel_mix(
    params, x: jax.Array, state: Optional[Dict[str, jax.Array]],
    *, backend: str = "auto", act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    prev = None if state is None else state["shift_c"]
    xs, new_prev = _shift(x, prev)
    xk = _mix(x, xs, params["mix_ck"])
    xr = _mix(x, xs, params["mix_cr"])
    k = jnp.square(jax.nn.relu(linear_apply(params["cm_k"], xk, backend=backend, act_bits=act_bits)))
    out = (jax.nn.sigmoid(linear_apply(params["cm_r"], xr, backend=backend, act_bits=act_bits))
           * linear_apply(params["cm_v"], k, backend=backend, act_bits=act_bits))
    return out, {"shift_c": new_prev}


def rwkv6_layer(
    params, x: jax.Array, state: Optional[Dict[str, jax.Array]] = None,
    *, head_dim: int = 64, chunk: int = 64, backend: str = "auto", act_bits: int = 32,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full pre-norm RWKV6 layer (time-mix + channel-mix). Norms are
    applied by the caller (model assembles ln -> tmix -> ln -> cmix)."""
    t_out, t_state = rwkv6_time_mix(params, x, state, head_dim=head_dim,
                                    chunk=chunk, backend=backend, act_bits=act_bits)
    return t_out, t_state
