"""Conv2D (NHWC) — LUT-Q aware, for the paper's CNN experiments."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.nn.linear import _quant_act, materialize


def conv_init(key, kh: int, kw: int, cin: int, cout: int, *, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout)) * (2.0 / fan_in) ** 0.5
    return {"kernel": w.astype(dtype)}, {"kernel": (None, None, "embed", "mlp")}


def conv_apply(params, x: jax.Array, *, stride: int = 1, padding: str = "SAME",
               act_bits: int = 32) -> jax.Array:
    """NHWC conv with the activation-quant regime at the kernel boundary
    (``act_bits=32`` keeps the input untouched; callers that pre-quantize
    — e.g. resnet's unsigned post-ReLU variant — pass the default)."""
    x = _quant_act(x, params["kernel"], act_bits)
    k = materialize(params["kernel"], x.dtype)
    return jax.lax.conv_general_dilated(
        x, k, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
