"""Normalization layers (RMSNorm / LayerNorm). BN lives in core.mlbn."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm_apply(params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)
