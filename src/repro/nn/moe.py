"""Top-k Mixture-of-Experts with capacity-based dispatch (GShard-style).

Dispatch is sort-free: slot positions come from a cumulative sum over the
(slots, experts) one-hot, tokens are scattered into a per-expert
capacity-padded buffer, expert FFNs run as one batched matmul (sharded
expert-parallel on the 'model' mesh axis), and outputs are combined with
the gate weights. Overflowing tokens are dropped (capacity factor
configurable), underflow is zero-padded — standard dropping MoE.

Expert weights are LUT-Q quantized with *per-expert dictionaries*
(the dictionary axis stacks over E), which is where LUT-Q's memory win
is largest: expert weights dominate MoE parameter counts.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.actquant import TaggedLutqState, record_amax
from repro.core.lutq import LutqState
from repro.kernels.ops import SpmdLutqState, lutq_dot, lutq_dot_sharded
from repro.nn.linear import _quant_act, dot_kernel, materialize
from repro.nn.tree import rng_stream


def _expert_dot(buf: jax.Array, leaf, cdt, backend: str = "auto",
                act_bits: int = 32) -> jax.Array:
    """Batched per-expert matmul: (E, C, Din) @ leaf (E, Din, Dout).

    Serve-form LUT-Q experts (stacked per-expert dictionaries) vmap the
    kernel backend layer over E, so each expert's fused Pallas kernel
    streams its own int8/packed assignments — the decoded expert weights
    (the bulk of MoE parameters) are never materialized in HBM. Leaves
    annotated by ``ops.annotate_spmd`` run expert-parallel through the
    shard_map path (each device computes its local experts' kernels).
    Train form / plain arrays keep the dense einsum.
    """
    if isinstance(leaf, TaggedLutqState):  # calibration capture
        record_amax(leaf.tag, buf)
        leaf = leaf.state
    buf = _quant_act(buf, leaf, act_bits)
    if (isinstance(leaf, SpmdLutqState) and leaf.w is None
            and leaf.d.ndim == 2 and leaf.a.ndim == 3):
        return lutq_dot_sharded(buf, leaf, backend=backend, out_dtype=cdt)
    if (isinstance(leaf, LutqState) and leaf.w is None
            and leaf.d.ndim == 2 and leaf.a.ndim == 3):
        return jax.vmap(
            lambda b, d, a, c: lutq_dot(b, LutqState(w=None, d=d, a=a, act=c),
                                        backend=backend, out_dtype=cdt)
        )(buf, leaf.d, leaf.a, leaf.act)
    return jnp.einsum("ecd,edf->ecf", buf, materialize(leaf, cdt))


def moe_init(
    key,
    d_model: int,
    d_ff: int,
    n_experts: int,
    *,
    n_shared: int = 0,
    d_ff_shared: Optional[int] = None,
    dtype=jnp.float32,
):
    rs = rng_stream(key)
    s = d_model ** -0.5
    params = {
        "router": (jax.random.normal(next(rs), (d_model, n_experts)) * s).astype(jnp.float32),
        "wi": (jax.random.normal(next(rs), (n_experts, d_model, d_ff)) * s).astype(dtype),
        "wg": (jax.random.normal(next(rs), (n_experts, d_model, d_ff)) * s).astype(dtype),
        "wo": (jax.random.normal(next(rs), (n_experts, d_ff, d_model)) * (d_ff ** -0.5)).astype(dtype),
    }
    axes = {
        "router": ("embed", None),
        "wi": ("expert", "embed", "moe_mlp"),
        "wg": ("expert", "embed", "moe_mlp"),
        "wo": ("expert", "moe_mlp", "embed"),
    }
    if n_shared > 0:
        dsh = d_ff_shared or d_ff * n_shared
        params["shared_wi"] = (jax.random.normal(next(rs), (d_model, dsh)) * s).astype(dtype)
        params["shared_wg"] = (jax.random.normal(next(rs), (d_model, dsh)) * s).astype(dtype)
        params["shared_wo"] = (jax.random.normal(next(rs), (dsh, d_model)) * (dsh ** -0.5)).astype(dtype)
        axes["shared_wi"] = ("embed", "mlp")
        axes["shared_wg"] = ("embed", "mlp")
        axes["shared_wo"] = ("mlp", "embed")
    return params, axes


def moe_apply(
    params,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    dtype=None,
    backend: str = "auto",
    act_bits: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss).

    Expert weights carry per-expert (stacked) dictionaries: serve-form
    experts vmap the kernel backend layer over the expert axis (see
    ``_expert_dot``), train-form experts keep the dense STE einsum. The
    unstacked shared-expert projections route through ``dot_kernel``.
    """
    B, S, D = x.shape
    cdt = dtype or x.dtype
    T = B * S
    xt = x.reshape(T, D)

    router = params["router"]
    E = router.shape[-1]
    logits = (xt.astype(jnp.float32) @ router.astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    C = max(1, int(math.ceil(T * top_k / E * capacity_factor)))

    # slot-major flattening: (T, k) -> (T*k,)
    e_flat = expert_ids.reshape(-1)          # (T*k,)
    g_flat = gate_vals.reshape(-1).astype(jnp.float32)

    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)       # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                 # position before me
    pos_of = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = (pos_of < C) & (g_flat > 0)

    # scatter tokens into (E*C, D)
    slot = jnp.where(keep, e_flat * C + pos_of, E * C)  # overflow -> dump row
    x_rep = jnp.repeat(xt[:, None, :], top_k, axis=1).reshape(T * top_k, D)
    buf = jnp.zeros((E * C + 1, D), cdt).at[slot].add(x_rep.astype(cdt))
    buf = buf[: E * C].reshape(E, C, D)

    h = (_expert_dot(buf, params["wi"], cdt, backend, act_bits)
         * jax.nn.silu(_expert_dot(buf, params["wg"], cdt, backend, act_bits)))
    out_buf = _expert_dot(h, params["wo"], cdt, backend,
                          act_bits).reshape(E * C, D)

    # combine
    gathered = jnp.take(out_buf, jnp.minimum(slot, E * C - 1), axis=0)
    gathered = gathered * (keep & (slot < E * C))[:, None].astype(cdt)
    combined = (gathered.astype(jnp.float32) * g_flat[:, None]).reshape(T, top_k, D).sum(1)
    out = combined.reshape(B, S, D).astype(x.dtype)

    if "shared_wi" in params:
        xs = x.astype(cdt)
        sh = (dot_kernel(xs, params["shared_wi"], backend=backend,
                         act_bits=act_bits)
              * jax.nn.silu(dot_kernel(xs, params["shared_wg"],
                                       backend=backend, act_bits=act_bits)))
        out = out + dot_kernel(sh, params["shared_wo"], backend=backend,
                               act_bits=act_bits).astype(x.dtype)
    return out, aux


def moe_apply_dense(params, x: jax.Array, *, top_k: int) -> Tuple[jax.Array, jax.Array]:
    """Oracle: every expert on every token, masked combine. O(T*E) compute."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, top_k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    gates = jnp.zeros_like(probs).at[jnp.arange(xt.shape[0])[:, None], ei].set(gv)
    wi, wg, wo = (materialize(params[k], xt.dtype) for k in ("wi", "wg", "wo"))
    h = jnp.einsum("td,edf->tef", xt, wi) * jax.nn.silu(jnp.einsum("td,edf->tef", xt, wg))
    per_e = jnp.einsum("tef,efd->ted", h, wo)
    out = jnp.einsum("ted,te->td", per_e, gates.astype(xt.dtype))
    me = probs.mean(0)
    ce = jnp.zeros((E,), jnp.float32).at[ei.reshape(-1)].add(1.0) / ei.size
    if "shared_wi" in params:
        sh = (xt @ materialize(params["shared_wi"], xt.dtype)) * jax.nn.silu(
            xt @ materialize(params["shared_wg"], xt.dtype))
        out = out + sh @ materialize(params["shared_wo"], xt.dtype)
    return out.reshape(B, S, D), E * jnp.sum(me * ce)
