"""Serving utilities: prefill-cache adaptation + batched generation.

Bridges ``prefill`` (which returns caches sized to the prompt) and
``decode_step`` (which expects max_len caches, ring-layout for SWA):
  * ``grow_cache``: right-pad linear caches to max_len;
  * ``ring_from_linear``: re-lay a linear KV cache into the SWA ring
    (slot = position % window) so decode can continue a long prompt;
  * ``decode_fn`` / ``prefill_fn``: jit-cached entry points keyed on
    the (hashable) ModelConfig, shared by the library loop and the
    serving CLI so both reuse one trace per config;
  * ``generate``: static-batch generation — a thin wrapper over the
    continuous-batching slot pool in ``runtime.engine`` (one batched
    prefill, then the engine's decode/retire loop), with per-stream
    ``lengths`` support for ragged right-padded batches.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import api
from repro.models.config import ModelConfig


def ring_from_linear(lin: jax.Array, prompt_len, window: int) -> jax.Array:
    """lin: (B, S_prompt, ...) linear cache -> (B, window, ...) ring.

    Position p lands in slot p % window; only the last `window`
    positions survive (they are the only live ones under SWA).
    ``prompt_len`` may be a python int, a scalar, or a per-stream (B,)
    vector — ragged batches relay each stream at its own length. The
    relay is a pure gather (slot s reads position
    ``s + window * floor((len-1-s)/window)``), so it traces without a
    host sync and vmaps over stacked layers.
    """
    B, S = lin.shape[:2]
    L = jnp.broadcast_to(jnp.asarray(prompt_len, jnp.int32).reshape(-1),
                         (B,))[:, None]                       # (B, 1)
    s = jnp.arange(window, dtype=jnp.int32)[None, :]          # (1, W)
    p = s + window * ((L - 1 - s) // window)   # slot's live position
    valid = p >= 0                             # slot empty when len < window
    idx = jnp.clip(p, 0, S - 1).reshape((B, window) + (1,) * (lin.ndim - 2))
    gathered = jnp.take_along_axis(lin, idx, axis=1)
    mask = valid.reshape((B, window) + (1,) * (lin.ndim - 2))
    return jnp.where(mask, gathered, jnp.zeros((), lin.dtype))


def grow_cache(cache_small, cache_big):
    """Right-pad every linear-seq leaf of `cache_small` into the
    max_len-sized `cache_big` (leaves with matching shape pass through)."""

    def merge(big, small):
        if big.shape == small.shape:
            return small.astype(big.dtype)
        pad = [(0, b - s) for b, s in zip(big.shape, small.shape)]
        return jnp.pad(small.astype(big.dtype), pad)

    return jax.tree.map(merge, cache_big, cache_small)


def adapt_prefill_cache(cfg: ModelConfig, cache, batch: int, max_len: int,
                        *, src_len: int = 0, lengths=None):
    """Convert a prefill cache into a decode-ready cache of max_len.

    ``lengths``: optional per-stream (B,) prompt lengths for ragged
    (right-padded) batches. Defaults to the prefill cache's own ``len``
    vector — never ``len[0]`` broadcast to the batch, and never forced
    to the host: the whole adaptation traces, so it can run inside jit
    (the engine's admission path relies on this).
    """
    target = api.init_cache(cfg, batch, max_len, src_len=src_len)
    if lengths is None:
        lengths = cache["len"]
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                               (batch,))
    cache = dict(cache)
    cache["len"] = lengths

    attn_kv = cfg.family in ("dense", "moe", "vlm") and not cfg.use_mla
    if attn_kv and cfg.kv_cache_bits == 8:
        # prefill emits float K/V; the decode cache holds int8 + scales
        # (§Perf cell C), so quantize on adaptation.
        from repro.models.lm import _kv_quant

        def quant_kv(layers):
            layers = dict(layers)
            for key in ("k", "v"):
                q, s = _kv_quant(layers[key], 8)
                layers[key], layers[f"{key}_scale"] = q, s
            return layers

        cache["layers"] = quant_kv(cache["layers"])
        if "prefix_layers" in cache:
            cache["prefix_layers"] = {k: quant_kv(v)
                                      for k, v in cache["prefix_layers"].items()}

    if attn_kv and cfg.window is not None:
        # SWA ring: re-lay per-position leaves at the decode ring width,
        # each stream at its own length
        layers = dict(cache["layers"])
        keys = [k for k in ("k", "v", "k_scale", "v_scale") if k in layers]
        for key in keys:
            lin = layers[key]  # (L, B, S, ...)
            eff = target["layers"][key].shape[2]
            ring = jax.vmap(lambda x: ring_from_linear(x, lengths, eff))(lin)
            layers[key] = ring.astype(target["layers"][key].dtype)
        out = dict(cache)
        out["layers"] = layers
        return out
    return grow_cache(cache, target)


def _decode_step(cfg: ModelConfig, params, token, cache):
    return api.decode_step(params, cfg, token, cache)


def _decode_step_meshed(cfg: ModelConfig, axes, mesh, params, token, cache):
    # annotate inside the trace: serve-form LutqState leaves gain their
    # mesh + assignment spec so nn-layer dots run shard-local Pallas
    # kernels instead of GSPMD's gather-around-the-custom-call fallback
    params = ops.annotate_spmd(params, axes, mesh)
    return api.decode_step(params, cfg, token, cache)


def _prefill(cfg: ModelConfig, max_len: int, params, batch, lengths=None):
    return api.prefill(params, cfg, batch, max_len=max_len, lengths=lengths)


def _prefill_meshed(cfg: ModelConfig, max_len: int, axes, mesh, params,
                    batch, lengths=None):
    params = ops.annotate_spmd(params, axes, mesh)
    return api.prefill(params, cfg, batch, max_len=max_len, lengths=lengths)


@functools.lru_cache(maxsize=64)
def _decode_fn_cached(cfg: ModelConfig, mesh, batch, max_len, src_len,
                      tuning):
    del tuning  # lru salt only: tuned tiles are baked into the trace
    if mesh is None:
        return jax.jit(functools.partial(_decode_step, cfg))
    from repro.launch.partition import serve_shardings

    axes = api.init_axes(cfg)
    sh = serve_shardings(cfg, mesh, batch=batch, max_len=max_len,
                         src_len=src_len)
    return jax.jit(functools.partial(_decode_step_meshed, cfg, axes, mesh),
                   in_shardings=(None, sh["token"], sh["cache"]),
                   out_shardings=(sh["logits"], sh["cache"]))


def decode_fn(cfg: ModelConfig, mesh=None, batch: Optional[int] = None,
              max_len: Optional[int] = None, src_len: int = 0):
    """Jit-cached one-token decode for a config (and optionally a mesh).

    ModelConfig is a frozen (hashable) dataclass, so repeated ``generate``
    calls — and the serving CLI — share one compiled decode per config
    instead of re-wrapping (and re-tracing) a fresh lambda per call. The
    lru key carries :func:`repro.kernels.ops.tuning_fingerprint`, so a
    tuning-cache update (``--autotune``) invalidates traces that baked
    in stale tile choices.

    With ``mesh`` (a hashable ``jax.sharding.Mesh`` — it is part of the
    cache key, so switching meshes in one process never reuses a stale
    trace) the jit takes explicit in/out NamedShardings from
    ``partition.serve_shardings``: token + cache batch-sharded on the
    data axis, cache layout preserved through the step, params left to
    their committed placement (``shard_serve_params``) and annotated
    in-trace (``ops.annotate_spmd``) so fused LUT-Q dots run on local
    index shards.
    """
    if mesh is not None and (batch is None or max_len is None):
        raise ValueError("decode_fn(cfg, mesh) needs the pool geometry: "
                         "pass batch= and max_len= (they size the cache "
                         "shardings)")
    return _decode_fn_cached(cfg, mesh, batch, max_len, src_len,
                             ops.tuning_fingerprint())


@functools.lru_cache(maxsize=64)
def _prefill_fn_cached(cfg: ModelConfig, max_len: int, mesh, tuning):
    del tuning
    if mesh is None:
        return jax.jit(functools.partial(_prefill, cfg, max_len))
    from repro.launch.partition import data_batch_shardings

    axes = api.init_axes(cfg)
    fn = jax.jit(functools.partial(_prefill_meshed, cfg, max_len, axes, mesh))

    def sharded(params, batch, lengths=None):
        batch = jax.device_put(batch, data_batch_shardings(batch, mesh))
        if lengths is None:
            return fn(params, batch)
        return fn(params, batch, lengths)

    return sharded


def prefill_fn(cfg: ModelConfig, max_len: int, mesh=None):
    """Jit-cached prefill for (config, max_len[, mesh]).

    The mesh variant places batch inputs onto their data-parallel
    NamedShardings before the call (prefill's cache output is re-laid by
    the admission splice, whose jit pins the pool shardings) and
    annotates params in-trace so fused LUT-Q dots run shard-local. Like
    ``decode_fn``, the lru key carries the tuning-cache fingerprint.
    """
    return _prefill_fn_cached(cfg, max_len, mesh, ops.tuning_fingerprint())


# ---------------------------------------------------------------------------
# paged entry points (block-table KV; see runtime/paged_kv.py)
# ---------------------------------------------------------------------------

def _paged_decode_step(cfg: ModelConfig, params, token, cache):
    return api.paged_decode_step(params, cfg, token, cache)


def _paged_decode_step_meshed(cfg: ModelConfig, axes, mesh, params, token,
                              cache):
    params = ops.annotate_spmd(params, axes, mesh)
    # the mesh rides into the paged-attention kernel dispatch so it can
    # shard_map over ("data", "model") instead of leaving GSPMD to
    # partition the block-table walk
    return api.paged_decode_step(params, cfg, token, cache, mesh=mesh)


@functools.lru_cache(maxsize=64)
def _paged_decode_fn_cached(cfg: ModelConfig, mesh, batch, n_pages,
                            page_size, n_blocks, src_len, tuning):
    del tuning
    if mesh is None:
        return jax.jit(functools.partial(_paged_decode_step, cfg))
    from repro.launch.partition import paged_serve_shardings

    axes = api.init_axes(cfg)
    sh = paged_serve_shardings(cfg, mesh, batch=batch, n_pages=n_pages,
                               page_size=page_size, n_blocks=n_blocks,
                               src_len=src_len)
    return jax.jit(
        functools.partial(_paged_decode_step_meshed, cfg, axes, mesh),
        in_shardings=(None, sh["token"], sh["cache"]),
        out_shardings=(sh["logits"], sh["cache"]))


def paged_decode_fn(cfg: ModelConfig, mesh=None, batch: Optional[int] = None,
                    n_pages: Optional[int] = None,
                    page_size: Optional[int] = None,
                    n_blocks: Optional[int] = None, src_len: int = 0):
    """Jit-cached paged decode step (same contract as ``decode_fn``).

    With ``mesh`` the jit pins the paged-cache NamedShardings from
    ``partition.paged_serve_shardings``: the page pool is model-sharded
    on the KV-head axis and replicated over data (any slot's block row
    may reference any page), block table/lengths batch-sharded on data.
    """
    if mesh is not None and (batch is None or n_pages is None
                             or page_size is None or n_blocks is None):
        raise ValueError("paged_decode_fn(cfg, mesh) needs the pool "
                         "geometry: batch=, n_pages=, page_size=, n_blocks=")
    return _paged_decode_fn_cached(cfg, mesh, batch, n_pages, page_size,
                                   n_blocks, src_len,
                                   ops.tuning_fingerprint())


@functools.lru_cache(maxsize=64)
def _paged_chunk_fn_cached(cfg: ModelConfig, tuning):
    del tuning
    from repro.models import lm as m_lm

    return jax.jit(lambda params, tokens, ws, start, n_real:
                   m_lm.lm_paged_prefill_chunk(params, cfg, tokens, ws,
                                               start, n_real))


def paged_chunk_fn(cfg: ModelConfig):
    """One jit for every chunk width: jax re-traces per (1, C) token
    shape, so ``_cache_size()`` counts exactly the bucket widths hit —
    the engine's no-new-traces-after-warmup assertion keys on this."""
    return _paged_chunk_fn_cached(cfg, ops.tuning_fingerprint())


@functools.lru_cache(maxsize=64)
def _paged_packed_fn_cached(cfg: ModelConfig, wws: int, tuning):
    del tuning
    from repro.models import lm as m_lm

    return jax.jit(lambda params, tokens, pool, blocks, bases, hists, lens:
                   m_lm.lm_paged_prefill_packed(params, cfg, tokens, pool,
                                                blocks, bases, hists, lens,
                                                wws))


def paged_packed_fn(cfg: ModelConfig, wws: int):
    """Fused packed prefill (hydrate + chunk + splice + per-segment
    logits) for several short prompts in one call. Like
    ``paged_chunk_fn``, jax re-traces per packed (1, C) bucket width —
    ``_cache_size()`` counts exactly the widths hit."""
    return _paged_packed_fn_cached(cfg, wws, ops.tuning_fingerprint())


@functools.lru_cache(maxsize=64)
def paged_splice_fn(cfg: ModelConfig):
    from repro.models import lm as m_lm

    return jax.jit(functools.partial(m_lm.lm_paged_splice, cfg))


@functools.lru_cache(maxsize=64)
def paged_hydrate_fn(cfg: ModelConfig, wws: int):
    from repro.models import lm as m_lm

    return jax.jit(lambda pool, row, hist:
                   m_lm.lm_paged_hydrate(cfg, pool, row, hist, wws))


@functools.lru_cache(maxsize=64)
def paged_encdec_splice_fn(cfg: ModelConfig):
    from repro.models import encdec as m_encdec

    return jax.jit(functools.partial(m_encdec.encdec_paged_splice, cfg))


def generate(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    steps: int,
    lengths=None,
    max_len: Optional[int] = None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    backend: Optional[str] = None,
    eos_id: Optional[int] = None,
    return_stats: bool = False,
    mesh=None,
    speculative: int = 0,
    draft_bits: int = 3,
    draft_params=None,
):
    """Prefill the prompt then decode `steps` tokens. Returns (B, steps).

    A thin static-batch wrapper over ``runtime.engine.Engine``: the
    whole batch is preloaded into a capacity-B slot pool with one
    batched prefill, then decoded by the engine's slot loop. This keeps
    one code path for sampling, retirement and stats between static and
    continuous batching.

    ``lengths``: per-stream prompt lengths for ragged (right-padded)
    batches. Each stream's first token is sampled from the logits at its
    own last *real* position and its cache continues from its own
    length. For recurrent families (ssm/hybrid) a padded prefill would
    corrupt the state, so ragged batches are prefilled per stream at
    exact length through the engine's admission path instead.

    ``eos_id``: optional early stop per stream; retired streams are
    right-padded with ``eos_id`` so the result stays (B, steps).

    ``backend``: optional kernel-backend override (auto | decode | fused
    | packed4) applied as ``cfg.replace(kernel_backend=...)``, so serve
    trees hit the requested Pallas LUT-Q path. ``return_stats=True``
    additionally returns {"t_prefill_s", "t_decode_s", "decode_tok_s",
    "backend"} measured around the jit-cached entry points (the same
    ones the CLI times, so library and CLI numbers agree).

    ``mesh``: optional ``("data", "model")`` device mesh for SPMD
    serving — params should already be placed (``shard_serve_params``);
    the engine places its slot pool/caches batch-on-data and the decode
    jits take explicit NamedShardings (see docs/sharding.md). Output is
    token-identical to the un-meshed path.

    ``speculative``: draft block length k for self-speculative decoding
    (0 disables; see ``runtime.speculative``). ``draft_bits`` sizes the
    coarsened draft view derived from the SAME LUT-Q weights unless an
    explicit ``draft_params`` tree is given. Greedy output is
    token-identical to ``speculative=0``; the cache is sized with k
    extra positions of verify-window headroom.
    """
    import numpy as np

    from repro.runtime.engine import Engine

    toks = batch["tokens"]
    B, P = toks.shape
    if lengths is not None:
        lengths = np.broadcast_to(
            np.asarray(jax.device_get(lengths), np.int32).reshape(-1), (B,))
    eng = Engine(
        params, cfg, capacity=B,
        max_len=max_len or (P + steps + int(speculative)),
        src_len=batch["frames"].shape[1] if cfg.family == "encdec" else 0,
        temperature=temperature, rng=rng, backend=backend, mesh=mesh,
        speculative=speculative, draft_bits=draft_bits,
        draft_params=draft_params)

    # recurrent state has no positions to mask and MoE expert capacity
    # couples real tokens to padding, so ANY padding (ragged or
    # uniformly short-of-P) corrupts those families — prefill each
    # stream at its exact length through the admission path instead
    padded = lengths is not None and (int(lengths.min()) != int(lengths.max())
                                      or int(lengths.max()) != P)
    if padded and (cfg.family in ("ssm", "hybrid") or cfg.n_experts):
        toks_h = np.asarray(jax.device_get(toks), np.int32)
        for i in range(B):
            eng.submit(toks_h[i, :int(lengths[i])], max_new=steps,
                       eos_id=eos_id)
        results = eng.run()
    else:
        eng.preload(batch, steps, lengths=lengths, eos_id=eos_id)
        results = eng.run()

    pad = 0 if eos_id is None else eos_id
    gen = np.full((B, steps), pad, np.int32)
    for r in results:
        t = r["tokens"]
        gen[r["rid"], :len(t)] = t
    gen = jnp.asarray(gen)
    if return_stats:
        stats = eng.stats()
        out = {
            "t_prefill_s": stats["t_prefill_s"],
            "t_decode_s": stats["t_decode_s"],
            "decode_tok_s": stats["decode_tok_s"],
            "backend": cfg.kernel_backend if backend is None else backend,
        }
        if speculative:
            for k in ("acceptance_rate", "spec_rounds",
                      "spec_tokens_per_round", "tokens_per_engine_step",
                      "draft_extra_bytes"):
                if k in stats:
                    out[k] = stats[k]
        return gen, out
    return gen
