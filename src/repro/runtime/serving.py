"""Serving utilities: prefill-cache adaptation + batched generation.

Bridges ``prefill`` (which returns caches sized to the prompt) and
``decode_step`` (which expects max_len caches, ring-layout for SWA):
  * ``grow_cache``: right-pad linear caches to max_len;
  * ``ring_from_linear``: re-lay a linear KV cache into the SWA ring
    (slot = position % window) so decode can continue a long prompt;
  * ``decode_fn`` / ``prefill_fn``: jit-cached entry points keyed on
    the (hashable) ModelConfig, shared by the library loop and the
    serving CLI so both reuse one trace per config;
  * ``generate``: batched greedy/temperature generation loop.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig


def ring_from_linear(lin: jax.Array, prompt_len: int, window: int) -> jax.Array:
    """lin: (B, S_prompt, ...) linear cache -> (B, window, ...) ring.

    Position p lands in slot p % window; only the last `window`
    positions survive (they are the only live ones under SWA).
    """
    B, S = lin.shape[:2]
    keep = lin[:, max(0, prompt_len - window):prompt_len]
    k = keep.shape[1]
    positions = jnp.arange(prompt_len - k, prompt_len) % window
    out = jnp.zeros((B, window) + lin.shape[2:], lin.dtype)
    return out.at[:, positions].set(keep)


def grow_cache(cache_small, cache_big):
    """Right-pad every linear-seq leaf of `cache_small` into the
    max_len-sized `cache_big` (leaves with matching shape pass through)."""

    def merge(big, small):
        if big.shape == small.shape:
            return small.astype(big.dtype)
        pad = [(0, b - s) for b, s in zip(big.shape, small.shape)]
        return jnp.pad(small.astype(big.dtype), pad)

    return jax.tree.map(merge, cache_big, cache_small)


def adapt_prefill_cache(cfg: ModelConfig, cache, batch: int, max_len: int,
                        *, src_len: int = 0):
    """Convert a prefill cache into a decode-ready cache of max_len."""
    target = api.init_cache(cfg, batch, max_len, src_len=src_len)
    prompt_len = int(cache["len"][0]) if hasattr(cache["len"], "shape") else cache["len"]

    if cfg.family in ("dense", "moe", "vlm") and cfg.window is not None \
            and not cfg.use_mla:
        # SWA ring: re-lay k/v at the decode cache's ring width
        layers = dict(cache["layers"])
        for key in ("k", "v"):
            lin = cache["layers"][key]  # (L, B, S, H, dh)
            eff = target["layers"][key].shape[2]
            ring = jax.vmap(lambda x: ring_from_linear(x, prompt_len, eff))(lin)
            layers[key] = ring.astype(target["layers"][key].dtype)
        out = dict(cache)
        out["layers"] = layers
        return out
    return grow_cache(cache, target)


def _decode_step(cfg: ModelConfig, params, token, cache):
    return api.decode_step(params, cfg, token, cache)


def _prefill(cfg: ModelConfig, max_len: int, params, batch):
    return api.prefill(params, cfg, batch, max_len=max_len)


@functools.lru_cache(maxsize=64)
def decode_fn(cfg: ModelConfig):
    """Jit-cached one-token decode for a config.

    ModelConfig is a frozen (hashable) dataclass, so repeated ``generate``
    calls — and the serving CLI — share one compiled decode per config
    instead of re-wrapping (and re-tracing) a fresh lambda per call.
    """
    return jax.jit(functools.partial(_decode_step, cfg))


@functools.lru_cache(maxsize=64)
def prefill_fn(cfg: ModelConfig, max_len: int):
    """Jit-cached prefill for (config, max_len)."""
    return jax.jit(functools.partial(_prefill, cfg, max_len))


def generate(
    params,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    *,
    steps: int,
    max_len: Optional[int] = None,
    temperature: float = 0.0,
    rng: Optional[jax.Array] = None,
    backend: Optional[str] = None,
    return_stats: bool = False,
):
    """Prefill the prompt then decode `steps` tokens. Returns (B, steps).

    ``backend``: optional kernel-backend override (auto | decode | fused
    | packed4) applied as ``cfg.replace(kernel_backend=...)``, so serve
    trees hit the requested Pallas LUT-Q path. ``return_stats=True``
    additionally returns {"t_prefill_s", "t_decode_s", "decode_tok_s",
    "backend"} measured around the jit-cached entry points (the same
    ones the CLI times, so library and CLI numbers agree).
    """
    if backend is not None:
        cfg = cfg.replace(kernel_backend=backend)
    toks = batch["tokens"]
    B, P = toks.shape
    # max_len counts text tokens; prepended modality embeddings (vlm)
    # occupy cache slots too, so widen the decode cache by the prefix.
    prefix = cfg.n_prefix_tokens if "prefix_embeds" in batch else 0
    max_len = (max_len or (P + steps)) + prefix

    t0 = time.perf_counter()
    logits, cache = prefill_fn(cfg, max_len)(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    cache = adapt_prefill_cache(
        cfg, cache, B, max_len,
        src_len=batch["frames"].shape[1] if cfg.family == "encdec" else 0)

    decode = decode_fn(cfg)

    def sample(lg, key):
        lg = lg[:, -1].astype(jnp.float32)
        if temperature <= 0:
            return jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, lg / temperature)[:, None].astype(jnp.int32)

    key = rng if rng is not None else jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    tok = sample(logits, sub)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(steps - 1):
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    if return_stats:
        stats = {
            "t_prefill_s": t_prefill,
            "t_decode_s": t_decode,
            "decode_tok_s": B * max(steps - 1, 0) / max(t_decode, 1e-9),
            "backend": cfg.kernel_backend,
        }
        return gen, stats
    return gen
