"""Fault-tolerant training loop: auto-resume, SIGTERM checkpointing,
straggler watchdog, deterministic data skip-ahead.

At 1000+ node scale the same loop runs per-host under
``jax.distributed.initialize``; here it runs single-process. The three
fault-tolerance mechanisms are real and tested:
  * auto-resume: restores the latest complete checkpoint on start;
  * preemption: SIGTERM/SIGINT triggers a final synchronous checkpoint
    before exit (TPU preemption notice pattern);
  * straggler watchdog: flags sync windows whose per-step time exceeds
    `straggler_factor` x the trailing median — on a real pod this feeds
    the controller's slow-host eviction; here it logs and counts.
    (Observation granularity is the metrics sync cadence — log_every /
    checkpoint — since the loop keeps metrics as pending device handles
    between syncs rather than blocking every step.)
"""
from __future__ import annotations

import signal
import statistics
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import (AsyncCheckpointer, latest_step,
                                   prune_shardings, restore)


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.times: List[float] = []
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                self.flagged += 1
                slow = True
        self.times.append(dt)
        return slow


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        make_batch: Callable[[int], Dict[str, np.ndarray]],
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep_n: int = 3,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        quant_policy=None,
        shardings=None,
        mesh=None,
        tuning=None,
    ):
        """``shardings``: optional NamedSharding tree matching the train
        state (``partition.train_shardings(...)["state"]``) — resume then
        restores each checkpoint leaf straight onto its device placement
        (elastic: the mesh may differ from the one recorded at save
        time). ``mesh`` is recorded in checkpoint manifests, as is
        ``tuning`` (a live ``kernels.autotune.TuningCache``) so the
        train->serve loop hands tuned kernel tiles to deployment."""
        self.train_step = train_step
        self.make_batch = make_batch
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.shardings = shardings
        self.watchdog = StragglerWatchdog()
        self.ckpt = (AsyncCheckpointer(ckpt_dir, keep_n, policy=quant_policy,
                                       mesh=mesh, tuning=tuning)
                     if ckpt_dir else None)
        self._preempted = threading.Event()
        self.history: List[Dict[str, float]] = []

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self.log(f"[loop] signal {signum}: checkpoint-and-exit requested")
            self._preempted.set()
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGUSR1, handler)
        except ValueError:
            pass  # not main thread (tests)

    def maybe_resume(self, state):
        """Restore latest checkpoint if present; returns (state, start_step).

        With ``shardings`` set, every stored leaf is mmap-loaded and
        ``device_put`` directly onto its NamedSharding inside
        :func:`repro.checkpoint.ckpt.restore` — the restored tree keeps
        (or acquires, on an elastic re-mesh) the caller's device
        placement instead of being pulled to host. Leaves absent from
        the checkpoint (e.g. fresh EF state after turning compression
        on) keep their live value.
        """
        if not self.ckpt_dir:
            return state, 0
        last = latest_step(self.ckpt_dir)
        if last is None:
            return state, 0
        shardings = self.shardings
        if shardings is not None:
            # drop shardings for leaves the checkpoint predates (e.g. EF
            # residuals after enabling grad compression mid-run) — those
            # keep their live value via the graft below
            shardings = prune_shardings(self.ckpt_dir, shardings)
        restored, step = restore(self.ckpt_dir, shardings=shardings)

        def graft(cur, new):
            if new is None:
                return cur
            if isinstance(cur, dict):
                return {k: graft(cur[k],
                                 new.get(k) if isinstance(new, dict) else None)
                        for k in cur}
            return new

        state = graft(state, restored)
        self.log(f"[loop] resumed from step {step}")
        return state, int(step)

    def run(self, state, num_steps: int, *, handle_signals: bool = True):
        if handle_signals:
            self._install_signal_handlers()
        state, start = self.maybe_resume(state)
        step = start
        # Metrics stay pending device handles between sync points (the
        # log/checkpoint cadence + loop exit): dispatching step N+1 while
        # N still computes is what keeps the device busy. A per-step
        # block_until_ready would serialize host and device (the PR 3
        # engine fix, applied to training).
        pending: List[tuple] = []
        t_mark = time.perf_counter()

        def drain():
            nonlocal t_mark
            if not pending:
                return
            jax.block_until_ready(pending[-1][1])
            dt = (time.perf_counter() - t_mark) / len(pending)
            t_mark = time.perf_counter()
            slow = self.watchdog.observe(dt)
            for s, metrics in pending:
                self.history.append(
                    {"step": s, "dt": dt,
                     **{k: float(v) for k, v in metrics.items()
                        if np.ndim(v) == 0}})
            if slow:
                self.log(f"[watchdog] window at step {pending[-1][0]} "
                         f"straggled: {dt*1e3:.1f} ms/step (median "
                         f"{statistics.median(self.watchdog.times[-32:])*1e3:.1f} ms)")
            pending.clear()

        while step < num_steps and not self._preempted.is_set():
            batch = self.make_batch(step)
            state, metrics = self.train_step(state, batch)
            pending.append((step, metrics))
            step += 1
            due_ckpt = self.ckpt and (step % self.ckpt_every == 0)
            if (step % self.log_every == 0) or due_ckpt:
                drain()
            if step % self.log_every == 0 and self.history:
                rec = self.history[-1]
                self.log(f"[train] step {rec['step']} "
                         f"loss {rec.get('loss', float('nan')):.4f} "
                         f"{rec['dt']*1e3:.1f} ms")
            if due_ckpt:
                self.ckpt.save(state, step)
        drain()
        if self.ckpt and (self._preempted.is_set() or step >= num_steps):
            self.ckpt.save(state, step)
            self.ckpt.wait()
            self.log(f"[loop] final checkpoint at step {step}")
        return state, step
