"""Fault-tolerant training loop: auto-resume, SIGTERM checkpointing,
straggler watchdog, deterministic data skip-ahead.

At 1000+ node scale the same loop runs per-host under
``jax.distributed.initialize``; here it runs single-process. The three
fault-tolerance mechanisms are real and tested:
  * auto-resume: restores the latest complete checkpoint on start;
  * preemption: SIGTERM/SIGINT triggers a final synchronous checkpoint
    before exit (TPU preemption notice pattern);
  * straggler watchdog: a monitor thread flags steps slower than
    `straggler_factor` x the trailing median — on a real pod this feeds
    the controller's slow-host eviction; here it logs and counts.
"""
from __future__ import annotations

import signal
import statistics
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.times: List[float] = []
        self.window = window
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                self.flagged += 1
                slow = True
        self.times.append(dt)
        return slow


class TrainLoop:
    def __init__(
        self,
        train_step: Callable,
        make_batch: Callable[[int], Dict[str, np.ndarray]],
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        keep_n: int = 3,
        log_every: int = 10,
        log_fn: Callable[[str], None] = print,
        quant_policy=None,
    ):
        self.train_step = train_step
        self.make_batch = make_batch
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.log = log_fn
        self.watchdog = StragglerWatchdog()
        self.ckpt = (AsyncCheckpointer(ckpt_dir, keep_n, policy=quant_policy)
                     if ckpt_dir else None)
        self._preempted = threading.Event()
        self.history: List[Dict[str, float]] = []

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self.log(f"[loop] signal {signum}: checkpoint-and-exit requested")
            self._preempted.set()
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGUSR1, handler)
        except ValueError:
            pass  # not main thread (tests)

    def maybe_resume(self, state):
        """Restore latest checkpoint if present; returns (state, start_step)."""
        if not self.ckpt_dir:
            return state, 0
        last = latest_step(self.ckpt_dir)
        if last is None:
            return state, 0
        restored, step = restore(self.ckpt_dir)
        # graft restored arrays into the live state tree (keeps shardings
        # decided by the caller — elastic restore)
        state = jax.tree.map(
            lambda cur, new: cur if new is None else
            (np.asarray(new) if cur is None else jax.numpy.asarray(new, dtype=cur.dtype)),
            state, restored, is_leaf=lambda x: x is None)
        self.log(f"[loop] resumed from step {step}")
        return state, int(step)

    def run(self, state, num_steps: int, *, handle_signals: bool = True):
        if handle_signals:
            self._install_signal_handlers()
        state, start = self.maybe_resume(state)
        step = start
        while step < num_steps and not self._preempted.is_set():
            batch = self.make_batch(step)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.watchdog.observe(dt)
            rec = {"step": step, "dt": dt,
                   **{k: float(v) for k, v in metrics.items()
                      if np.ndim(v) == 0}}
            self.history.append(rec)
            if slow:
                self.log(f"[watchdog] step {step} straggled: {dt*1e3:.1f} ms "
                         f"(median {statistics.median(self.watchdog.times[-32:])*1e3:.1f} ms)")
            if step % self.log_every == 0:
                self.log(f"[train] step {step} loss {rec.get('loss', float('nan')):.4f} "
                         f"{dt*1e3:.1f} ms")
            step += 1
            if self.ckpt and (step % self.ckpt_every == 0):
                self.ckpt.save(state, step)
        if self.ckpt and (self._preempted.is_set() or step >= num_steps):
            self.ckpt.save(state, step)
            self.ckpt.wait()
            self.log(f"[loop] final checkpoint at step {step}")
        return state, step
