"""Paged KV-cache subsystem: page pool, prefix cache, chunk schedule.

The slot-pool engine sizes every slot's KV cache at ``max_len``, so
activation memory — not the 2–4 bit LUT-Q weights — bounds concurrency.
This module replaces per-slot caches with a *block-table* layout:

  * a global **page pool** of ``n_pages`` fixed-size pages (``page_size``
    tokens each, power of two) shared by every slot; a slot owns a row of
    the int32 **block table** mapping logical block ``j`` to a physical
    page id. Slot count is bounded by pool bytes, not capacity × max_len.
  * a host-side **PageAllocator** with refcounts and a free stack.
    Physical page 0 is reserved as the *trash page*: dead-slot decode
    writes, padded scatter positions and empty block-table entries all
    land there, and reads through it are always masked, so device-side
    code never needs a validity branch.
  * a **PrefixCache** — a hash-chain trie over full prompt pages — so
    requests sharing a prompt prefix (system prompts) map the *same*
    physical pages. Shared pages are refcounted and immutable on the
    engine path; a copy-on-write ``fork_page`` is exposed at the
    allocator level for writers that must diverge. Cold prefixes are
    evicted leaf-first in LRU order, and eviction never frees a page a
    live slot still references (the cache holds its own ref; a page is
    only returned to the pool when *every* holder releases it).
  * a **chunk schedule** that feeds long prompts through a small set of
    power-of-two prefill buckets so the jit trace set is closed at
    engine start (AOT warmup) and a long prompt never stalls decode.

Everything here is host-side bookkeeping (numpy / plain python); the
device-side gather/scatter lives in ``nn/attention.py`` and the model
files. See docs/serving.md §"Paged KV and prefix caching".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

TRASH_PAGE = 0  # reserved physical page: masked reads, garbage writes


def next_pow2(n: int) -> int:
    n = max(1, int(n))
    return 1 << (n - 1).bit_length()


def prefill_buckets(max_chunk: int, min_bucket: int = 16) -> Tuple[int, ...]:
    """The closed set of chunk widths the engine will ever trace."""
    if max_chunk & (max_chunk - 1):
        raise ValueError(f"max_chunk must be a power of two, got {max_chunk}")
    b, out = min(min_bucket, max_chunk), []
    while b <= max_chunk:
        out.append(b)
        b *= 2
    return tuple(out)


def chunk_plan(length: int, start: int, max_chunk: int,
               min_bucket: int = 16) -> List[Tuple[int, int, int]]:
    """Split prompt positions [start, length) into bucketed chunks.

    Returns [(start, width, n_real), ...]: full ``max_chunk`` chunks
    followed by at most one padded chunk whose width is the smallest
    bucket covering the remainder. The workspace is sized
    ``>= 2 * next_pow2(max_len)`` (see ``workspace_len``) so
    ``start + width`` always fits even when the padded tail overhangs.
    """
    plan = []
    while length - start >= max_chunk:
        plan.append((start, max_chunk, max_chunk))
        start += max_chunk
    rem = length - start
    if rem > 0:
        plan.append((start, next_pow2(max(rem, min_bucket)), rem))
    return plan


def workspace_len(max_len: int, n_blocks: int, page_size: int) -> int:
    """Width of the fp prefill workspace.

    Must cover (a) the gathered pool width ``n_blocks * page_size`` so a
    prefix-hit hydrate fits, and (b) any ``start + chunk_width`` the
    schedule can produce. The padded tail chunk satisfies
    ``start + width <= length + rem <= 2 * max_len``, so doubling the
    pow2 envelope is always safe.
    """
    return max(n_blocks * page_size, 2 * next_pow2(max_len))


def kv_bytes_per_token(cfg) -> int:
    """KV bytes one token occupies across all stacked attention layers."""
    import jax.numpy as jnp

    if cfg.family == "encdec":
        per = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * jnp.dtype(cfg.dtype).itemsize
        return per * cfg.n_layers
    per = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
    if cfg.kv_cache_bits == 8:
        # int8 payload + bf16 per-entry scale (one scale per (token, head))
        return (per + 2 * cfg.n_kv_heads * 2) * cfg.n_layers
    return per * jnp.dtype(cfg.dtype).itemsize * cfg.n_layers


class PageAllocator:
    """Refcounted free-list allocator over ``n_pages`` physical pages.

    Page 0 is the trash page: permanently pinned, never handed out,
    never freed. ``alloc`` is all-or-nothing (returns None on
    shortfall) so a request can never hold a partial reservation.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = int(n_pages)
        self.refs = np.zeros(self.n_pages, np.int32)
        self.refs[TRASH_PAGE] = 1  # pinned forever
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError("alloc(n) needs n >= 0")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self.refs[p] == 0, f"free-list page {p} had refs"
            self.refs[p] = 1
        return pages

    def retain(self, page: int) -> None:
        if page == TRASH_PAGE:
            return
        assert self.refs[page] > 0, f"retain of unreferenced page {page}"
        self.refs[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; returns True when the page went back to
        the pool (last holder released)."""
        if page == TRASH_PAGE:
            return False
        assert self.refs[page] > 0, f"double free of page {page}"
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self._free.append(page)
            return True
        return False

    def fork_page(self, page: int) -> Optional[int]:
        """Copy-on-write: a writer that shares ``page`` gets a private
        page id to copy into (caller performs the device copy), and the
        shared original loses one ref. If the caller is already the sole
        holder the same page is returned (no copy needed)."""
        if page == TRASH_PAGE:
            raise ValueError("cannot fork the trash page")
        assert self.refs[page] > 0
        if self.refs[page] == 1:
            return page
        got = self.alloc(1)
        if got is None:
            return None
        self.release(page)
        return got[0]

    def check(self) -> None:
        """Invariant sweep (used by property tests)."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert TRASH_PAGE not in free
        for p in range(1, self.n_pages):
            # a page is free iff nobody holds a reference to it
            assert (p in free) == (self.refs[p] == 0), \
                f"page {p}: refs={self.refs[p]} free={p in free}"
        n_owned = sum(1 for p in range(1, self.n_pages) if self.refs[p] > 0)
        assert n_owned + len(free) == self.n_pages - 1


@dataclasses.dataclass
class _TrieNode:
    key: Tuple          # (parent id, block-token tuple) — exact, no hash risk
    page: int
    parent: Optional["_TrieNode"]
    n_children: int = 0
    stamp: int = 0      # LRU clock


class PrefixCache:
    """Hash-chain trie mapping full prompt pages to physical page ids.

    A node at depth ``i`` represents prompt tokens
    ``[i*page_size, (i+1)*page_size)`` *given* its parent chain — the
    trie key stores the exact block tokens, so equal chains are shared
    and distinct chains can never collide. The cache owns one reference
    per cached page; slots that hit add their own. Eviction is
    leaf-first LRU: interior nodes with live children are untouchable,
    and a freed node only returns its page to the pool when no slot
    still holds it (refcount > 1 just drops the cache's share).
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        self.alloc = alloc
        self.page_size = int(page_size)
        self._nodes: Dict[Tuple, _TrieNode] = {}
        self._clock = 0
        self.hits = 0        # pages served from cache
        self.queries = 0     # pages looked up
        self.insertions = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest cached page chain for ``tokens``; caller must cap the
        hit so the last prompt token is always recomputed. Retains one
        ref per returned page on the caller's behalf and bumps LRU."""
        tokens = [int(t) for t in tokens]
        n_full = len(tokens) // self.page_size
        pages, parent_id = [], None
        for i in range(n_full):
            blk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            self.queries += 1
            node = self._nodes.get((parent_id, blk))
            if node is None:
                break
            self.hits += 1
            node.stamp = self._tick()
            self.alloc.retain(node.page)
            pages.append(node.page)
            parent_id = id(node)
        return pages

    def probe(self, tokens: Sequence[int]) -> int:
        """Length (in pages) of the cached chain ``match`` would return,
        with NO side effects: no refcounts taken, no LRU bump, no
        hit/query accounting. Admission planning (prefill packing) uses
        this to size a group before committing any reservation."""
        tokens = [int(t) for t in tokens]
        n_full = len(tokens) // self.page_size
        n, parent_id = 0, None
        for i in range(n_full):
            blk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            node = self._nodes.get((parent_id, blk))
            if node is None:
                break
            n += 1
            parent_id = id(node)
        return n

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> None:
        """Cache ``pages[i]`` as the page for full prompt block ``i``.

        Only full pages are cached (the tail block of a prompt keeps
        growing during decode, so it is never shareable). The cache
        retains each newly-cached page."""
        tokens = [int(t) for t in tokens]
        n_full = min(len(tokens) // self.page_size, len(pages))
        parent, parent_id = None, None
        for i in range(n_full):
            blk = tuple(tokens[i * self.page_size:(i + 1) * self.page_size])
            key = (parent_id, blk)
            node = self._nodes.get(key)
            if node is None:
                node = _TrieNode(key=key, page=int(pages[i]), parent=parent,
                                 stamp=self._tick())
                self._nodes[key] = node
                self.alloc.retain(node.page)
                if parent is not None:
                    parent.n_children += 1
                self.insertions += 1
            else:
                node.stamp = self._tick()
            parent, parent_id = node, id(node)

    def evict(self, n_pages_needed: int) -> int:
        """Drop LRU leaves until the allocator can cover
        ``n_pages_needed`` frees-worth of demand (or the trie is empty).
        Returns the number of nodes evicted. Dropping a node releases
        the cache's ref — the page only reaches the free list when no
        slot still references it, so eviction can never free live data.
        """
        evicted = 0
        while self.alloc.n_free < n_pages_needed and self._nodes:
            leaf = min((n for n in self._nodes.values() if n.n_children == 0),
                       key=lambda n: n.stamp, default=None)
            if leaf is None:  # cycle-free trie always has a leaf; be safe
                break
            del self._nodes[leaf.key]
            if leaf.parent is not None:
                leaf.parent.n_children -= 1
            self.alloc.release(leaf.page)
            evicted += 1
        self.evictions += evicted
        return evicted

    def clear(self) -> None:
        for node in self._nodes.values():
            self.alloc.release(node.page)
        self._nodes.clear()

    @property
    def hit_rate(self) -> float:
        return self.hits / self.queries if self.queries else 0.0


class PagedKV:
    """Per-engine paged-KV bookkeeping: block-table rows, reservations,
    prefix-cache integration and behind-window page release.

    Device state (the pool itself, the int32 block table, per-slot
    lengths) lives in the engine's cache pytree; this object mirrors the
    block table on the host so admission/retire never sync the device.
    """

    def __init__(self, n_pages: int, page_size: int, n_blocks: int,
                 capacity: int, *, prefix_cache: bool = True):
        if page_size & (page_size - 1):
            raise ValueError(f"page_size must be a power of two, got "
                             f"{page_size}")
        self.page_size = int(page_size)
        self.n_blocks = int(n_blocks)
        self.alloc = PageAllocator(n_pages)
        self.prefix = PrefixCache(self.alloc, page_size) if prefix_cache \
            else None
        # per-slot: list of owned page ids (logical block order), prompt
        # hit length in tokens, host-tracked live length
        self.rows: List[Optional[List[int]]] = [None] * capacity
        self.hit_tokens: List[int] = [0] * capacity
        self.lens: List[int] = [0] * capacity
        self.pages_peak = 0

    def n_pages_for(self, total_tokens: int) -> int:
        return -(-int(total_tokens) // self.page_size)

    def peek(self, tokens: Sequence[int]) -> int:
        """Prospective prefix-hit length in TOKENS for ``tokens``,
        without reserving anything — the same cap ``admit`` applies (the
        page holding the last prompt token is never hit, so its logits
        are recomputed). Pure: repeated peeks don't perturb LRU order or
        hit-rate stats."""
        if self.prefix is None or tokens is None:
            return 0
        n = self.prefix.probe(tokens)
        return min(n, (len(tokens) - 1) // self.page_size) * self.page_size

    def admit(self, slot: int, tokens: Sequence[int], total_tokens: int):
        """Reserve pages for a request (prompt + budgeted new tokens).

        Returns ``(row, n_hit_tokens)`` or None when the pool cannot
        cover the reservation even after evicting cold prefixes. ``row``
        is the full (n_blocks,) int32 block row (unused tail = trash).
        """
        assert self.rows[slot] is None, f"slot {slot} already owns pages"
        n_need = self.n_pages_for(total_tokens)
        if n_need > self.n_blocks:
            raise ValueError(f"request needs {n_need} pages > block table "
                             f"width {self.n_blocks}")
        hit: List[int] = []
        if self.prefix is not None and tokens is not None:
            hit = self.prefix.match(tokens)
            # the last prompt token must be recomputed (its logits seed
            # sampling), so never hit the page containing it
            cap = (len(tokens) - 1) // self.page_size
            while len(hit) > cap:
                self.alloc.release(hit.pop())
        n_new = n_need - len(hit)
        if self.alloc.n_free < n_new and self.prefix is not None:
            self.prefix.evict(n_new)
        fresh = self.alloc.alloc(n_new)
        if fresh is None:
            for p in hit:
                self.alloc.release(p)
            return None
        pages = hit + fresh
        self.rows[slot] = pages
        self.hit_tokens[slot] = len(hit) * self.page_size
        self.lens[slot] = 0
        row = np.zeros(self.n_blocks, np.int32)
        row[:len(pages)] = pages
        self.pages_peak = max(self.pages_peak, self.alloc.pages_in_use)
        return row, self.hit_tokens[slot]

    def insert_prefix(self, slot: int, tokens: Sequence[int]) -> None:
        """After prefill completes, publish the slot's full prompt pages
        into the prefix cache (decode tokens are never published)."""
        if self.prefix is None or self.rows[slot] is None:
            return
        self.prefix.insert(tokens, self.rows[slot])

    def release_slot(self, slot: int) -> None:
        row = self.rows[slot]
        if row is None:
            return
        for p in row:
            self.alloc.release(p)
        self.rows[slot] = None
        self.hit_tokens[slot] = 0
        self.lens[slot] = 0

    def release_behind_window(self, slot: int,
                              window: int) -> List[int]:
        """Free pages that have slid fully behind the attention window.

        Returns the logical block indices freed so the engine can zero
        the device block row (future reads are masked anyway; zeroing
        routes dead-slot decode writes to the trash page). Block ``j``
        is dead once ``(j+1)*page_size <= len - window``.
        """
        row = self.rows[slot]
        if row is None or window is None:
            return []
        dead_before = self.lens[slot] - window
        freed = []
        for j, p in enumerate(row):
            if p == TRASH_PAGE:
                continue
            if (j + 1) * self.page_size <= dead_before:
                self.alloc.release(p)
                row[j] = TRASH_PAGE
                freed.append(j)
        return freed

    def stats(self) -> Dict[str, float]:
        out = {
            "kv_pages": self.alloc.n_pages,
            "page_size": self.page_size,
            "pages_in_use": self.alloc.pages_in_use,
            "pages_peak": self.pages_peak,
        }
        if self.prefix is not None:
            out.update(prefix_nodes=len(self.prefix),
                       prefix_hits=self.prefix.hits,
                       prefix_queries=self.prefix.queries,
                       prefix_hit_rate=self.prefix.hit_rate,
                       prefix_evictions=self.prefix.evictions)
        return out
