"""Continuous-batching serving engine over the jit-cached decode path.

A fixed-capacity **slot pool**: every decode step advances all
``capacity`` slots through one jit-compiled ``decode_step + sample``
trace (fixed shapes — no retracing as traffic changes), while a FIFO
admission queue prefills new requests into free slots mid-flight and
EOS / max-token retirement frees slots immediately. This converts the
fused LUT-Q kernel win (weight bytes / HBM bandwidth per decode step)
into *served* throughput on ragged, asynchronous traffic — the decode
batch stays full instead of lock-stepping on the slowest member of a
static batch.

Lifecycle per request (see docs/serving.md):

  submit -> [queue] -> admit: requests taken the same step share ONE
                              batched prefill when exactness allows it
                              -> adapt_prefill_cache -> cache.at[slot]
         -> decode: one token per engine step, per-slot position/rng
         -> retire: EOS or max_new reached; slot freed the same step

Correctness contract: a request's tokens are **identical to a solo
``generate``** run of the same prompt (the ragged-parity suite pins
this per family, including ``kernel_backend="fused"``). Admission
prefills at the request's exact length by default — which is what makes
this hold for recurrent families (rwkv/zamba) whose state cannot mask
padding after the fact — and groups compatible requests into one
batched prefill (attention-only families batch ragged prompts via the
per-stream ``lengths`` threading in ``models.api.prefill``; recurrent
and MoE families group by exact length). ``prefill_bucket > 1``
right-pads admission prompts onto bucket boundaries for attention
families, closing the jit trace set over ragged lengths.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models import api
from repro.models.config import ModelConfig
from repro.runtime import paged_kv
from repro.runtime.serving import (adapt_prefill_cache, paged_chunk_fn,
                                   paged_encdec_splice_fn, paged_hydrate_fn,
                                   paged_packed_fn, paged_splice_fn,
                                   prefill_fn)
from repro.runtime.speculative import spec_step_fn


def _batch_axes(cfg: ModelConfig, max_len: int, src_len: int):
    """Per-leaf batch axis of the decode cache (structural finder;
    shared with ``partition.serve_shardings`` which needs the same
    answer to batch-shard the pool)."""
    from repro.launch.partition import cache_batch_axes

    return cache_batch_axes(cfg, max_len, src_len)


@functools.lru_cache(maxsize=64)
def _splice_fn(cfg: ModelConfig, axes: tuple, max_len: int, src_len: int,
               m: int, mesh=None, capacity: int = 0):
    """Jit-cached admission splice: adapt a batch=m prefill cache to the
    decode layout (ring relay, int8-KV quant, length override) and write
    row i into slot ``slots[i]`` of the pooled cache — one compiled
    dispatch per admission *group* instead of a trail of small
    host-driven ops. ``adapt_prefill_cache`` traces (no host sync),
    which is what makes this composition possible. Under a mesh the
    pool keeps its batch-on-data NamedShardings through the splice
    (mesh is part of the cache key — no stale traces across meshes)."""

    def splice(pool, prefill_cache, slots, lengths):
        grp = adapt_prefill_cache(cfg, prefill_cache, m, max_len,
                                  src_len=src_len, lengths=lengths)
        leaves_p, treedef = jax.tree.flatten(pool)
        leaves_g = jax.tree.leaves(grp)
        out = []
        for p, g, ax in zip(leaves_p, leaves_g, axes):
            g = g.astype(p.dtype)
            for i in range(m):
                row = jax.lax.dynamic_slice_in_dim(g, i, 1, axis=ax)
                p = jax.lax.dynamic_update_slice_in_dim(p, row, slots[i],
                                                        axis=ax)
            out.append(p)
        return jax.tree.unflatten(treedef, out)

    if mesh is None:
        return jax.jit(splice)
    from repro.launch.partition import serve_shardings

    sh = serve_shardings(cfg, mesh, batch=capacity, max_len=max_len,
                         src_len=src_len)
    return jax.jit(splice, in_shardings=(sh["cache"], None, None, None),
                   out_shardings=sh["cache"])


def _sample(logits, keys, temp, greedy: bool):
    """Per-slot sampling: logits (B,1,V) -> (tok (B,1), new keys).

    Each slot owns an rng chain, so a request's samples depend only on
    its own key — not on batch composition — which is what makes
    continuous-batch output reproducible against solo runs."""
    lg = logits[:, -1].astype(jnp.float32)
    if greedy:
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), keys
    split = jax.vmap(jax.random.split)(keys)  # (B, 2, key)
    sub, new = split[:, 0], split[:, 1]
    tok = jax.vmap(jax.random.categorical)(sub, lg / jnp.maximum(temp, 1e-6))
    return tok[:, None].astype(jnp.int32), new


@functools.lru_cache(maxsize=64)
def _sample_fn(greedy: bool):
    # no explicit shardings: jit keys its executables on the input
    # shardings itself, so meshed and un-meshed engines can share this
    return jax.jit(functools.partial(_sample, greedy=greedy))


@functools.lru_cache(maxsize=64)
def _step_fn_cached(cfg: ModelConfig, greedy: bool, mesh, capacity: int,
                    max_len: int, src_len: int, tuning: int):
    del tuning  # lru salt: tuned tiles are baked into the trace
    axes = api.init_axes(cfg) if mesh is not None else None

    def step(params, tok, cache, keys, temp):
        if mesh is not None:
            params = ops.annotate_spmd(params, axes, mesh)
        logits, cache = api.decode_step(params, cfg, tok, cache)
        tok, keys = _sample(logits, keys, temp, greedy)
        return tok, cache, keys

    if mesh is None:
        return jax.jit(step)
    from repro.launch.partition import serve_shardings

    sh = serve_shardings(cfg, mesh, batch=capacity, max_len=max_len,
                         src_len=src_len)
    return jax.jit(
        step,
        in_shardings=(None, sh["token"], sh["cache"], sh["keys"], None),
        out_shardings=(sh["token"], sh["cache"], sh["keys"]))


def _step_fn(cfg: ModelConfig, greedy: bool, mesh=None, capacity: int = 0,
             max_len: int = 0, src_len: int = 0):
    """One fused engine step: decode_step + per-slot sampling.

    With a mesh, the step takes explicit in/out NamedShardings
    (``partition.serve_shardings``): tok/cache/keys batch-sharded on
    the data axis, params at their committed placement and annotated
    in-trace (``ops.annotate_spmd``) so fused LUT-Q dots run on local
    index shards. The mesh is in the lru key, so one process can serve
    several meshes without trace reuse; the tuning-cache fingerprint is
    too, so ``--autotune`` invalidates traces with stale tiles."""
    return _step_fn_cached(cfg, greedy, mesh, capacity, max_len, src_len,
                           ops.tuning_fingerprint())


@functools.lru_cache(maxsize=64)
def _paged_step_fn_cached(cfg: ModelConfig, greedy: bool, mesh,
                          capacity: int, n_pages: int, page_size: int,
                          n_blocks: int, src_len: int, tuning: int):
    del tuning
    axes = api.init_axes(cfg) if mesh is not None else None

    def step(params, tok, cache, keys, temp):
        if mesh is not None:
            params = ops.annotate_spmd(params, axes, mesh)
        logits, cache = api.paged_decode_step(params, cfg, tok, cache)
        tok, keys = _sample(logits, keys, temp, greedy)
        return tok, cache, keys

    if mesh is None:
        return jax.jit(step)
    from repro.launch.partition import paged_serve_shardings

    sh = paged_serve_shardings(cfg, mesh, batch=capacity, n_pages=n_pages,
                               page_size=page_size, n_blocks=n_blocks,
                               src_len=src_len)
    return jax.jit(
        step,
        in_shardings=(None, sh["token"], sh["cache"], sh["keys"], None),
        out_shardings=(sh["token"], sh["cache"], sh["keys"]))


def _paged_step_fn(cfg: ModelConfig, greedy: bool, mesh=None,
                   capacity: int = 0, n_pages: int = 0, page_size: int = 0,
                   n_blocks: int = 0, src_len: int = 0):
    """Paged twin of ``_step_fn``: paged decode_step + per-slot sampling.

    The page-pool geometry is part of the lru key (it sizes the cache
    shardings under a mesh and keeps engines with different pools from
    sharing a trace)."""
    return _paged_step_fn_cached(cfg, greedy, mesh, capacity, n_pages,
                                 page_size, n_blocks, src_len,
                                 ops.tuning_fingerprint())


def synthetic_requests(cfg: ModelConfig, n: int, *, max_prompt: int,
                       max_new: int, seed: int = 0, src_len: int = 0,
                       rate: float = 0.0):
    """Deterministic ragged workload: ``n`` requests with uniform prompt
    lengths in [max(2, max_prompt//4), max_prompt], uniform max_new in
    [max(1, max_new//8), max_new] (wide on purpose — real generation
    lengths are heavy-tailed, which is exactly the straggle a lock-step
    batch pays for), and (for ``rate > 0``) Poisson arrival offsets at
    ``rate`` requests/s. Returns a list of kwargs dicts for
    ``Engine.submit`` plus an ``arrival_s`` field (callers that serve an
    open queue pop requests as their arrival time passes; batch callers
    ignore it)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        L = int(rng.integers(max(2, max_prompt // 4), max_prompt + 1))
        g = int(rng.integers(max(1, max_new // 8), max_new + 1))
        req: Dict[str, Any] = {
            "tokens": rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32),
            "max_new": g,
            "arrival_s": t,
        }
        if cfg.family == "encdec":
            sl = int(rng.integers(max(2, src_len // 2), src_len + 1))
            req["frames"] = rng.standard_normal((sl, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            req["prefix_embeds"] = rng.standard_normal(
                (cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(req)
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
    return reqs


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                      # (L,) int32 prompt
    max_new: int
    eos_id: Optional[int]
    key: jax.Array
    frames: Optional[np.ndarray] = None     # encdec source embeddings (S, D)
    prefix_embeds: Optional[np.ndarray] = None  # vlm prefix (P, D)
    out: List[int] = dataclasses.field(default_factory=list)
    pstart: int = 0   # index into the engine's pending-token ring
    kv_pages: int = 0  # pages reserved at admission (paged engines)
    finish: str = ""
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    """Fixed-capacity continuous-batching engine.

    Usage::

        eng = Engine(params, cfg, capacity=8, max_len=128)
        eng.submit(prompt_tokens, max_new=32, eos_id=2)
        for result in eng.run(stream=True):
            ...                      # per-request dict as it retires
        print(eng.stats())

    ``capacity``: decode slots (the fixed decode batch).
    ``max_len``: per-slot cache width in text tokens (prompt + new);
    the vlm modality prefix widens it internally.
    ``src_len``: cross-attention memory width (encdec only).
    ``prefill_bucket``: round admission prefills up to a multiple of
    this to bound jit retraces across ragged prompt lengths (attention
    families only; recurrent families always prefill exact).
    ``mesh``: optional ``("data", "model")`` device mesh. The slot pool
    (cache, pending tokens, per-slot rng chains) is placed batch-on-data
    and every engine jit — splice dispatch, decode step, sampler —
    takes explicit NamedShardings keyed on the mesh, so continuous
    batching composes with tensor parallelism (params should already be
    placed via ``distributed.sharding.shard_serve_params``). Results
    are token-identical to an un-meshed engine.
    ``speculative``: draft block length k (0 disables). Each engine step
    becomes one speculative round: a coarsened ``draft_bits`` view of
    the same LUT-Q weights (``api.draft_view`` — or an explicit
    ``draft_params``) proposes k tokens, ONE target forward over the
    k+1 window verifies them. Greedy rounds are token-identical to
    non-speculative serving; temperature uses rejection sampling
    (distribution-exact, not bitwise). Requires k extra tokens of cache
    headroom per request (``submit`` enforces it).
    """

    def __init__(self, params, cfg: ModelConfig, *, capacity: int = 8,
                 max_len: int = 128, src_len: int = 0,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None,
                 backend: Optional[str] = None, prefill_bucket: int = 1,
                 mesh=None, kv_pages: Optional[int] = None,
                 page_size: int = 64, prefix_cache: bool = True,
                 max_chunk: int = 256, prefill_pack: bool = True,
                 warmup: bool = True, speculative: int = 0,
                 draft_bits: int = 3, draft_params=None):
        if backend is not None:
            cfg = cfg.replace(kernel_backend=backend)
        self.cfg = cfg
        self.params = params
        self.capacity = int(capacity)
        self.prefix = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
        self.max_len = int(max_len) + self.prefix
        self.src_len = int(src_len)
        self.temperature = float(temperature)
        self.greedy = self.temperature <= 0
        self.mesh = mesh
        self.prefill_bucket = max(1, int(prefill_bucket))
        if cfg.family in ("ssm", "hybrid") or cfg.n_experts:
            # padded prefill corrupts recurrent state, and MoE routing
            # capacity couples real tokens to padding — always exact
            self.prefill_bucket = 1
        self._base_rng = rng if rng is not None else jax.random.PRNGKey(0)

        # paged KV (runtime/paged_kv.py): slot caches become block-table
        # rows over a global page pool. Families the paged layout does
        # not support (fixed-size recurrent state, MLA latents, MoE /
        # prefix-layer caches) silently keep the slot path behind the
        # same API — `stats()["paged"]` reports which path ran.
        self.paged = kv_pages is not None and api.paged_supported(cfg)

        # self-speculative decoding: a coarsened view of the SAME LUT-Q
        # weights drafts k tokens per round, one verify window checks
        # them (runtime/speculative.py). Greedy rounds are token-
        # identical to non-speculative serving; the refusal reasons
        # (activation quant, recurrent state, MLA, MoE, meshes) are
        # exactness gates, not missing plumbing.
        self.spec_k = int(speculative)
        self.draft_bits = int(draft_bits)
        self.draft_report: Optional[Dict[str, Dict]] = None
        self.n_spec_rounds = 0
        self._spec_acc_tok = 0    # accepted tokens over live slot-rounds
        self._spec_acc_draft = 0  # accepted DRAFT tokens (excl. bonus)
        self._spec_live = 0       # live slot-rounds
        if self.spec_k:
            ok, why = api.speculative_supported(cfg)
            if not ok:
                raise ValueError(why)
            if mesh is not None:
                raise ValueError(
                    "speculative decoding does not compose with SPMD "
                    "meshes yet (per-slot rewind vs sharded caches); run "
                    "speculative engines un-meshed")
            if not self.paged and cfg.window is not None:
                eff = min(self.max_len, cfg.window)
                if self.spec_k + 1 > eff:
                    raise ValueError(
                        f"speculative k={self.spec_k} needs k+1 <= ring "
                        f"width {eff} (verify window must fit in the SWA "
                        "ring)")
            if draft_params is None:
                draft_params, self.draft_report = api.draft_view(
                    params, draft_bits=self.draft_bits, with_report=True)
        self.draft_params = draft_params if self.spec_k else None
        self._chunking: Optional[Dict[str, Any]] = None
        self.n_chunk_calls = 0
        self.n_packed_groups = 0
        self.n_packed_reqs = 0
        # packed prefill: several short queue-head prompts share ONE
        # chunk call (encdec prefills whole prompts through the dense
        # prefill path — nothing to pack there). Dynamic activation
        # quantization disables it: fake_quant's per-TENSOR max scale
        # couples every row of a packed batch (and of the decode batch)
        # to its neighbours, so packed tokens would not be bit-identical
        # to unpacked serving — the same exactness discipline that pins
        # prefill_bucket=1 for recurrent families.
        self.prefill_pack = (bool(prefill_pack) and self.paged
                             and cfg.family != "encdec"
                             and cfg.act_bits >= 32)
        if self.paged:
            self.page_size = int(page_size)
            self.n_blocks = -(-self.max_len // self.page_size)
            self.n_pages = int(kv_pages)
            self.pkv = paged_kv.PagedKV(
                self.n_pages, self.page_size, self.n_blocks, self.capacity,
                # encdec KV depends on the source frames — never shareable
                prefix_cache=prefix_cache and cfg.family != "encdec")
            self.cache = api.init_paged_cache(
                cfg, self.capacity, self.n_pages, self.page_size,
                self.n_blocks, src_len=self.src_len)
            if cfg.family == "encdec":
                # encdec prefills whole prompts in one shot (the decoder
                # attends the full source anyway), padded onto pow2
                # buckets up to the context width
                self.max_chunk = paged_kv.next_pow2(self.max_len)
            else:
                self.max_chunk = min(int(max_chunk),
                                     paged_kv.next_pow2(self.max_len))
                self.wws = paged_kv.workspace_len(
                    self.max_len, self.n_blocks, self.page_size)
                from repro.models.lm import init_paged_workspace

                self.ws = init_paged_workspace(cfg, self.wws)
            self.buckets = paged_kv.prefill_buckets(self.max_chunk)
            self.prefill_chunks_per_step = 1
        else:
            self.cache = api.init_cache(cfg, self.capacity, self.max_len,
                                        src_len=self.src_len)
            self._axes = _batch_axes(cfg, self.max_len, self.src_len)
        self.tok = jnp.zeros((self.capacity, 1), jnp.int32)
        self.keys = jnp.stack([jax.random.fold_in(self._base_rng, i)
                               for i in range(self.capacity)])
        if mesh is not None:
            from repro.launch.partition import (paged_serve_shardings,
                                                serve_shardings)

            if self.paged:
                sh = paged_serve_shardings(
                    cfg, mesh, batch=self.capacity, n_pages=self.n_pages,
                    page_size=self.page_size, n_blocks=self.n_blocks,
                    src_len=self.src_len)
            else:
                sh = serve_shardings(cfg, mesh, batch=self.capacity,
                                     max_len=self.max_len,
                                     src_len=self.src_len)
            self.cache = jax.device_put(self.cache, sh["cache"])
            self.tok = jax.device_put(self.tok, sh["token"])
            self.keys = jax.device_put(self.keys, sh["keys"])
        self.slots: List[Optional[Request]] = [None] * self.capacity
        self.queue: deque = deque()
        self._pending: List[jax.Array] = []  # un-synced decode tokens
        self.results: Dict[int, Dict[str, Any]] = {}
        self._next_rid = 0
        self.n_decode_steps = 0
        self.n_admitted = 0
        self.t_prefill = 0.0
        self.t_decode = 0.0
        self.t_warmup = 0.0
        self._t_start: Optional[float] = None
        if self.paged and warmup:
            self._warm_paged()

    # ------------------------------------------------------------- queue

    def submit(self, tokens, *, max_new: int, eos_id: Optional[int] = None,
               rng: Optional[jax.Array] = None, frames=None,
               prefix_embeds=None) -> int:
        """Enqueue one request; returns its rid (FIFO admission order).

        ``rng``: per-request sampling key (defaults to
        ``fold_in(engine_rng, rid)``). ``generate`` gives its stream i
        the key ``fold_in(generate_rng, i)``, so to reproduce a
        temperature>0 stream against a solo ``generate(..., rng=K)``
        run, submit with ``rng=jax.random.fold_in(K, 0)``.
        """
        prompt = np.asarray(jax.device_get(tokens), np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if (len(prompt) + int(max_new) + self.prefix + self.spec_k
                > self.max_len):
            extra = (f" (+{self.spec_k} speculative headroom)"
                     if self.spec_k else "")
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new}{extra} exceeds "
                f"engine max_len {self.max_len - self.prefix}")
        if self.cfg.family == "encdec":
            if frames is None:
                raise ValueError("encdec requests need `frames`")
            if frames.shape[0] > self.src_len:
                raise ValueError(
                    f"frames {frames.shape[0]} exceed engine src_len "
                    f"{self.src_len}")
        if self.paged:
            n_need = self.pkv.n_pages_for(
                len(prompt) + int(max_new) + self.spec_k)
            if n_need > self.n_pages - 1:
                raise ValueError(
                    f"request needs {n_need} KV pages but the pool only has "
                    f"{self.n_pages - 1} allocatable pages")
        rid = self._next_rid
        self._next_rid += 1
        key = rng if rng is not None else jax.random.fold_in(self._base_rng, rid)
        req = Request(rid, prompt, int(max_new), eos_id, key,
                      frames=frames, prefix_embeds=prefix_embeds,
                      t_submit=time.perf_counter())
        self.queue.append(req)
        return rid

    # --------------------------------------------------------- admission

    def _group_key(self, req: Request):
        """Requests admitted in the same step share one batched prefill
        when exactness allows it: attention-only families batch ragged
        prompts freely (per-stream ``lengths`` keeps them exact);
        recurrent state and MoE routing are batch-coupled under padding,
        so those group by exact prompt length; encdec additionally needs
        equal source widths (the encoder is bidirectional — padded
        frames would corrupt real positions)."""
        if self.cfg.family in ("ssm", "hybrid") or self.cfg.n_experts:
            return ("exact", len(req.tokens))
        if self.cfg.family == "encdec":
            return ("src", req.frames.shape[0])
        # text-only and prefixed vlm requests occupy different cache
        # layouts — never share a prefill
        return ("any", req.prefix_embeds is not None)

    def _admit_group(self, slots: List[int], reqs: List[Request]):
        """Prefill a group of compatible requests with ONE batched call
        and splice each row into its slot."""
        t0 = time.perf_counter()
        cfg = self.cfg
        m = len(reqs)
        Ls = [len(r.tokens) for r in reqs]
        Lb = -(-max(Ls) // self.prefill_bucket) * self.prefill_bucket
        toks = np.zeros((m, Lb), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :Ls[i]] = r.tokens
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.stack([jnp.asarray(r.frames) for r in reqs])
        # a modality prefix occupies cache slots only when it is really
        # present (text-only vlm requests prefill without one, and the
        # group key keeps the two kinds apart)
        pfx = 0
        if reqs[0].prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.stack(
                [jnp.asarray(r.prefix_embeds) for r in reqs])
            pfx = self.prefix
        lengths = jnp.asarray(Ls, jnp.int32)
        slots_j = jnp.asarray(slots, jnp.int32)

        logits, cache = prefill_fn(cfg, self.max_len, self.mesh)(
            self.params, batch, lengths)
        # prefill wants *text* lengths (its logit gather offsets the vlm
        # prefix itself); the decode cache's `len` counts cache slots,
        # which include any prefix positions
        self.cache = _splice_fn(cfg, self._axes, self.max_len, self.src_len,
                                m, self.mesh, self.capacity)(
                                    self.cache, cache, slots_j, lengths + pfx)
        keys = jnp.stack([r.key for r in reqs])
        tok1, keys1 = _sample_fn(self.greedy)(
            logits, keys, jnp.float32(self.temperature))
        self.tok = self.tok.at[slots_j].set(tok1)
        self.keys = self.keys.at[slots_j].set(keys1)
        firsts = np.asarray(jax.device_get(tok1[:, 0]))  # one sync per group

        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            req.t_admit = t0
            req.t_first = now
            req.out = [int(firsts[i])]
            req.pstart = len(self._pending)  # earlier pending rows belong
            self.slots[slot] = req           # to the slot's prior occupant
            self.n_admitted += 1
            self._maybe_retire(slot)
        self.t_prefill += now - t0

    def _maybe_retire(self, slot: int):
        req = self.slots[slot]
        done_eos = req.eos_id is not None and req.out[-1] == req.eos_id
        done_len = len(req.out) >= req.max_new
        if not (done_eos or done_len):
            return
        req.finish = "eos" if done_eos else "length"
        req.t_done = time.perf_counter()
        self.slots[slot] = None
        # pin the freed slot's position and token so its dead-slot
        # decode writes stay inside the slot (and stay deterministic)
        # until the next admission overwrites it
        self.cache = dict(self.cache)
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        self.tok = self.tok.at[slot].set(0)
        if self.paged and self.pkv.rows[slot] is not None:
            # release the refcounts AND zero the device block row: the
            # freed pages may be reallocated immediately, and a stale
            # row would let this dead slot's trash-writes corrupt them
            self.pkv.release_slot(slot)
            self.cache["block"] = self.cache["block"].at[slot].set(0)
        self.results[req.rid] = {
            "rid": req.rid,
            "tokens": np.asarray(req.out, np.int32),
            "prompt_len": len(req.tokens),
            "n_new": len(req.out),
            "finish": req.finish,
            "t_queue_s": req.t_admit - req.t_submit,
            "t_first_token_s": req.t_first - req.t_submit,
            "t_total_s": req.t_done - req.t_submit,
            "kv_pages": req.kv_pages,
        }

    # ---------------------------------------------------- paged admission

    def _paged_admit(self):
        """Paged-mode admission: advance the in-flight chunked prefill
        (one chunk per engine step — decode slots keep stepping between
        chunks, which is the point of chunking), then FIFO-admit queue
        heads into free slots while pages hold out. A page shortfall
        defers the queue head (FIFO preserved) until retirements or
        prefix-cache eviction free pages."""
        budget = self.prefill_chunks_per_step
        while budget > 0:
            if self._chunking is not None:
                self._chunk_step()
                budget -= 1
                continue
            if not self.queue or None not in self.slots:
                return
            req = self.queue[0]
            slot = self.slots.index(None)
            if self.cfg.family == "encdec":
                if not self._admit_paged_encdec(slot, req):
                    return
                budget -= 1
                continue
            if self.prefill_pack and self._try_packed_admit():
                budget -= 1
                continue
            got = self.pkv.admit(slot, req.tokens,
                                 len(req.tokens) + req.max_new + self.spec_k)
            if got is None:
                return  # deferred: not enough pages even after eviction
            self.queue.popleft()
            row, hit = got
            req.t_admit = time.perf_counter()
            req.kv_pages = len(self.pkv.rows[slot])
            self._start_chunking(slot, req, row, hit)

    def _try_packed_admit(self) -> bool:
        """Admit several queue-head requests as ONE packed prefill call.

        A group packs consecutive FIFO requests whose tails (prompt
        minus prospective prefix hit, via the side-effect-free
        ``pkv.peek``) sum within one ``max_chunk`` bucket and whose
        kv_block-aligned workspace spans fit ``wws`` — at most one
        segment per free slot. Returns True when it consumed this
        step's chunk budget (packed call, or a degenerate single-request
        group handed to the normal chunked path); False hands admission
        back to the unpacked path with the queue untouched.
        """
        free = [i for i, s in enumerate(self.slots) if s is None]
        if len(free) < 2 or len(self.queue) < 2:
            return False
        kvb = min(self.cfg.attn_kv_block, self.wws)

        def span(L):
            return -(-L // kvb) * kvb

        plan: List[Request] = []
        c_tot = base = 0
        for req in itertools.islice(self.queue, len(free)):
            if req.frames is not None or req.prefix_embeds is not None:
                break
            L = len(req.tokens)
            tail = L - self.pkv.peek(req.tokens)
            if (tail > self.max_chunk or c_tot + tail > self.max_chunk
                    or base + span(L) > self.wws):
                break
            plan.append(req)
            c_tot += tail
            base += span(L)
        if len(plan) < 2:
            return False
        # commit: reserve pages in FIFO order, stopping at the first
        # shortfall. The fit is re-checked against the ACTUAL hit — an
        # earlier admit's eviction can shrink a later candidate's hit
        # and grow its tail past the planned bucket.
        admitted: List[tuple] = []
        c_tot = base = 0
        for req in plan:
            slot = free[len(admitted)]
            L = len(req.tokens)
            got = self.pkv.admit(slot, req.tokens,
                                 L + req.max_new + self.spec_k)
            if got is None:
                break
            row, hit = got
            if c_tot + (L - hit) > self.max_chunk or base + span(L) > self.wws:
                self.pkv.release_slot(slot)
                break
            self.queue.popleft()
            req.t_admit = time.perf_counter()
            req.kv_pages = len(self.pkv.rows[slot])
            admitted.append((slot, req, row, hit))
            c_tot += L - hit
            base += span(L)
        if not admitted:
            return False  # page shortfall at the head: defer, FIFO held
        if len(admitted) == 1:
            slot, req, row, hit = admitted[0]
            self._start_chunking(slot, req, row, hit)
            return True
        self._packed_prefill(admitted)
        return True

    def _packed_prefill(self, admitted: List[tuple]):
        """Run one fused packed prefill for an admitted group and
        install every member: per-segment logits row -> first token,
        block row + length -> device cache, prompt -> prefix cache."""
        t0 = time.perf_counter()
        cfg = self.cfg
        S, NB = self.capacity, self.n_blocks
        kvb = min(cfg.attn_kv_block, self.wws)
        blocks = np.zeros((S, NB), np.int32)
        bases = np.zeros(S, np.int32)
        hists = np.zeros(S, np.int32)
        lens = np.zeros(S, np.int32)
        tails = []
        base = 0
        for s, (slot, req, row, hit) in enumerate(admitted):
            blocks[s] = row
            bases[s] = base
            hists[s] = hit
            lens[s] = len(req.tokens)
            tails.append(req.tokens[hit:])
            base += -(-len(req.tokens) // kvb) * kvb
        bases[len(admitted):] = base  # inactive segments park at the end
        tail_tot = sum(len(t) for t in tails)
        C = paged_kv.next_pow2(max(tail_tot, self.buckets[0]))
        toks = np.zeros((1, C), np.int32)
        toks[0, :tail_tot] = np.concatenate(tails)
        logits, pool = paged_packed_fn(cfg, self.wws)(
            self.params, jnp.asarray(toks), self.cache["pool"],
            jnp.asarray(blocks), jnp.asarray(bases), jnp.asarray(hists),
            jnp.asarray(lens))
        self.n_chunk_calls += 1
        self.n_packed_groups += 1
        self.n_packed_reqs += len(admitted)
        self.cache = dict(self.cache)
        self.cache["pool"] = pool
        for s, (slot, req, row, hit) in enumerate(admitted):
            L = len(req.tokens)
            self.cache["block"] = self.cache["block"].at[slot].set(
                jnp.asarray(row))
            self.cache["len"] = self.cache["len"].at[slot].set(L)
            self.pkv.insert_prefix(slot, req.tokens)
            # t_prefill is charged once (s=0 spans the packed call)
            self._install_first_token(slot, req, logits[s][None], L,
                                      t0 if s == 0 else time.perf_counter())

    def _start_chunking(self, slot: int, req: Request, row: np.ndarray,
                        hit_tokens: int):
        """Begin a chunked prefill. The device block row stays all-trash
        until the prefill finishes (``_finish_chunking`` installs it), so
        the slot's dead decode writes land in the trash page meanwhile.

        The workspace is ALWAYS hydrated — from cached pages on a prefix
        hit, and to zeros otherwise — so chunk inputs never depend on a
        previous request's leftovers (masked garbage is a numeric no-op,
        but a deterministic workspace keeps replays bit-stable)."""
        t0 = time.perf_counter()
        row_j = jnp.asarray(row)
        self.ws = paged_hydrate_fn(self.cfg, self.wws)(
            self.cache["pool"], row_j, jnp.int32(hit_tokens))
        self._chunking = {
            "req": req, "slot": slot, "row": row_j, "hit": hit_tokens,
            "plan": paged_kv.chunk_plan(len(req.tokens), hit_tokens,
                                        self.max_chunk),
            "i": 0,
        }
        self.t_prefill += time.perf_counter() - t0

    def _chunk_step(self):
        st = self._chunking
        t0 = time.perf_counter()
        start, width, n_real = st["plan"][st["i"]]
        req = st["req"]
        toks = np.zeros((1, width), np.int32)
        toks[0, :n_real] = req.tokens[start:start + n_real]
        logits, self.ws = paged_chunk_fn(self.cfg)(
            self.params, jnp.asarray(toks), self.ws, jnp.int32(start),
            jnp.int32(n_real))
        self.n_chunk_calls += 1
        st["i"] += 1
        self.t_prefill += time.perf_counter() - t0
        if st["i"] == len(st["plan"]):
            self._finish_chunking(logits)

    def _finish_chunking(self, logits):
        """Commit the finished prefill: splice workspace KV [hit, L) to
        the pages (never rewriting shared prefix-hit pages), install the
        block row + length, publish the prompt to the prefix cache, and
        sample the first token."""
        st, self._chunking = self._chunking, None
        req, slot, row_j = st["req"], st["slot"], st["row"]
        t0 = time.perf_counter()
        L = len(req.tokens)
        self.cache = dict(self.cache)
        self.cache["pool"] = paged_splice_fn(self.cfg)(
            self.cache["pool"], self.ws, row_j, jnp.int32(st["hit"]),
            jnp.int32(L))
        self.cache["block"] = self.cache["block"].at[slot].set(row_j)
        self.cache["len"] = self.cache["len"].at[slot].set(L)
        self.pkv.insert_prefix(slot, req.tokens)
        self._install_first_token(slot, req, logits, L, t0)

    def _admit_paged_encdec(self, slot: int, req: Request) -> bool:
        """encdec admission: reserve pages, run ONE bucket-padded prefill
        (per-stream ``lengths`` keeps the causal decoder exact under
        right-padding), splice self-attn KV to the pages and park the
        cross-attn memory in the slot's dense lane. Returns False on a
        page shortfall (head-of-line waits)."""
        total = len(req.tokens) + req.max_new + self.spec_k
        got = self.pkv.admit(slot, None, total)
        if got is None:
            return False
        self.queue.popleft()
        row, _ = got
        req.t_admit = time.perf_counter()
        req.kv_pages = len(self.pkv.rows[slot])
        t0 = time.perf_counter()
        L = len(req.tokens)
        Lb = paged_kv.next_pow2(max(L, self.buckets[0]))
        toks = np.zeros((1, Lb), np.int32)
        toks[0, :L] = req.tokens
        batch = {"tokens": jnp.asarray(toks),
                 "frames": jnp.asarray(req.frames)[None]}
        logits, pcache = prefill_fn(self.cfg, self.max_len, self.mesh)(
            self.params, batch, jnp.asarray([L], jnp.int32))
        row_j = jnp.asarray(row)
        self.cache = dict(paged_encdec_splice_fn(self.cfg)(
            self.cache, pcache["layers"], row_j, jnp.int32(L),
            jnp.int32(slot)))
        self.cache["block"] = self.cache["block"].at[slot].set(row_j)
        self.cache["len"] = self.cache["len"].at[slot].set(L)
        self.cache["src_len"] = self.cache["src_len"].at[slot].set(
            req.frames.shape[0])
        self._install_first_token(slot, req, logits, L, t0)
        return True

    def _install_first_token(self, slot: int, req: Request, logits,
                             length: int, t0: float):
        """Shared admission tail: sample token 1, arm the slot, retire
        immediately if max_new == 1 or the first token is EOS."""
        tok1, keys1 = _sample_fn(self.greedy)(
            logits, req.key[None], jnp.float32(self.temperature))
        self.tok = self.tok.at[slot].set(tok1[0])
        self.keys = self.keys.at[slot].set(keys1[0])
        first = int(np.asarray(jax.device_get(tok1))[0, 0])
        now = time.perf_counter()
        req.t_first = now
        req.out = [first]
        req.pstart = len(self._pending)
        self.slots[slot] = req
        self.n_admitted += 1
        self.pkv.lens[slot] = length
        self._maybe_retire(slot)
        self.t_prefill += now - t0

    def _release_window_pages(self):
        """Sliding-window decode never reads KV behind ``len - window``:
        free those pages (refcount-aware — shared prefix pages stay) and
        zero their device block entries so the freed physical pages
        can't be read or written through stale rows."""
        updates = []
        for slot, r in enumerate(self.slots):
            if r is None:
                continue
            for j in self.pkv.release_behind_window(slot, self.cfg.window):
                updates.append((slot, j))
        if updates:
            rows = jnp.asarray([u[0] for u in updates], jnp.int32)
            cols = jnp.asarray([u[1] for u in updates], jnp.int32)
            self.cache = dict(self.cache)
            self.cache["block"] = self.cache["block"].at[rows, cols].set(0)

    def _warm_paged(self):
        """AOT-warm every jit the paged engine can hit, closing the trace
        set at startup: all prefill bucket widths, hydrate, splice, the
        sampler, and the decode step. All calls are functional and their
        outputs are discarded — the engine cache stays zeroed. Serving
        must add no traces after this (``paged_trace_counts`` lets tests
        assert exactly that)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        temp = jnp.float32(self.temperature)
        zrow = jnp.zeros((self.n_blocks,), jnp.int32)
        lg = None
        if cfg.family == "encdec":
            frames = jnp.zeros((1, self.src_len, cfg.d_model), cfg.dtype)
            for width in self.buckets:
                lg, pc = prefill_fn(cfg, self.max_len, self.mesh)(
                    self.params,
                    {"tokens": jnp.zeros((1, width), jnp.int32),
                     "frames": frames},
                    jnp.asarray([1], jnp.int32))
                paged_encdec_splice_fn(cfg)(
                    self.cache, pc["layers"], zrow, jnp.int32(0),
                    jnp.int32(0))
            # NOTE: source widths re-trace per width — warmup covers the
            # full src_len; ragged-source workloads trace on first use
        else:
            ws = paged_hydrate_fn(cfg, self.wws)(
                self.cache["pool"], zrow, jnp.int32(0))
            for width in self.buckets:
                lg, ws = paged_chunk_fn(cfg)(
                    self.params, jnp.zeros((1, width), jnp.int32), ws,
                    jnp.int32(0), jnp.int32(width))
            paged_splice_fn(cfg)(self.cache["pool"], ws, zrow,
                               jnp.int32(0), jnp.int32(0))
            if self.prefill_pack:
                # all-inactive group: every row masked, splice targets
                # the trash page — functional, outputs discarded
                zb = jnp.zeros((self.capacity, self.n_blocks), jnp.int32)
                zs = jnp.zeros((self.capacity,), jnp.int32)
                for width in self.buckets:
                    paged_packed_fn(cfg, self.wws)(
                        self.params, jnp.zeros((1, width), jnp.int32),
                        self.cache["pool"], zb, zs, zs, zs)
        _sample_fn(self.greedy)(lg, self.keys[:1], temp)
        out = _paged_step_fn(cfg, self.greedy, self.mesh, self.capacity,
                             self.n_pages, self.page_size, self.n_blocks,
                             self.src_len)(
            self.params, self.tok, self.cache, self.keys, temp)
        jax.block_until_ready(out)
        if self.spec_k:
            # the spec round is one fused trace (drafts + verify window);
            # all-zero state drafts garbage into the trash page — outputs
            # discarded, the engine cache stays zeroed
            out = spec_step_fn(cfg, k=self.spec_k, greedy=self.greedy,
                               paged=True, mesh=self.mesh)(
                self.params, self.draft_params, self.tok, self.cache,
                self.keys, temp)
            jax.block_until_ready(out)
        self.t_warmup = time.perf_counter() - t0

    def paged_trace_counts(self) -> Dict[str, int]:
        """Jit-cache entry counts for every paged entry point this
        engine drives. The warmup closes the trace set, so serving must
        not grow these — the paged test-suite asserts the dict is
        unchanged across a full serve. NOTE: the underlying jits are
        lru-shared process-wide per config, so tests comparing engines
        that share a config should assert deltas, not absolutes."""
        cfg = self.cfg
        out = {
            "decode": _paged_step_fn(
                cfg, self.greedy, self.mesh, self.capacity, self.n_pages,
                self.page_size, self.n_blocks, self.src_len)._cache_size(),
            "sample": _sample_fn(self.greedy)._cache_size(),
        }
        if self.spec_k:
            out["spec"] = spec_step_fn(
                cfg, k=self.spec_k, greedy=self.greedy, paged=True,
                mesh=self.mesh)._cache_size()
        if cfg.family == "encdec":
            pf = prefill_fn(cfg, self.max_len, self.mesh)
            if hasattr(pf, "_cache_size"):
                out["prefill"] = pf._cache_size()
            out["splice"] = paged_encdec_splice_fn(cfg)._cache_size()
        else:
            out["chunk"] = paged_chunk_fn(cfg)._cache_size()
            out["splice"] = paged_splice_fn(cfg)._cache_size()
            out["hydrate"] = paged_hydrate_fn(cfg, self.wws)._cache_size()
            if self.prefill_pack:
                out["packed"] = paged_packed_fn(cfg, self.wws)._cache_size()
        return out

    # ------------------------------------------------------ static batch

    def preload(self, batch: Dict[str, jax.Array], steps: int, *,
                lengths=None, eos_id: Optional[int] = None):
        """Admit a whole padded batch with ONE batched prefill.

        The static-batch fast path used by ``serving.generate``: the
        engine must be idle and ``batch["tokens"]`` must have exactly
        ``capacity`` rows. ``lengths`` carries per-stream real prompt
        lengths for ragged batches (attention families; see
        ``api.prefill``). Slot i samples with ``fold_in(engine_rng, i)``
        — the same key a solo ``submit`` of that request would get.
        """
        if self.queue or any(s is not None for s in self.slots):
            raise RuntimeError("preload requires an idle engine")
        if self.paged:
            raise RuntimeError("preload is a slot-pool fast path; submit "
                               "requests individually on a paged engine")
        toks = batch["tokens"]
        B, P = toks.shape
        if B != self.capacity:
            raise ValueError(f"batch {B} != capacity {self.capacity}")
        t0 = time.perf_counter()
        lengths_j = (jnp.full((B,), P, jnp.int32) if lengths is None
                     else jnp.asarray(lengths, jnp.int32))
        pf = prefill_fn(self.cfg, self.max_len, self.mesh)
        if lengths is None:
            logits, cache = pf(self.params, batch)
        else:
            logits, cache = pf(self.params, batch, lengths_j)
        pfx = self.prefix if "prefix_embeds" in batch else 0
        self.cache = adapt_prefill_cache(
            self.cfg, cache, B, self.max_len, src_len=self.src_len,
            lengths=lengths_j + pfx)
        if self.mesh is not None:
            from repro.launch.partition import serve_shardings

            sh = serve_shardings(self.cfg, self.mesh, batch=self.capacity,
                                 max_len=self.max_len, src_len=self.src_len)
            self.cache = jax.device_put(self.cache, sh["cache"])
        tok1, keys = _sample_fn(self.greedy)(
            logits, self.keys, jnp.float32(self.temperature))
        self.tok, self.keys = tok1, keys
        firsts = np.asarray(jax.device_get(tok1[:, 0]))
        self.t_prefill += time.perf_counter() - t0

        now = time.perf_counter()
        lens_h = np.asarray(jax.device_get(lengths_j))
        toks_h = np.asarray(jax.device_get(toks), np.int32)
        for i in range(B):
            req = Request(self._next_rid, toks_h[i, :int(lens_h[i])],
                          int(steps), eos_id, self.keys[i],
                          t_submit=t0)
            self._next_rid += 1
            req.t_admit = t0
            req.t_first = now
            req.out = [int(firsts[i])]
            self.slots[i] = req
            self.n_admitted += 1
            self._maybe_retire(i)

    # -------------------------------------------------------------- loop

    def _materialize(self):
        """Pull all pending decode tokens to the host in one sync and
        run the retirement checks they unlock."""
        if not self._pending:
            return
        vals = np.asarray(jax.device_get(jnp.stack(self._pending)))  # (k, B)
        k = len(self._pending)
        self._pending = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for j in range(req.pstart, k):
                req.out.append(int(vals[j, slot]))
            req.pstart = 0
        for slot in range(self.capacity):
            if self.slots[slot] is not None:
                self._maybe_retire(slot)

    def _spec_round(self):
        """One speculative round over all slots: k draft steps with the
        coarse view + ONE verify window with the target weights, then a
        single host sync of the accepted blocks. Speculation trades the
        pipelined pending-token scheme for multi-token rounds — the host
        must see ``n_acc`` each round to know how far every slot got, so
        rounds materialize eagerly (one sync per round, not per token).

        EOS landing *inside* an accepted block retires the request the
        same step: the block is truncated at EOS (or at ``max_new``) and
        the slot is freed immediately — trailing accepted tokens are
        dropped, exactly as sequential decode would never have produced
        them. The cache length still advances by the full ``n_acc`` for
        the round, which is harmless: retirement pins the freed slot's
        length to 0 and admission overwrites it."""
        fn = spec_step_fn(self.cfg, k=self.spec_k, greedy=self.greedy,
                          paged=self.paged, mesh=self.mesh)
        out, n_acc, self.cache, self.keys = fn(
            self.params, self.draft_params, self.tok, self.cache,
            self.keys, jnp.float32(self.temperature))
        # next pending token = last accepted (its KV is not written yet)
        self.tok = jnp.take_along_axis(
            out, (n_acc - 1)[:, None], axis=1).astype(jnp.int32)
        out_h = np.asarray(jax.device_get(out))     # (B, k+1)
        acc_h = np.asarray(jax.device_get(n_acc))   # (B,)
        self.n_spec_rounds += 1
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            A = int(acc_h[slot])
            self._spec_live += 1
            self._spec_acc_tok += A
            self._spec_acc_draft += A - 1
            for t in out_h[slot, :A]:
                req.out.append(int(t))
                if ((req.eos_id is not None and int(t) == req.eos_id)
                        or len(req.out) >= req.max_new):
                    break
            if self.paged:
                self.pkv.lens[slot] += A
            self._maybe_retire(slot)
        if self.paged and self.cfg.window is not None:
            self._release_window_pages()

    def step(self) -> List[Dict[str, Any]]:
        """One engine iteration: admit into free slots, then advance all
        slots one decode step. Returns the requests retired this step.

        Sampled tokens stay on the device as pending handles — dispatch
        runs ahead of the host — and are materialized in ONE sync only
        when a retirement decision needs their values: every step while
        a live request carries an ``eos_id`` (the decision depends on
        the token), otherwise only on the host-predictable step where
        some request reaches ``max_new``. The static ``generate`` path
        (no EOS) therefore syncs once per run, like the loop it
        replaced; admission stays per-step responsive because it needs
        a free slot, not token values."""
        if self._t_start is None:
            self._t_start = time.perf_counter()
        before = set(self.results)
        if self.paged:
            self._paged_admit()
        elif self.queue and None in self.slots:
            free = [i for i, s in enumerate(self.slots) if s is None]
            take = [self.queue.popleft()
                    for _ in range(min(len(free), len(self.queue)))]
            groups: Dict[Any, List[Request]] = {}
            for req in take:
                groups.setdefault(self._group_key(req), []).append(req)
            for reqs in groups.values():
                slots, free = free[:len(reqs)], free[len(reqs):]
                self._admit_group(slots, reqs)
        active = [r for r in self.slots if r is not None]
        if active and self.spec_k:
            t0 = time.perf_counter()
            self._spec_round()
            self.t_decode += time.perf_counter() - t0
            self.n_decode_steps += 1
        elif active:
            t0 = time.perf_counter()
            if self.paged:
                fn = _paged_step_fn(self.cfg, self.greedy, self.mesh,
                                    self.capacity, self.n_pages,
                                    self.page_size, self.n_blocks,
                                    self.src_len)
            else:
                fn = _step_fn(self.cfg, self.greedy, self.mesh,
                              self.capacity, self.max_len, self.src_len)
            self.tok, self.cache, self.keys = fn(
                self.params, self.tok, self.cache, self.keys,
                jnp.float32(self.temperature))
            self._pending.append(self.tok[:, 0])
            if self.paged:
                for i, r in enumerate(self.slots):
                    if r is not None:
                        self.pkv.lens[i] += 1
                if self.cfg.window is not None:
                    self._release_window_pages()
            n_pend = len(self._pending)
            if (any(r.eos_id is not None for r in active)
                    or any(len(r.out) + n_pend - r.pstart >= r.max_new
                           for r in active)):
                self._materialize()
            self.t_decode += time.perf_counter() - t0
            self.n_decode_steps += 1
        return [self.results[r] for r in sorted(set(self.results) - before)]

    @property
    def idle(self) -> bool:
        return (not self.queue and self._chunking is None
                and all(s is None for s in self.slots))

    def run(self, stream: bool = False):
        """Drive the engine until every request retires.

        ``stream=True`` yields per-request result dicts as they finish;
        otherwise returns the full list ordered by rid."""

        def _gen():
            while not self.idle:
                for res in self.step():
                    yield res

        if stream:
            return _gen()
        for _ in _gen():
            pass
        return [self.results[rid] for rid in sorted(self.results)]

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        done = list(self.results.values())
        new_toks = sum(r["n_new"] for r in done)
        # first tokens come from prefill; decode produced the rest
        decoded = sum(max(r["n_new"] - 1, 0) for r in done)
        lat = sorted(r["t_total_s"] for r in done) or [0.0]
        ttft = sorted(r["t_first_token_s"] for r in done) or [0.0]
        wall = ((time.perf_counter() - self._t_start)
                if self._t_start is not None else 0.0)
        out = {
            "capacity": self.capacity,
            "max_len": self.max_len,
            "backend": self.cfg.kernel_backend,
            "mesh": (None if self.mesh is None else "x".join(
                str(self.mesh.shape[a]) for a in self.mesh.axis_names)),
            "admitted": self.n_admitted,
            "completed": len(done),
            "decode_steps": self.n_decode_steps,
            "new_tokens": new_toks,
            "t_prefill_s": self.t_prefill,
            "t_decode_s": self.t_decode,
            "wall_s": wall,
            "decode_tok_s": decoded / max(self.t_decode, 1e-9),
            "goodput_tok_s": new_toks / max(wall, 1e-9),
            "tokens_per_engine_step": decoded / max(self.n_decode_steps, 1),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "paged": self.paged,
        }
        if self.spec_k:
            live = max(self._spec_live, 1)
            out["speculative_k"] = self.spec_k
            out["draft_bits"] = self.draft_bits
            out["spec_rounds"] = self.n_spec_rounds
            # fraction of proposed draft tokens the verify pass accepted
            out["acceptance_rate"] = self._spec_acc_draft / (self.spec_k * live)
            # raw accepted tokens per live slot-round (incl. the bonus /
            # correction token — >= 1 by construction)
            out["spec_tokens_per_round"] = self._spec_acc_tok / live
            if self.draft_report is not None:
                out["draft_extra_bytes"] = sum(
                    v["draft_bytes"] for v in self.draft_report.values())
                out["draft_shared_leaves"] = sum(
                    1 for v in self.draft_report.values() if v["shared"])
                out["draft_coarse_leaves"] = sum(
                    1 for v in self.draft_report.values() if not v["shared"])
        if self.paged:
            out.update(self.pkv.stats())
            out["kv_bytes_per_token"] = paged_kv.kv_bytes_per_token(self.cfg)
            out["t_warmup_s"] = self.t_warmup
            out["prefill_chunk_calls"] = self.n_chunk_calls
            out["packed_groups"] = self.n_packed_groups
            out["packed_requests"] = self.n_packed_reqs
        return out
