"""Continuous-batching serving engine over the jit-cached decode path.

A fixed-capacity **slot pool**: every decode step advances all
``capacity`` slots through one jit-compiled ``decode_step + sample``
trace (fixed shapes — no retracing as traffic changes), while a FIFO
admission queue prefills new requests into free slots mid-flight and
EOS / max-token retirement frees slots immediately. This converts the
fused LUT-Q kernel win (weight bytes / HBM bandwidth per decode step)
into *served* throughput on ragged, asynchronous traffic — the decode
batch stays full instead of lock-stepping on the slowest member of a
static batch.

Lifecycle per request (see docs/serving.md):

  submit -> [queue] -> admit: requests taken the same step share ONE
                              batched prefill when exactness allows it
                              -> adapt_prefill_cache -> cache.at[slot]
         -> decode: one token per engine step, per-slot position/rng
         -> retire: EOS or max_new reached; slot freed the same step

Correctness contract: a request's tokens are **identical to a solo
``generate``** run of the same prompt (the ragged-parity suite pins
this per family, including ``kernel_backend="fused"``). Admission
prefills at the request's exact length by default — which is what makes
this hold for recurrent families (rwkv/zamba) whose state cannot mask
padding after the fact — and groups compatible requests into one
batched prefill (attention-only families batch ragged prompts via the
per-stream ``lengths`` threading in ``models.api.prefill``; recurrent
and MoE families group by exact length). ``prefill_bucket > 1``
right-pads admission prompts onto bucket boundaries for attention
families, closing the jit trace set over ragged lengths.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.runtime.serving import adapt_prefill_cache, prefill_fn


def _batch_axes(cfg: ModelConfig, max_len: int, src_len: int):
    """Per-leaf batch axis of the decode cache (structural finder;
    shared with ``partition.serve_shardings`` which needs the same
    answer to batch-shard the pool)."""
    from repro.launch.partition import cache_batch_axes

    return cache_batch_axes(cfg, max_len, src_len)


@functools.lru_cache(maxsize=64)
def _splice_fn(cfg: ModelConfig, axes: tuple, max_len: int, src_len: int,
               m: int, mesh=None, capacity: int = 0):
    """Jit-cached admission splice: adapt a batch=m prefill cache to the
    decode layout (ring relay, int8-KV quant, length override) and write
    row i into slot ``slots[i]`` of the pooled cache — one compiled
    dispatch per admission *group* instead of a trail of small
    host-driven ops. ``adapt_prefill_cache`` traces (no host sync),
    which is what makes this composition possible. Under a mesh the
    pool keeps its batch-on-data NamedShardings through the splice
    (mesh is part of the cache key — no stale traces across meshes)."""

    def splice(pool, prefill_cache, slots, lengths):
        grp = adapt_prefill_cache(cfg, prefill_cache, m, max_len,
                                  src_len=src_len, lengths=lengths)
        leaves_p, treedef = jax.tree.flatten(pool)
        leaves_g = jax.tree.leaves(grp)
        out = []
        for p, g, ax in zip(leaves_p, leaves_g, axes):
            g = g.astype(p.dtype)
            for i in range(m):
                row = jax.lax.dynamic_slice_in_dim(g, i, 1, axis=ax)
                p = jax.lax.dynamic_update_slice_in_dim(p, row, slots[i],
                                                        axis=ax)
            out.append(p)
        return jax.tree.unflatten(treedef, out)

    if mesh is None:
        return jax.jit(splice)
    from repro.launch.partition import serve_shardings

    sh = serve_shardings(cfg, mesh, batch=capacity, max_len=max_len,
                         src_len=src_len)
    return jax.jit(splice, in_shardings=(sh["cache"], None, None, None),
                   out_shardings=sh["cache"])


def _sample(logits, keys, temp, greedy: bool):
    """Per-slot sampling: logits (B,1,V) -> (tok (B,1), new keys).

    Each slot owns an rng chain, so a request's samples depend only on
    its own key — not on batch composition — which is what makes
    continuous-batch output reproducible against solo runs."""
    lg = logits[:, -1].astype(jnp.float32)
    if greedy:
        return jnp.argmax(lg, -1)[:, None].astype(jnp.int32), keys
    split = jax.vmap(jax.random.split)(keys)  # (B, 2, key)
    sub, new = split[:, 0], split[:, 1]
    tok = jax.vmap(jax.random.categorical)(sub, lg / jnp.maximum(temp, 1e-6))
    return tok[:, None].astype(jnp.int32), new


@functools.lru_cache(maxsize=64)
def _sample_fn(greedy: bool):
    # no explicit shardings: jit keys its executables on the input
    # shardings itself, so meshed and un-meshed engines can share this
    return jax.jit(functools.partial(_sample, greedy=greedy))


@functools.lru_cache(maxsize=64)
def _step_fn(cfg: ModelConfig, greedy: bool, mesh=None, capacity: int = 0,
             max_len: int = 0, src_len: int = 0):
    """One fused engine step: decode_step + per-slot sampling.

    With a mesh, the step takes explicit in/out NamedShardings
    (``partition.serve_shardings``): tok/cache/keys batch-sharded on
    the data axis, params at their committed placement. The mesh is in
    the lru key, so one process can serve several meshes without trace
    reuse."""

    def step(params, tok, cache, keys, temp):
        logits, cache = api.decode_step(params, cfg, tok, cache)
        tok, keys = _sample(logits, keys, temp, greedy)
        return tok, cache, keys

    if mesh is None:
        return jax.jit(step)
    from repro.launch.partition import serve_shardings

    sh = serve_shardings(cfg, mesh, batch=capacity, max_len=max_len,
                         src_len=src_len)
    return jax.jit(
        step,
        in_shardings=(None, sh["token"], sh["cache"], sh["keys"], None),
        out_shardings=(sh["token"], sh["cache"], sh["keys"]))


def synthetic_requests(cfg: ModelConfig, n: int, *, max_prompt: int,
                       max_new: int, seed: int = 0, src_len: int = 0,
                       rate: float = 0.0):
    """Deterministic ragged workload: ``n`` requests with uniform prompt
    lengths in [max(2, max_prompt//4), max_prompt], uniform max_new in
    [max(1, max_new//8), max_new] (wide on purpose — real generation
    lengths are heavy-tailed, which is exactly the straggle a lock-step
    batch pays for), and (for ``rate > 0``) Poisson arrival offsets at
    ``rate`` requests/s. Returns a list of kwargs dicts for
    ``Engine.submit`` plus an ``arrival_s`` field (callers that serve an
    open queue pop requests as their arrival time passes; batch callers
    ignore it)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        L = int(rng.integers(max(2, max_prompt // 4), max_prompt + 1))
        g = int(rng.integers(max(1, max_new // 8), max_new + 1))
        req: Dict[str, Any] = {
            "tokens": rng.integers(0, cfg.vocab, size=(L,)).astype(np.int32),
            "max_new": g,
            "arrival_s": t,
        }
        if cfg.family == "encdec":
            sl = int(rng.integers(max(2, src_len // 2), src_len + 1))
            req["frames"] = rng.standard_normal((sl, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            req["prefix_embeds"] = rng.standard_normal(
                (cfg.n_prefix_tokens, cfg.d_model)).astype(np.float32)
        reqs.append(req)
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
    return reqs


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                      # (L,) int32 prompt
    max_new: int
    eos_id: Optional[int]
    key: jax.Array
    frames: Optional[np.ndarray] = None     # encdec source embeddings (S, D)
    prefix_embeds: Optional[np.ndarray] = None  # vlm prefix (P, D)
    out: List[int] = dataclasses.field(default_factory=list)
    pstart: int = 0   # index into the engine's pending-token ring
    finish: str = ""
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    """Fixed-capacity continuous-batching engine.

    Usage::

        eng = Engine(params, cfg, capacity=8, max_len=128)
        eng.submit(prompt_tokens, max_new=32, eos_id=2)
        for result in eng.run(stream=True):
            ...                      # per-request dict as it retires
        print(eng.stats())

    ``capacity``: decode slots (the fixed decode batch).
    ``max_len``: per-slot cache width in text tokens (prompt + new);
    the vlm modality prefix widens it internally.
    ``src_len``: cross-attention memory width (encdec only).
    ``prefill_bucket``: round admission prefills up to a multiple of
    this to bound jit retraces across ragged prompt lengths (attention
    families only; recurrent families always prefill exact).
    ``mesh``: optional ``("data", "model")`` device mesh. The slot pool
    (cache, pending tokens, per-slot rng chains) is placed batch-on-data
    and every engine jit — splice dispatch, decode step, sampler —
    takes explicit NamedShardings keyed on the mesh, so continuous
    batching composes with tensor parallelism (params should already be
    placed via ``distributed.sharding.shard_serve_params``). Results
    are token-identical to an un-meshed engine.
    """

    def __init__(self, params, cfg: ModelConfig, *, capacity: int = 8,
                 max_len: int = 128, src_len: int = 0,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None,
                 backend: Optional[str] = None, prefill_bucket: int = 1,
                 mesh=None):
        if backend is not None:
            cfg = cfg.replace(kernel_backend=backend)
        self.cfg = cfg
        self.params = params
        self.capacity = int(capacity)
        self.prefix = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
        self.max_len = int(max_len) + self.prefix
        self.src_len = int(src_len)
        self.temperature = float(temperature)
        self.greedy = self.temperature <= 0
        self.mesh = mesh
        self.prefill_bucket = max(1, int(prefill_bucket))
        if cfg.family in ("ssm", "hybrid") or cfg.n_experts:
            # padded prefill corrupts recurrent state, and MoE routing
            # capacity couples real tokens to padding — always exact
            self.prefill_bucket = 1
        self._base_rng = rng if rng is not None else jax.random.PRNGKey(0)

        self.cache = api.init_cache(cfg, self.capacity, self.max_len,
                                    src_len=self.src_len)
        self._axes = _batch_axes(cfg, self.max_len, self.src_len)
        self.tok = jnp.zeros((self.capacity, 1), jnp.int32)
        self.keys = jnp.stack([jax.random.fold_in(self._base_rng, i)
                               for i in range(self.capacity)])
        if mesh is not None:
            from repro.launch.partition import serve_shardings

            sh = serve_shardings(cfg, mesh, batch=self.capacity,
                                 max_len=self.max_len, src_len=self.src_len)
            self.cache = jax.device_put(self.cache, sh["cache"])
            self.tok = jax.device_put(self.tok, sh["token"])
            self.keys = jax.device_put(self.keys, sh["keys"])
        self.slots: List[Optional[Request]] = [None] * self.capacity
        self.queue: deque = deque()
        self._pending: List[jax.Array] = []  # un-synced decode tokens
        self.results: Dict[int, Dict[str, Any]] = {}
        self._next_rid = 0
        self.n_decode_steps = 0
        self.n_admitted = 0
        self.t_prefill = 0.0
        self.t_decode = 0.0
        self._t_start: Optional[float] = None

    # ------------------------------------------------------------- queue

    def submit(self, tokens, *, max_new: int, eos_id: Optional[int] = None,
               rng: Optional[jax.Array] = None, frames=None,
               prefix_embeds=None) -> int:
        """Enqueue one request; returns its rid (FIFO admission order).

        ``rng``: per-request sampling key (defaults to
        ``fold_in(engine_rng, rid)``). ``generate`` gives its stream i
        the key ``fold_in(generate_rng, i)``, so to reproduce a
        temperature>0 stream against a solo ``generate(..., rng=K)``
        run, submit with ``rng=jax.random.fold_in(K, 0)``.
        """
        prompt = np.asarray(jax.device_get(tokens), np.int32).reshape(-1)
        if len(prompt) == 0:
            raise ValueError("empty prompt")
        if len(prompt) + int(max_new) + self.prefix > self.max_len:
            raise ValueError(
                f"prompt {len(prompt)} + max_new {max_new} exceeds engine "
                f"max_len {self.max_len - self.prefix}")
        if self.cfg.family == "encdec":
            if frames is None:
                raise ValueError("encdec requests need `frames`")
            if frames.shape[0] > self.src_len:
                raise ValueError(
                    f"frames {frames.shape[0]} exceed engine src_len "
                    f"{self.src_len}")
        rid = self._next_rid
        self._next_rid += 1
        key = rng if rng is not None else jax.random.fold_in(self._base_rng, rid)
        req = Request(rid, prompt, int(max_new), eos_id, key,
                      frames=frames, prefix_embeds=prefix_embeds,
                      t_submit=time.perf_counter())
        self.queue.append(req)
        return rid

    # --------------------------------------------------------- admission

    def _group_key(self, req: Request):
        """Requests admitted in the same step share one batched prefill
        when exactness allows it: attention-only families batch ragged
        prompts freely (per-stream ``lengths`` keeps them exact);
        recurrent state and MoE routing are batch-coupled under padding,
        so those group by exact prompt length; encdec additionally needs
        equal source widths (the encoder is bidirectional — padded
        frames would corrupt real positions)."""
        if self.cfg.family in ("ssm", "hybrid") or self.cfg.n_experts:
            return ("exact", len(req.tokens))
        if self.cfg.family == "encdec":
            return ("src", req.frames.shape[0])
        # text-only and prefixed vlm requests occupy different cache
        # layouts — never share a prefill
        return ("any", req.prefix_embeds is not None)

    def _admit_group(self, slots: List[int], reqs: List[Request]):
        """Prefill a group of compatible requests with ONE batched call
        and splice each row into its slot."""
        t0 = time.perf_counter()
        cfg = self.cfg
        m = len(reqs)
        Ls = [len(r.tokens) for r in reqs]
        Lb = -(-max(Ls) // self.prefill_bucket) * self.prefill_bucket
        toks = np.zeros((m, Lb), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :Ls[i]] = r.tokens
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.stack([jnp.asarray(r.frames) for r in reqs])
        # a modality prefix occupies cache slots only when it is really
        # present (text-only vlm requests prefill without one, and the
        # group key keeps the two kinds apart)
        pfx = 0
        if reqs[0].prefix_embeds is not None:
            batch["prefix_embeds"] = jnp.stack(
                [jnp.asarray(r.prefix_embeds) for r in reqs])
            pfx = self.prefix
        lengths = jnp.asarray(Ls, jnp.int32)
        slots_j = jnp.asarray(slots, jnp.int32)

        logits, cache = prefill_fn(cfg, self.max_len, self.mesh)(
            self.params, batch, lengths)
        # prefill wants *text* lengths (its logit gather offsets the vlm
        # prefix itself); the decode cache's `len` counts cache slots,
        # which include any prefix positions
        self.cache = _splice_fn(cfg, self._axes, self.max_len, self.src_len,
                                m, self.mesh, self.capacity)(
                                    self.cache, cache, slots_j, lengths + pfx)
        keys = jnp.stack([r.key for r in reqs])
        tok1, keys1 = _sample_fn(self.greedy)(
            logits, keys, jnp.float32(self.temperature))
        self.tok = self.tok.at[slots_j].set(tok1)
        self.keys = self.keys.at[slots_j].set(keys1)
        firsts = np.asarray(jax.device_get(tok1[:, 0]))  # one sync per group

        now = time.perf_counter()
        for i, (slot, req) in enumerate(zip(slots, reqs)):
            req.t_admit = t0
            req.t_first = now
            req.out = [int(firsts[i])]
            req.pstart = len(self._pending)  # earlier pending rows belong
            self.slots[slot] = req           # to the slot's prior occupant
            self.n_admitted += 1
            self._maybe_retire(slot)
        self.t_prefill += now - t0

    def _maybe_retire(self, slot: int):
        req = self.slots[slot]
        done_eos = req.eos_id is not None and req.out[-1] == req.eos_id
        done_len = len(req.out) >= req.max_new
        if not (done_eos or done_len):
            return
        req.finish = "eos" if done_eos else "length"
        req.t_done = time.perf_counter()
        self.slots[slot] = None
        # pin the freed slot's position and token so its dead-slot
        # decode writes stay inside the slot (and stay deterministic)
        # until the next admission overwrites it
        self.cache = dict(self.cache)
        self.cache["len"] = self.cache["len"].at[slot].set(0)
        self.tok = self.tok.at[slot].set(0)
        self.results[req.rid] = {
            "rid": req.rid,
            "tokens": np.asarray(req.out, np.int32),
            "prompt_len": len(req.tokens),
            "n_new": len(req.out),
            "finish": req.finish,
            "t_queue_s": req.t_admit - req.t_submit,
            "t_first_token_s": req.t_first - req.t_submit,
            "t_total_s": req.t_done - req.t_submit,
        }

    # ------------------------------------------------------ static batch

    def preload(self, batch: Dict[str, jax.Array], steps: int, *,
                lengths=None, eos_id: Optional[int] = None):
        """Admit a whole padded batch with ONE batched prefill.

        The static-batch fast path used by ``serving.generate``: the
        engine must be idle and ``batch["tokens"]`` must have exactly
        ``capacity`` rows. ``lengths`` carries per-stream real prompt
        lengths for ragged batches (attention families; see
        ``api.prefill``). Slot i samples with ``fold_in(engine_rng, i)``
        — the same key a solo ``submit`` of that request would get.
        """
        if self.queue or any(s is not None for s in self.slots):
            raise RuntimeError("preload requires an idle engine")
        toks = batch["tokens"]
        B, P = toks.shape
        if B != self.capacity:
            raise ValueError(f"batch {B} != capacity {self.capacity}")
        t0 = time.perf_counter()
        lengths_j = (jnp.full((B,), P, jnp.int32) if lengths is None
                     else jnp.asarray(lengths, jnp.int32))
        pf = prefill_fn(self.cfg, self.max_len, self.mesh)
        if lengths is None:
            logits, cache = pf(self.params, batch)
        else:
            logits, cache = pf(self.params, batch, lengths_j)
        pfx = self.prefix if "prefix_embeds" in batch else 0
        self.cache = adapt_prefill_cache(
            self.cfg, cache, B, self.max_len, src_len=self.src_len,
            lengths=lengths_j + pfx)
        if self.mesh is not None:
            from repro.launch.partition import serve_shardings

            sh = serve_shardings(self.cfg, self.mesh, batch=self.capacity,
                                 max_len=self.max_len, src_len=self.src_len)
            self.cache = jax.device_put(self.cache, sh["cache"])
        tok1, keys = _sample_fn(self.greedy)(
            logits, self.keys, jnp.float32(self.temperature))
        self.tok, self.keys = tok1, keys
        firsts = np.asarray(jax.device_get(tok1[:, 0]))
        self.t_prefill += time.perf_counter() - t0

        now = time.perf_counter()
        lens_h = np.asarray(jax.device_get(lengths_j))
        toks_h = np.asarray(jax.device_get(toks), np.int32)
        for i in range(B):
            req = Request(self._next_rid, toks_h[i, :int(lens_h[i])],
                          int(steps), eos_id, self.keys[i],
                          t_submit=t0)
            self._next_rid += 1
            req.t_admit = t0
            req.t_first = now
            req.out = [int(firsts[i])]
            self.slots[i] = req
            self.n_admitted += 1
            self._maybe_retire(i)

    # -------------------------------------------------------------- loop

    def _materialize(self):
        """Pull all pending decode tokens to the host in one sync and
        run the retirement checks they unlock."""
        if not self._pending:
            return
        vals = np.asarray(jax.device_get(jnp.stack(self._pending)))  # (k, B)
        k = len(self._pending)
        self._pending = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for j in range(req.pstart, k):
                req.out.append(int(vals[j, slot]))
            req.pstart = 0
        for slot in range(self.capacity):
            if self.slots[slot] is not None:
                self._maybe_retire(slot)

    def step(self) -> List[Dict[str, Any]]:
        """One engine iteration: admit into free slots, then advance all
        slots one decode step. Returns the requests retired this step.

        Sampled tokens stay on the device as pending handles — dispatch
        runs ahead of the host — and are materialized in ONE sync only
        when a retirement decision needs their values: every step while
        a live request carries an ``eos_id`` (the decision depends on
        the token), otherwise only on the host-predictable step where
        some request reaches ``max_new``. The static ``generate`` path
        (no EOS) therefore syncs once per run, like the loop it
        replaced; admission stays per-step responsive because it needs
        a free slot, not token values."""
        if self._t_start is None:
            self._t_start = time.perf_counter()
        before = set(self.results)
        if self.queue and None in self.slots:
            free = [i for i, s in enumerate(self.slots) if s is None]
            take = [self.queue.popleft()
                    for _ in range(min(len(free), len(self.queue)))]
            groups: Dict[Any, List[Request]] = {}
            for req in take:
                groups.setdefault(self._group_key(req), []).append(req)
            for reqs in groups.values():
                slots, free = free[:len(reqs)], free[len(reqs):]
                self._admit_group(slots, reqs)
        active = [r for r in self.slots if r is not None]
        if active:
            t0 = time.perf_counter()
            self.tok, self.cache, self.keys = _step_fn(
                self.cfg, self.greedy, self.mesh, self.capacity,
                self.max_len, self.src_len)(
                    self.params, self.tok, self.cache, self.keys,
                    jnp.float32(self.temperature))
            self._pending.append(self.tok[:, 0])
            n_pend = len(self._pending)
            if (any(r.eos_id is not None for r in active)
                    or any(len(r.out) + n_pend - r.pstart >= r.max_new
                           for r in active)):
                self._materialize()
            self.t_decode += time.perf_counter() - t0
            self.n_decode_steps += 1
        return [self.results[r] for r in sorted(set(self.results) - before)]

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)

    def run(self, stream: bool = False):
        """Drive the engine until every request retires.

        ``stream=True`` yields per-request result dicts as they finish;
        otherwise returns the full list ordered by rid."""

        def _gen():
            while not self.idle:
                for res in self.step():
                    yield res

        if stream:
            return _gen()
        for _ in _gen():
            pass
        return [self.results[rid] for rid in sorted(self.results)]

    # ------------------------------------------------------------- stats

    def stats(self) -> Dict[str, Any]:
        done = list(self.results.values())
        new_toks = sum(r["n_new"] for r in done)
        # first tokens come from prefill; decode produced the rest
        decoded = sum(max(r["n_new"] - 1, 0) for r in done)
        lat = sorted(r["t_total_s"] for r in done) or [0.0]
        wall = ((time.perf_counter() - self._t_start)
                if self._t_start is not None else 0.0)
        return {
            "capacity": self.capacity,
            "max_len": self.max_len,
            "backend": self.cfg.kernel_backend,
            "mesh": (None if self.mesh is None else "x".join(
                str(self.mesh.shape[a]) for a in self.mesh.axis_names)),
            "admitted": self.n_admitted,
            "completed": len(done),
            "decode_steps": self.n_decode_steps,
            "new_tokens": new_toks,
            "t_prefill_s": self.t_prefill,
            "t_decode_s": self.t_decode,
            "wall_s": wall,
            "decode_tok_s": decoded / max(self.t_decode, 1e-9),
            "goodput_tok_s": new_toks / max(wall, 1e-9),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p95_latency_s": float(np.percentile(lat, 95)),
        }
