"""Self-speculative decoding from nested LUT-Q dictionaries.

Decode is weight-bandwidth-bound: every engine step streams the whole
quantized model from HBM for ONE token per slot. LUT-Q gives us a draft
model for free — :func:`repro.core.policy.draft_view` re-clusters each
K-entry dictionary into K' = 2**draft_bits coarse entries over the SAME
stored assignment indices, so a low-bit "view" of the model costs a
second tiny dictionary plus remapped/packed indices. Each round:

  1. the draft view proposes k tokens autoregressively (k cheap steps,
     streaming the coarse dictionaries + packed indices);
  2. ONE target forward over the (k+1)-token window verifies them
     (``api.decode_window`` — weight matmuls batch over the window, so
     the full-precision-dictionary weights stream once per round);
  3. accepted tokens commit; the cache rewinds to the accepted length.

The draft and target share one KV cache: draft steps write their
(draft-computed) KV at positions n0..n0+k-1, then the verify window
re-feeds the same tokens with target params and overwrites those
positions position-by-position *before* each position attends — so
every verify position attends pure target KV, and under greedy the
round's accepted tokens are **bitwise identical** to non-speculative
decode (the repo's parity contract). Rejected positions' KV stays in
the cache beyond ``len`` — masked scores hit -1e30 before the softmax
row max, so their contribution is exactly 0.0 (the same bitwise-neutral
masking the paged trash page relies on) — and is overwritten next
round.

Sliding-window (ring) caches need one extra move: the k+1 ring columns
a round touches may hold still-live entries from ``window`` positions
back, so the round snapshots them up front and restores the columns
past the accepted length afterwards (requires k+1 <= ring width,
enforced by the engine).

Under temperature the accept rule is Leviathan et al.'s rejection
sampling: draft token d_i is accepted with probability
min(1, p_i(d_i)/q_i(d_i)); the first rejection resamples from
norm(max(0, p_i - q_i)); a fully-accepted round samples a bonus token
from p_{k+1}. Per-position outputs are then distributed exactly as
sampling from the target alone (distributional, not bitwise, parity —
the rng consumption differs).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import api
from repro.models.config import ModelConfig

_RING_KEYS = ("k", "v", "k_scale", "v_scale")


def ring_width(cfg: ModelConfig, max_len: int) -> Optional[int]:
    """Ring-buffer width of the slot KV cache, or None when the cache is
    linear (no SWA, or max_len within the window)."""
    if cfg.window is None:
        return None
    eff = min(max_len, cfg.window)
    return eff if eff <= cfg.window else None


def _is_ring(cfg: ModelConfig, cache) -> bool:
    if cfg.window is None or "layers" not in cache:
        return False
    lk = cache["layers"].get("k") if isinstance(cache["layers"], dict) else None
    if lk is None:
        return False
    return lk.shape[2] <= cfg.window


def _ring_slots(n0: jax.Array, W: int, eff: int) -> jax.Array:
    """(B, W) ring columns a round touches: slot of position n0+j."""
    return (n0[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]) % eff


def _take_cols(leaf, slots, *, stacked: bool):
    b = jnp.arange(slots.shape[0])[:, None]
    return leaf[:, b, slots] if stacked else leaf[b, slots]


def _put_cols(leaf, slots, vals, *, stacked: bool):
    b = jnp.arange(slots.shape[0])[:, None]
    return (leaf.at[:, b, slots].set(vals) if stacked
            else leaf.at[b, slots].set(vals))


def _ring_snapshot(cache, slots):
    """Copy the touched ring columns of every per-position KV leaf.

    ``cache["layers"]`` leaves are stacked (Ls, B, eff, ...); prefix
    layers (first_dense) hold unstacked (B, eff, ...) twins. Cross-KV
    (xk/xv) and non-seq leaves are untouched by decode and skipped.
    """
    snap = {"layers": {k: _take_cols(cache["layers"][k], slots, stacked=True)
                       for k in _RING_KEYS if k in cache["layers"]}}
    if "prefix_layers" in cache:
        snap["prefix_layers"] = {
            name: {k: _take_cols(lc[k], slots, stacked=False)
                   for k in _RING_KEYS if k in lc}
            for name, lc in cache["prefix_layers"].items()}
    return snap


def _ring_restore(cache, snap, slots, n_acc):
    """Restore snapshot columns j >= n_acc (per batch row).

    Columns j < n_acc hold the verified target KV of the accepted
    positions n0..n0+A-1 and must keep it; columns j >= n_acc were
    speculatively overwritten and must regain their pre-round content
    (the entries ``window`` positions back, still live under SWA).
    """
    keep = jnp.arange(slots.shape[1])[None, :] >= n_acc[:, None]  # (B, W)

    def merge(leaf, sv, stacked):
        cur = _take_cols(leaf, slots, stacked=stacked)
        # broadcast (B, W) keep over the (Ls,) lead / head-dim tail
        lead = 1 if stacked else 0
        shape = (1,) * lead + keep.shape + (1,) * (cur.ndim - 2 - lead)
        m = keep.reshape(shape)
        return _put_cols(leaf, slots, jnp.where(m, sv, cur), stacked=stacked)

    out = dict(cache)
    out["layers"] = dict(cache["layers"])
    for k, sv in snap["layers"].items():
        out["layers"][k] = merge(cache["layers"][k], sv, True)
    if "prefix_layers" in snap:
        out["prefix_layers"] = {
            name: {**cache["prefix_layers"][name],
                   **{k: merge(cache["prefix_layers"][name][k], sv, False)
                      for k, sv in lc.items()}}
            for name, lc in snap["prefix_layers"].items()}
    return out


# ---------------------------------------------------------------------------
# accept rules
# ---------------------------------------------------------------------------

def greedy_accept(d: jax.Array, p_logits: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Longest-matching-prefix accept under greedy.

    d: (B, k) draft tokens; p_logits: (B, k+1, V) target logits over the
    verify window. Returns ``(out (B, k+1), n_acc (B,))`` — the emitted
    tokens are ``argmax(p)`` at every position (on the accepted prefix
    the draft token IS the argmax, so this single expression covers both
    the matched prefix and the free correction token), valid through
    ``n_acc = longest match + 1``. Token-identical to sequential greedy
    decode by induction: position j's logits were computed against pure
    target KV of positions < n0 + j.
    """
    k = d.shape[1]
    tgt = jnp.argmax(p_logits, axis=-1).astype(jnp.int32)       # (B, k+1)
    match = (d == tgt[:, :k]).astype(jnp.int32)
    n_acc = 1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return tgt, n_acc.astype(jnp.int32)


def rejection_accept(keys, d, q_logits, p_logits, temp
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Leviathan-style speculative rejection sampling (temperature > 0).

    d: (B, k) draft tokens sampled from q; q_logits: (B, k, V) draft
    logits each d_i was sampled from; p_logits: (B, k+1, V) target
    logits. Accept d_i w.p. min(1, p_i(d_i)/q_i(d_i)) (log-space); the
    first rejection resamples from norm(max(0, p_i - q_i)); full accept
    samples the bonus token from p_{k+1}. Returns (keys, out, n_acc);
    the marginal of each emitted token is exactly softmax(p_i/temp).
    """
    k = d.shape[1]

    def one(kk, dd, qq, pp):
        ka, kr, kn = jax.random.split(kk, 3)
        lq = jax.nn.log_softmax(qq.astype(jnp.float32) / temp, axis=-1)
        lp = jax.nn.log_softmax(pp.astype(jnp.float32) / temp, axis=-1)
        lq_d = jnp.take_along_axis(lq, dd[:, None], axis=1)[:, 0]
        lp_d = jnp.take_along_axis(lp[:k], dd[:, None], axis=1)[:, 0]
        u = jax.random.uniform(ka, (k,))
        acc = jnp.log(u) < (lp_d - lq_d)      # u < p/q  <=>  accept
        L = jnp.sum(jnp.cumprod(acc.astype(jnp.int32)))
        p_res = jnp.exp(jnp.take(lp, L, axis=0))                    # (V,)
        q_res = jnp.exp(jnp.take(lq, jnp.minimum(L, k - 1), axis=0))
        resid = jnp.where(L == k, p_res, jnp.maximum(p_res - q_res, 0.0))
        tot = jnp.sum(resid)
        probs = jnp.where(tot > 0, resid / jnp.maximum(tot, 1e-38), p_res)
        extra = jax.random.categorical(kr, jnp.log(jnp.maximum(probs, 1e-38)))
        out = jnp.where(jnp.arange(k + 1) < L,
                        jnp.concatenate([dd, dd[-1:]]),
                        extra).astype(jnp.int32)
        return kn, out, L + 1

    keys, out, n_acc = jax.vmap(one)(keys, d, q_logits, p_logits)
    return keys, out, n_acc.astype(jnp.int32)


# ---------------------------------------------------------------------------
# the fused speculative step
# ---------------------------------------------------------------------------

def _sample_draft(keys, logits, temp):
    def one(kk, lg):
        k1, k2 = jax.random.split(kk)
        t = jax.random.categorical(k2, lg.astype(jnp.float32) / temp)
        return k1, t
    keys, toks = jax.vmap(one)(keys, logits)
    return keys, toks.astype(jnp.int32)[:, None]


def _build_spec_step(cfg: ModelConfig, k: int, greedy: bool, paged: bool,
                     mesh):
    """One round: draft k tokens, verify in one window, accept, rewind.

    Signature: ``step(params, draft_params, tok, cache, keys, temp) ->
    (out (B, k+1), n_acc (B,), cache, keys)`` — ``tok`` is the per-slot
    pending token (KV not yet in the cache), ``out[:, :n_acc]`` are the
    round's emitted tokens, the new pending token is
    ``out[b, n_acc[b]-1]`` and the cache lands at ``len = n0 + n_acc``.
    """

    def step(params, draft_params, tok, cache, keys, temp):
        n0 = cache["len"]
        ring = (not paged) and _is_ring(cfg, cache)
        if ring:
            eff = cache["layers"]["k"].shape[2]
            slots = _ring_slots(n0, k + 1, eff)
            snap = _ring_snapshot(cache, slots)

        # -- draft: k cheap autoregressive steps with the coarse view --
        cur, c = tok, cache
        q_logits, drafts = [], []
        for _ in range(k):
            if paged:
                lg, c = api.paged_decode_step(draft_params, cfg, cur, c,
                                              mesh=mesh)
            else:
                lg, c = api.decode_step(draft_params, cfg, cur, c)
            lg = lg[:, -1]
            q_logits.append(lg)
            if greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
            else:
                keys, nxt = _sample_draft(keys, lg, temp)
            drafts.append(nxt)
            cur = nxt
        d = jnp.concatenate(drafts, axis=1)                       # (B, k)

        # -- verify: ONE target forward over the k+1 window, rewound to
        # n0 so it overwrites the draft KV position-by-position --
        if ring:
            # full rings attend EVERY filled slot, so the columns the
            # draft overwrote must regain their pre-round (still-live)
            # entries before verify position j attends them; the verify
            # scatter re-overwrites column j right before position j
            # attends, replaying the sequential order exactly
            c = _ring_restore(c, snap, slots, jnp.zeros_like(n0))
        c = dict(c)
        c["len"] = n0
        win = jnp.concatenate([tok, d], axis=1)                   # (B, k+1)
        if paged:
            p_logits, c = api.paged_decode_window(params, cfg, win, c,
                                                  mesh=mesh)
        else:
            p_logits, c = api.decode_window(params, cfg, win, c)

        if greedy:
            out, n_acc = greedy_accept(d, p_logits)
        else:
            keys, out, n_acc = rejection_accept(
                keys, d, jnp.stack(q_logits, axis=1), p_logits, temp)

        c = dict(c)
        c["len"] = n0 + n_acc
        if ring:
            c = _ring_restore(c, snap, slots, n_acc)
        return out, n_acc, c, keys

    return step


@functools.lru_cache(maxsize=64)
def _spec_fn_cached(cfg: ModelConfig, k: int, greedy: bool, paged: bool,
                    mesh, tuning):
    del tuning  # lru salt only (see serving.decode_fn)
    if mesh is not None:
        raise ValueError("speculative decoding does not compose with SPMD "
                         "meshes yet (per-slot rewind vs sharded caches); "
                         "run speculative engines un-meshed")
    return jax.jit(_build_spec_step(cfg, k, greedy, paged, mesh))


def spec_step_fn(cfg: ModelConfig, *, k: int, greedy: bool,
                 paged: bool = False, mesh=None):
    """Jit-cached speculative round (same caching contract as
    ``serving.decode_fn``: keyed on the hashable config + round shape +
    the tuning-cache fingerprint). The engine AOT-warms exactly this fn,
    keeping the closed-trace-set assertion intact."""
    ok, why = api.speculative_supported(cfg)
    if not ok:
        raise ValueError(why)
    if k < 1:
        raise ValueError(f"speculative k must be >= 1, got {k}")
    return _spec_fn_cached(cfg, k, greedy, paged, mesh,
                           ops.tuning_fingerprint())
