"""Logical-axis sharding rules (MaxText-style).

Model init returns a tree of *logical* axis names per array dim; this
module maps them onto mesh axes, with automatic divisibility fallback
(an axis whose dim doesn't divide the mesh axis size is replicated
rather than failing — e.g. 8 KV heads on a 16-way model axis shard via
the flattened feature dim instead).

Default physical mapping:
    batch            -> ("pod", "data")   [data parallel]
    embed            -> "data"            [FSDP / ZeRO-3 weight shard]
    heads/kv_heads/mlp/moe_mlp/vocab/kv_lora -> "model"  [tensor parallel]
    expert           -> "model"           [expert parallel]
    layer/super/inner-> None              [scan axes]
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.lutq import LutqState
from repro.nn.tree import map_with_path

LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    # per-expert FFN dim stays local: "expert" already takes the model
    # axis for MoE kernels (expert parallelism)
    "moe_mlp": (),
    # vocab_in = embedding table's vocab dim: kept unsharded so the token
    # gather needs no cross-device resharding (SPMD full-remat trap);
    # vocab = lm_head output dim: model-sharded (matmul-friendly).
    "vocab": ("model",),
    "vocab_in": (),
    # MLA latent dim stays local; its up-projections shard on heads
    "kv_lora": (),
    "expert": ("model",),
    "layer": (),
    "super": (),
    "inner": (),
}

# Serving variant: tensor/expert parallel only. At decode the FSDP-style
# "embed" -> data mapping is a pessimization — every step would
# all-gather weight shards over the data axis — and it breaks solo/
# sharded bit-identity (partial-sum reduction order). At serve the data
# axis shards batch/caches only; weights shard on "model" alone, so each
# matmul keeps its full reduction axis local and numerics are identical
# to a single device.
SERVE_RULES: Dict[str, Tuple[str, ...]] = {**LOGICAL_RULES, "embed": ()}

# Training variant: the full FSDP/DP + tensor-parallel mapping. Master
# weights, their gradients and the optimizer moments all shard embed ->
# "data" (ZeRO-style) on top of the "model" tensor axes; LutqState
# assignments follow the master's spec while dictionaries and rule ids
# are forced fully replicated by :func:`train_pspecs` — the step-4
# recenter then combines per-shard sums/counts with one psum (emitted by
# the partitioner for the segsum/stats formulations) and lands an
# identical dictionary on every device with no gather and no dense
# rematerialization. See docs/training.md.
TRAIN_RULES: Dict[str, Tuple[str, ...]] = dict(LOGICAL_RULES)


def _axes_for(name: Optional[str], mesh: Mesh, rules=None):
    if name is None:
        return None
    rules = LOGICAL_RULES if rules is None else rules
    cands = [a for a in rules.get(name, ()) if a in mesh.axis_names]
    if not cands:
        return None
    return tuple(cands) if len(cands) > 1 else cands[0]


def pspec_for(logical: Tuple[Optional[str], ...], mesh: Mesh,
              shape: Optional[Tuple[int, ...]] = None, rules=None) -> P:
    """PartitionSpec for one array. Drops axes that don't divide and
    never maps one mesh axis twice in a single spec."""
    parts = []
    used: set = set()
    for i, name in enumerate(logical):
        ax = _axes_for(name, mesh, rules)
        if ax is not None:
            ax_tuple = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in ax_tuple):
                ax = None
        if ax is not None and shape is not None:
            ax_tuple = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in ax_tuple]))
            if shape[i] % size != 0:
                ax = None
        if ax is not None:
            used.update(ax if isinstance(ax, tuple) else (ax,))
        parts.append(ax)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_pspecs(axes_tree, mesh: Mesh, shapes_tree=None, rules=None):
    """Build a PartitionSpec tree parallel to the params tree.

    LutqState leaves: w and a use the weight's spec; the dictionary d is
    sharded only along its stack axes (the K axis is tiny/replicated).
    For serve-form leaves (w=None) the spec — including the divisibility
    fallback — is computed against the *assignment's* actual shape:
    packed4 assignments hold two 4-bit indices per byte along axis -2,
    so a reduction axis that divides the logical weight dim but not the
    packed row count replicates rather than splitting a row pair across
    devices.
    """

    def lookup_shape(path):
        node = shapes_tree
        if node is None:
            return None
        for k in path:
            if not isinstance(node, dict) or k not in node:
                return None
            node = node[k]
        return node

    def build(path, logical):
        shp = lookup_shape(path)
        if isinstance(shp, LutqState) or (shp is not None and hasattr(shp, "w")):
            # serve_view drops w; assignments mirror the weight shape
            # (packed4: axis -2 counts packed row *pairs* — see docstring)
            wshape = (shp.w if shp.w is not None else shp.a).shape
            wspec = pspec_for(tuple(logical), mesh, wshape, rules)
            # d: (stack..., K) — shard stack axes like w, replicate K
            nstack = shp.d.ndim - 1
            dspec = P(*([wspec[i] if i < len(wspec) else None
                         for i in range(nstack)] + [None])) if nstack else P()
            sidspec = P() if getattr(shp, "sid", None) is not None else None
            # act: (stack..., 2) frozen [scale, qmax] pairs — shard the
            # stack axes like d, replicate the pair axis
            actspec = dspec if getattr(shp, "act", None) is not None else None
            return LutqState(w=wspec, d=dspec, a=wspec, sid=sidspec,
                             act=actspec)
        shape = getattr(shp, "shape", None)
        return pspec_for(tuple(logical), mesh, shape, rules)

    return map_with_path(build, axes_tree)


def train_pspecs(axes_tree, mesh: Mesh, params):
    """PartitionSpec tree for a *train-form* params tree under TRAIN_RULES.

    Masters ``w`` and assignments ``a`` partition along the weight's
    logical axes (FSDP ``embed -> data`` plus the tensor-parallel model
    axes); dictionaries ``d`` and rule ids ``sid`` are fully replicated
    — including their leading stack axes — so every device holds every
    (tiny) dictionary and the step-4 recenter psum is exact with no
    gather. The same specs govern gradients, optimizer moments and
    error-feedback state (they mirror the trainable tree leaf-for-leaf).
    """
    specs = tree_pspecs(axes_tree, mesh, params, rules=TRAIN_RULES)

    def replicate_d(leaf):
        if isinstance(leaf, LutqState):
            return LutqState(w=leaf.w, d=P(), a=leaf.a,
                             sid=P() if leaf.sid is not None else None,
                             act=P() if leaf.act is not None else None)
        return leaf

    return jax.tree.map(
        replicate_d, specs,
        is_leaf=lambda x: isinstance(x, (LutqState, P)) or x is None)


def serve_pspecs(axes_tree, mesh: Mesh, params):
    """PartitionSpec tree for a serve_view tree under SERVE_RULES.

    Indices (and packed layouts) partition along the same logical axes
    as the dense weight would, restricted to the "model" axis;
    dictionaries and rule ids replicate. See docs/sharding.md.
    """
    return tree_pspecs(axes_tree, mesh, params, rules=SERVE_RULES)


def shard_serve_params(params, axes_tree, mesh: Mesh):
    """device_put a serve_view tree onto its serving NamedShardings.

    Returns (sharded_params, pspec_tree). Every leaf lands committed —
    the serving jits then run SPMD with no dense weight materialization
    (quantized leaves stay dictionary + index shards on every device).
    """
    pspecs = serve_pspecs(axes_tree, mesh, params)
    return shard_tree(params, pspecs, mesh), pspecs


def shard_tree(tree, pspecs, mesh: Mesh):
    """device_put every leaf with its NamedSharding."""

    def put(x, spec):
        if x is None:
            return None
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, pspecs,
                        is_leaf=lambda x: x is None)


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes if len(axes) > 1 else (axes[0] if axes else None))


def _ambient_axes():
    """Axis names of whatever mesh is in context (jit or Mesh ctx)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if not am.empty:
            return set(am.axis_names)
    except Exception:  # noqa: BLE001
        pass
    try:
        from jax._src import mesh as _mesh_mod
        pm = _mesh_mod.thread_resources.env.physical_mesh
        if pm.axis_names:
            return set(pm.axis_names)
    except Exception:  # noqa: BLE001
        pass
    return set()


def constrain(x, parts):
    """Best-effort ``with_sharding_constraint``: drops axis names absent
    from the ambient mesh and becomes a no-op when there is no mesh —
    safe to call from model code that also runs un-meshed on CPU.

    Used at resharding cliffs (embedding gather output, logits) where
    SPMD otherwise falls back to replicate-then-repartition.
    """
    axes = _ambient_axes()
    if not axes:
        return x
    def keep(p):
        if p is None:
            return None
        t = p if isinstance(p, tuple) else (p,)
        t = tuple(a for a in t if a in axes)
        if not t:
            return None
        return t if len(t) > 1 else t[0]
    spec = P(*[keep(p) for p in parts])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:  # noqa: BLE001 — best effort
        return x
