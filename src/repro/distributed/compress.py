"""Gradient compression for the data-parallel all-reduce.

Two composable pieces (paper-adjacent: quantization applied to
*communication*, with the same error-feedback idea that makes LUT-Q's
lossy weights trainable):

1. ``ef_int8_transform`` — error-feedback int8 gradient compression.
   Each leaf is quantized to int8 with a per-tensor scale before the
   reduction; the quantization residual is carried to the next step
   (Seide et al. / 1-bit SGD style EF), which keeps SGD convergent.
   Under pjit the all-reduce itself is emitted by XLA; compressing the
   *values* that enter it is exactly what a compressed collective does
   arithmetically, and halves/quarters DP collective bytes at scale
   (quantified in the roofline table).

2. ``ring_allreduce`` — an explicit reduce-scatter + all-gather ring
   built from ``ppermute`` inside ``shard_map``, operating on int8
   chunks. This is the collective-schedule building block for
   bandwidth-optimal compressed reductions; validated on host devices.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# error-feedback int8 compression
# ---------------------------------------------------------------------------

def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress_leaf(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (dequantized compressed gradient, new error memory)."""
    x = g.astype(jnp.float32) + e
    q, scale = _quant_int8(x)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), x - deq


def init_ef_state(grads_like):
    return jax.tree.map(
        lambda g: None if g is None else jnp.zeros(g.shape, jnp.float32),
        grads_like, is_leaf=lambda x: x is None)


def ef_int8_transform(grads, ef_state):
    """Apply EF-int8 compression to a gradient tree. Returns (grads, ef)."""
    out = jax.tree.map(
        lambda g, e: (None, None) if g is None else ef_compress_leaf(g, e),
        grads, ef_state, is_leaf=lambda x: x is None)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


# ---------------------------------------------------------------------------
# explicit ring all-reduce (reduce-scatter + all-gather) via ppermute
# ---------------------------------------------------------------------------

def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bandwidth-optimal ring all-reduce along a shard_map axis.

    x: the *local* shard, chunked along dim 0 into `n` pieces. Total
    bytes on the wire per device: 2 * (n-1)/n * |x| — the textbook ring.
    """
    # jax.lax.axis_size is missing on older jax; psum of a literal 1
    # resolves to a concrete int under shard_map there.
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:
        n = jax.lax.psum(1, axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    pad = (-x.shape[0]) % n
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    chunks = x.reshape(n, x.shape[0] // n, *x.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 steps, chunk (idx+1) holds the full sum
    def rs_step(k, chunks):
        send_i = (idx - k) % n
        buf = jax.lax.ppermute(chunks[send_i], axis_name, perm)
        recv_i = (idx - k - 1) % n
        return chunks.at[recv_i].add(buf)

    chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)

    # all-gather: circulate the reduced chunks
    def ag_step(k, chunks):
        send_i = (idx + 1 - k) % n
        buf = jax.lax.ppermute(chunks[send_i], axis_name, perm)
        recv_i = (idx - k) % n
        return chunks.at[recv_i].set(buf)

    chunks = jax.lax.fori_loop(0, n - 1, ag_step, chunks)
    out = chunks.reshape(-1, *x.shape[1:])
    if pad:
        out = out[: out.shape[0] - pad]
    return out


def compressed_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire ring all-reduce: quantize the local contribution,
    ring-reduce in f32 (accumulators never overflow int8 range * n),
    requantizing per hop is a policy knob (kept exact-accumulate here)."""
    q, scale = _quant_int8(x.astype(jnp.float32))
    # per-device scales differ: ship scale-adjusted f16 payloads
    payload = (q.astype(jnp.float16) * scale.astype(jnp.float16))
    return ring_allreduce(payload.astype(jnp.float32), axis_name).astype(x.dtype)


# ---------------------------------------------------------------------------
# the train step's grad_transform hook: compressed DP gradient exchange
# ---------------------------------------------------------------------------

GRAD_COMPRESS_MODES = ("ef", "ring")


def dp_axis_size(mesh) -> int:
    """Total data-parallel degree of a mesh (product of pod x data)."""
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= int(mesh.shape[a])
    return n


def _flatten_grads(tree):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: x is None)
    idx = [i for i, g in enumerate(leaves) if g is not None]
    return leaves, treedef, idx


def _has_dp_axis(spec) -> bool:
    for part in tuple(spec or ()):
        for a in (part if isinstance(part, tuple) else (part,)):
            if a in ("data", "pod"):
                return True
    return False


def _ring_transform(grads, ef, mesh, pspecs=None):
    """EF-int8 compression + the explicit f16-payload ppermute ring.

    Gradients enter this hook already summed over the data axis (the
    partitioner emits that reduction inside backward), so the ring runs
    as a *broadcast-consistency* pass: every device quantizes ``x/n``
    (x = grad + error memory) and the ring sums the n identical f16
    payloads back to ~x. Wire traffic per device is the real compressed
    collective schedule — ``2 (n-1)/n`` of the f16 payload bytes through
    ``ppermute`` — and the value that reaches the optimizer is exactly
    what an n-worker compressed ring all-reduce would deliver, error
    feedback included. f16 ring accumulation is the validation-path
    simplification (a production ring accumulates wider per hop).

    ``pspecs`` (the trainable PartitionSpec tree, when the step runs
    meshed) keeps the pass gather-free: leaves replicated across the
    data axis enter the shard_map with their *own* spec (model-axis
    shards ring as-is), while FSDP data-sharded leaves — whose gradient
    slices are per-device owned, with no replicas to make consistent —
    take the plain EF path instead of being all-gathered to f32 just to
    ring. Without pspecs every leaf is assumed replicated (host-tree
    callers).

    Scaling note: the ring runs over the "data" axis only, so the
    broadcast-consistency divisor must match it exactly — devices along
    "pod"/"model" hold the same already-reduced gradient and do not
    participate (dp_axis_size here would shrink gradients by the pod
    factor on a multi-pod mesh).
    """
    from jax.experimental.shard_map import shard_map

    n = int(mesh.shape["data"])
    g_leaves, treedef, idx = _flatten_grads(grads)
    e_leaves, _, _ = _flatten_grads(ef)
    if pspecs is None:
        spec_leaves = [P()] * len(g_leaves)
    else:
        spec_leaves = jax.tree.flatten(pspecs, is_leaf=lambda x: x is None)[0]
        spec_leaves = [P() if s is None else s for s in spec_leaves]

    ring_idx = [i for i in idx if not _has_dp_axis(spec_leaves[i])]
    ef_idx = [i for i in idx if _has_dp_axis(spec_leaves[i])]

    go, eo = list(g_leaves), list(e_leaves)
    for i in ef_idx:  # per-device-owned shards: compress in place
        go[i], eo[i] = ef_compress_leaf(g_leaves[i], e_leaves[i])

    if ring_idx:
        def local(gs, es):
            outs_g, outs_e = [], []
            for g, e in zip(gs, es):
                x = g.astype(jnp.float32) + e
                q, scale = _quant_int8(x / n)
                payload = q.astype(jnp.float16) * scale.astype(jnp.float16)
                wire = ring_allreduce(payload, "data").astype(jnp.float32)
                outs_g.append(wire.astype(g.dtype))
                outs_e.append(x - wire)
            return tuple(outs_g), tuple(outs_e)

        specs = tuple(spec_leaves[i] for i in ring_idx)
        new_g, new_e = shard_map(
            local, mesh=mesh, in_specs=(specs, specs),
            out_specs=(specs, specs), check_rep=False)(
                tuple(g_leaves[i] for i in ring_idx),
                tuple(e_leaves[i] for i in ring_idx))
        for j, i in enumerate(ring_idx):
            go[i], eo[i] = new_g[j], new_e[j]
    return jax.tree.unflatten(treedef, go), jax.tree.unflatten(treedef, eo)


def dp_grad_transform(mesh=None, *, mode: str = "ef", pspecs=None):
    """Build the ``grad_transform`` hook for compressed data parallelism.

    Returns ``fn(grads, ef) -> (grads, ef)`` for
    :func:`repro.optim.train_state.make_train_step`; the error-feedback
    tree ``ef`` lives in the train state (``init_train_state(...,
    grad_compress=True)``) so the residual carries across steps.

    ``mode``:
      * ``"ef"`` — error-feedback int8 quantize/dequantize per leaf: the
        arithmetic each worker contributes to a compressed DP
        all-reduce, with the reduction itself still emitted by the
        partitioner. Works on any mesh (or none) and keeps tensor
        parallelism fully intact.
      * ``"ring"`` — additionally pushes every leaf through the explicit
        f16-payload :func:`ring_allreduce` over the ``"data"`` axis
        (real ``ppermute`` wire traffic; see :func:`_ring_transform`).
        Falls back to ``"ef"`` arithmetic when the mesh has no
        data-parallel degree.

    ``pspecs``: the trainable PartitionSpec tree (mirror of the grads
    tree) when the step runs under explicit shardings — lets the ring
    operate on local shards with no gathers; see
    :func:`_ring_transform`.
    """
    if mode not in GRAD_COMPRESS_MODES:
        raise ValueError(f"unknown grad-compress mode {mode!r}; expected "
                         f"one of {GRAD_COMPRESS_MODES}")
    ring = (mode == "ring" and mesh is not None
            and "data" in mesh.axis_names and int(mesh.shape["data"]) > 1)

    def transform(grads, ef):
        if ef is None:
            raise ValueError("grad compression needs the error-feedback "
                             "state: init_train_state(..., grad_compress=True)")
        if ring:
            return _ring_transform(grads, ef, mesh, pspecs)
        return ef_int8_transform(grads, ef)

    return transform


def trainable_pspecs(shardings_state):
    """PartitionSpec tree of the trainable subtree of a
    ``launch.partition.train_shardings(...)["state"]`` dict — the
    ``pspecs`` input of :func:`dp_grad_transform`."""
    return jax.tree.map(
        lambda s: None if s is None else s.spec,
        shardings_state["trainable"], is_leaf=lambda x: x is None)


def dp_wire_bytes(grads, dp: int, mode: Optional[str] = None) -> int:
    """Modeled per-device DP gradient-exchange wire bytes for one step.

    Ring model: ``2 (n-1)/n * payload`` bytes per device (the textbook
    bound both the GSPMD all-reduce and :func:`ring_allreduce` meet).
    Payload dtype per leaf: native (f32) uncompressed; int8 + one f32
    scale for ``"ef"``; f16 + scale for ``"ring"`` (what the explicit
    ring actually ships). Used by ``benchmarks/train_bench.py`` — a
    modeled quantity (labeled as such there), not an HLO measurement.
    """
    if dp <= 1:
        return 0
    per_el = {None: None, "ef": 1, "ring": 2}[mode]
    total = 0
    for g in jax.tree.leaves(grads, is_leaf=lambda x: x is None):
        if g is None or not hasattr(g, "size"):
            continue
        itemsize = getattr(getattr(g, "dtype", None), "itemsize", 4)
        total += g.size * (per_el if per_el is not None else itemsize)
        if per_el is not None:
            total += 4  # per-tensor scale
    return int(total * 2 * (dp - 1) / dp)
