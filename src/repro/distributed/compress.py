"""Gradient compression for the data-parallel all-reduce.

Two composable pieces (paper-adjacent: quantization applied to
*communication*, with the same error-feedback idea that makes LUT-Q's
lossy weights trainable):

1. ``ef_int8_transform`` — error-feedback int8 gradient compression.
   Each leaf is quantized to int8 with a per-tensor scale before the
   reduction; the quantization residual is carried to the next step
   (Seide et al. / 1-bit SGD style EF), which keeps SGD convergent.
   Under pjit the all-reduce itself is emitted by XLA; compressing the
   *values* that enter it is exactly what a compressed collective does
   arithmetically, and halves/quarters DP collective bytes at scale
   (quantified in the roofline table).

2. ``ring_allreduce`` — an explicit reduce-scatter + all-gather ring
   built from ``ppermute`` inside ``shard_map``, operating on int8
   chunks. This is the collective-schedule building block for
   bandwidth-optimal compressed reductions; validated on host devices.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------------------
# error-feedback int8 compression
# ---------------------------------------------------------------------------

def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress_leaf(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (dequantized compressed gradient, new error memory)."""
    x = g.astype(jnp.float32) + e
    q, scale = _quant_int8(x)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), x - deq


def init_ef_state(grads_like):
    return jax.tree.map(
        lambda g: None if g is None else jnp.zeros(g.shape, jnp.float32),
        grads_like, is_leaf=lambda x: x is None)


def ef_int8_transform(grads, ef_state):
    """Apply EF-int8 compression to a gradient tree. Returns (grads, ef)."""
    out = jax.tree.map(
        lambda g, e: (None, None) if g is None else ef_compress_leaf(g, e),
        grads, ef_state, is_leaf=lambda x: x is None)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


# ---------------------------------------------------------------------------
# explicit ring all-reduce (reduce-scatter + all-gather) via ppermute
# ---------------------------------------------------------------------------

def ring_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bandwidth-optimal ring all-reduce along a shard_map axis.

    x: the *local* shard, chunked along dim 0 into `n` pieces. Total
    bytes on the wire per device: 2 * (n-1)/n * |x| — the textbook ring.
    """
    # jax.lax.axis_size is missing on older jax; psum of a literal 1
    # resolves to a concrete int under shard_map there.
    if hasattr(jax.lax, "axis_size"):
        n = jax.lax.axis_size(axis_name)
    else:
        n = jax.lax.psum(1, axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    pad = (-x.shape[0]) % n
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    chunks = x.reshape(n, x.shape[0] // n, *x.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: after n-1 steps, chunk (idx+1) holds the full sum
    def rs_step(k, chunks):
        send_i = (idx - k) % n
        buf = jax.lax.ppermute(chunks[send_i], axis_name, perm)
        recv_i = (idx - k - 1) % n
        return chunks.at[recv_i].add(buf)

    chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)

    # all-gather: circulate the reduced chunks
    def ag_step(k, chunks):
        send_i = (idx + 1 - k) % n
        buf = jax.lax.ppermute(chunks[send_i], axis_name, perm)
        recv_i = (idx - k) % n
        return chunks.at[recv_i].set(buf)

    chunks = jax.lax.fori_loop(0, n - 1, ag_step, chunks)
    out = chunks.reshape(-1, *x.shape[1:])
    if pad:
        out = out[: out.shape[0] - pad]
    return out


def compressed_allreduce(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire ring all-reduce: quantize the local contribution,
    ring-reduce in f32 (accumulators never overflow int8 range * n),
    requantizing per hop is a policy knob (kept exact-accumulate here)."""
    q, scale = _quant_int8(x.astype(jnp.float32))
    # per-device scales differ: ship scale-adjusted f16 payloads
    payload = (q.astype(jnp.float16) * scale.astype(jnp.float16))
    return ring_allreduce(payload.astype(jnp.float32), axis_name).astype(x.dtype)
