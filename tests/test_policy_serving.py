"""Tests for quantization policy, deployment views, and serving paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.lutq import LutqState, decode_any
from repro.core.policy import (
    default_predicate,
    dequantize_tree,
    kmeans_tree,
    merge_trainable,
    quantize_tree,
    quantized_fraction,
    serve_view,
    split_trainable,
)
from repro.kernels.ref import unpack4_kin
from repro.core.spec import QuantSpec


def _params():
    k = jax.random.PRNGKey(0)
    return {
        "layer": {
            "kernel": jax.random.normal(k, (64, 128)),
            "bias": jnp.zeros((128,)),
        },
        "norm": {"scale": jnp.ones((64,))},
        "step": jnp.zeros((), jnp.int32),
        "stacked": {"kernel": jax.random.normal(k, (3, 64, 64))},
        "moe": {"wi": jax.random.normal(k, (4, 32, 256))},
    }


class TestPolicy:
    def test_predicate_excludes_norms_and_biases(self):
        assert not default_predicate(("norm", "scale"), jnp.ones((64,)))
        assert not default_predicate(("layer", "bias"), jnp.ones((64, 64)))
        assert not default_predicate(("moe", "router"), jnp.ones((64, 8)))
        assert default_predicate(("layer", "kernel"), jnp.ones((64, 64)))

    def test_quantize_respects_min_size(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=10_000))
        assert not isinstance(q["layer"]["kernel"], LutqState)
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        assert isinstance(q["layer"]["kernel"], LutqState)

    def test_stack_axes_from_logical_axes(self):
        axes = {
            "layer": {"kernel": ("embed", "mlp"), "bias": ("mlp",)},
            "norm": {"scale": ("embed",)},
            "step": (),
            "stacked": {"kernel": ("layer", "embed", "mlp")},
            "moe": {"wi": ("expert", "embed", "moe_mlp")},
        }
        q = quantize_tree(_params(), QuantSpec(bits=2, min_size=1024), axes=axes)
        # per-layer and per-expert dictionaries
        assert q["stacked"]["kernel"].d.shape == (3, 4)
        assert q["moe"]["wi"].d.shape == (4, 4)
        assert q["layer"]["kernel"].d.shape == (4,)

    def test_split_merge_roundtrip(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        t, s = split_trainable(q)
        back = merge_trainable(t, s)
        for a, b in zip(jax.tree.leaves(q), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # int leaves are static
        assert t["step"] is None

    def test_kmeans_tree_improves_fit(self):
        params = _params()
        q = quantize_tree(params, QuantSpec(bits=2, min_size=1024))
        st0 = q["layer"]["kernel"]
        # perturb masters, refresh, fit must track the new masters
        w2 = st0.w + 0.5
        q["layer"]["kernel"] = LutqState(w=w2, d=st0.d, a=st0.a)
        q2 = kmeans_tree(q, QuantSpec(bits=2, min_size=1024, kmeans_iters=3))
        e_before = float(jnp.mean((decode_any(st0.d, st0.a) - w2) ** 2))
        st2 = q2["layer"]["kernel"]
        e_after = float(jnp.mean((decode_any(st2.d, st2.a) - w2) ** 2))
        assert e_after < e_before

    def test_dequantize_tree(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        d = dequantize_tree(q)
        assert not any(isinstance(l, LutqState)
                       for l in jax.tree.leaves(
                           d, is_leaf=lambda x: isinstance(x, LutqState)))

    def test_quantized_fraction(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        assert 0.5 < quantized_fraction(q) <= 1.0


class TestServeView:
    def test_drops_masters(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        s = serve_view(q)
        assert s["layer"]["kernel"].w is None
        # decoded values identical
        np.testing.assert_array_equal(
            np.asarray(decode_any(s["layer"]["kernel"].d, s["layer"]["kernel"].a)),
            np.asarray(decode_any(q["layer"]["kernel"].d, q["layer"]["kernel"].a)))

    def test_pack4_roundtrip(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        s = serve_view(q, pack4=True)
        a_packed = s["layer"]["kernel"].a
        assert a_packed.dtype == jnp.uint8
        # packed along axis -2: the matmul reduction axis = the Pallas
        # lutq_gemv_packed row-pair layout
        assert a_packed.shape[-2] == q["layer"]["kernel"].a.shape[-2] // 2
        np.testing.assert_array_equal(
            np.asarray(unpack4_kin(a_packed)),
            np.asarray(q["layer"]["kernel"].a))

    def test_pack4_skipped_for_large_K(self):
        q = quantize_tree(_params(), QuantSpec(bits=8, min_size=1024))
        s = serve_view(q, pack4=True)
        assert s["layer"]["kernel"].a.dtype == jnp.int8  # K=256 can't pack

    def test_materialize_unpacks(self):
        from repro.nn.linear import materialize
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        s = serve_view(q, pack4=True)
        np.testing.assert_allclose(
            np.asarray(materialize(s["layer"]["kernel"])),
            np.asarray(materialize(serve_view(q)["layer"]["kernel"])))

    def test_serve_bytes_match_paper_formula(self):
        from repro.core.memory import lutq_layer_bits
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        s = serve_view(q, pack4=True)
        st = s["layer"]["kernel"]
        n = st.a.size * 2  # packed
        got_bits = st.a.nbytes * 8 + st.d.nbytes * 8
        want_bits = lutq_layer_bits(n, K=16, b_float=32)
        assert got_bits == want_bits


class TestKV8:
    @pytest.mark.slow
    def test_decode_parity_within_tolerance(self):
        from repro.configs import get_config
        from repro.models import api
        from repro.models.reduce import reduced
        cfg = reduced(get_config("mistral-nemo-12b")).replace(
            quant=None, act_bits=32, remat=False)
        cfg8 = cfg.replace(kv_cache_bits=8)
        params, _ = api.init(jax.random.PRNGKey(1), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab)
        c16 = api.init_cache(cfg, 2, 16)
        c8 = api.init_cache(cfg8, 2, 16)
        assert c8["layers"]["k"].dtype == jnp.int8
        assert "k_scale" in c8["layers"]
        o16, o8 = [], []
        for t in range(12):
            l16, c16 = api.decode_step(params, cfg, toks[:, t:t+1], c16)
            l8, c8 = api.decode_step(params, cfg8, toks[:, t:t+1], c8)
            o16.append(l16)
            o8.append(l8)
        a, b = jnp.concatenate(o16, 1), jnp.concatenate(o8, 1)
        rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(a)))
        assert rel < 0.05, rel

    def test_kv8_cache_is_half_the_bytes(self):
        from repro.configs import get_config
        from repro.models import api
        from repro.models.reduce import reduced
        cfg = reduced(get_config("mistral-nemo-12b"))
        nb = lambda c: sum(x.nbytes for x in jax.tree.leaves(c))
        b16 = nb(api.init_cache(cfg.replace(dtype=jnp.bfloat16), 2, 1024))
        b8 = nb(api.init_cache(cfg.replace(dtype=jnp.bfloat16,
                                           kv_cache_bits=8), 2, 1024))
        assert b8 < b16 * 0.6  # int8 + scales ~= 0.53x


class TestMemoryFormulas:
    @given(st.integers(1, 8), st.integers(1000, 10_000_000))
    @settings(max_examples=20, deadline=None)
    def test_property_lutq_bits_formula(self, bits, n):
        from repro.core.memory import dense_layer_bits, lutq_layer_bits
        K = 2 ** bits
        got = lutq_layer_bits(n, K)
        assert got == K * 32 + n * bits
        if bits <= 8 and n > K * 32:
            assert got < dense_layer_bits(n)

    def test_affine_mults(self):
        from repro.core.memory import affine_mults
        assert affine_mults(10, 1000) == 10_000
        assert affine_mults(10, 1000, K=16) == 160
