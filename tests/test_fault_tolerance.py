"""Fault-tolerance integration tests, each in a subprocess with its own
device topology: elastic re-mesh restore (4 -> 8 devices) and SIGTERM
preemption checkpointing."""
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

ROOT = os.path.dirname(os.path.dirname(__file__))


def _run(code, timeout=300, env_extra=None):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=ROOT)


ELASTIC_PHASE1 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.ckpt import save
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4,), ("data",))
    w = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                       NamedSharding(mesh, P("data", None)))
    # one "training" update on the 4-device mesh
    w = jax.jit(lambda w: w * 2 + 1)(w)
    save({"w": w, "step": jnp.asarray(3)}, sys.argv_dir, 3)
    print("PHASE1_OK")
""")

ELASTIC_PHASE2 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.ckpt import restore
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((8,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None)), "step": None}
    tree, step = restore(sys.argv_dir, shardings=sh)
    assert step == 3
    w = tree["w"]
    assert len(w.sharding.device_set) == 8, w.sharding
    expect = np.arange(32.0).reshape(8, 4) * 2 + 1
    np.testing.assert_array_equal(np.asarray(w), expect)
    # keep training on the NEW mesh
    w2 = jax.jit(lambda w: w + 1)(w)
    np.testing.assert_array_equal(np.asarray(w2), expect + 1)
    print("PHASE2_OK")
""")


class TestElasticRemesh:
    def test_restore_onto_larger_mesh(self):
        with tempfile.TemporaryDirectory() as td:
            p1 = ELASTIC_PHASE1.replace("sys.argv_dir", repr(td))
            r1 = _run(p1)
            assert "PHASE1_OK" in r1.stdout, r1.stdout + r1.stderr
            p2 = ELASTIC_PHASE2.replace("sys.argv_dir", repr(td))
            r2 = _run(p2)
            assert "PHASE2_OK" in r2.stdout, r2.stdout + r2.stderr


PREEMPT = textwrap.dedent("""
    import os, sys, signal, threading
    sys.path.insert(0, "src")
    import jax.numpy as jnp
    from repro.runtime.loop import TrainLoop

    def slow_step(state, batch):
        import time; time.sleep(0.05)
        return {"x": state["x"] + 1}, {"loss": jnp.asarray(1.0)}

    loop = TrainLoop(slow_step, lambda s: {}, ckpt_dir=sys.argv_dir,
                     ckpt_every=10_000, log_every=10_000)
    # deliver SIGTERM to ourselves mid-run
    threading.Timer(0.4, lambda: os.kill(os.getpid(), signal.SIGTERM)).start()
    state, step = loop.run({"x": jnp.asarray(0)}, 10_000)
    assert step < 10_000, "should have been preempted"
    from repro.checkpoint.ckpt import latest_step
    assert latest_step(sys.argv_dir) == step
    print("PREEMPT_OK", step)
""")


class TestPreemption:
    def test_sigterm_checkpoints_and_exits(self):
        with tempfile.TemporaryDirectory() as td:
            r = _run(PREEMPT.replace("sys.argv_dir", repr(td)), timeout=120)
            assert "PREEMPT_OK" in r.stdout, r.stdout + r.stderr
