"""Shared test configuration.

Ensures ``src`` is importable when pytest is run without PYTHONPATH, and
makes the sibling ``hypothesis_compat`` shim importable from any rootdir
(property-based tests degrade to skips when hypothesis is absent instead
of dying at collection).
"""
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(Path(__file__).resolve().parent)):
    if p not in sys.path:
        sys.path.insert(0, p)
