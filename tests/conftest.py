"""Shared test configuration.

Ensures ``src`` is importable when pytest is run without PYTHONPATH, and
makes the sibling ``hypothesis_compat`` shim importable from any rootdir
(property-based tests degrade to skips when hypothesis is absent instead
of dying at collection).

Also drops compiled executables between test modules: one pytest
process compiles thousands of XLA CPU programs across the suite, and
the LLVM JIT eventually segfaults inside ``backend_compile`` if they
all stay resident (observed at ~300 tests in; the crashing test passes
in isolation). Clearing the repo's jit lru caches plus
``jax.clear_caches()`` at module boundaries bounds resident executables
at the cost of recompiling shared traces per module — correctness is
unaffected because every module builds its own engines/jits, and the
paged trace-closure assertions only compare counts within one module.
"""
import gc
import sys
from pathlib import Path

import pytest

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(Path(__file__).resolve().parent)):
    if p not in sys.path:
        sys.path.insert(0, p)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables_per_module():
    yield
    import jax

    from repro.launch import partition
    from repro.runtime import engine, serving

    for mod in (serving, engine, partition):
        for obj in vars(mod).values():
            if hasattr(obj, "cache_clear"):
                obj.cache_clear()
    # drop tuned tiles too: a module that autotunes must not leak tile
    # choices (or manifest "__tuning_cache__" entries) into the next
    from repro.kernels import ops as _ops

    _ops.tuning_cache().clear()
    jax.clear_caches()
    gc.collect()
