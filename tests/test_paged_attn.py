"""Paged-attention decode kernel + packed prefill acceptance.

Three contracts (see kernels/paged_attn.py and docs/serving.md):

  * the Pallas block-table kernel is **bit-identical** to the gather
    oracle (``paged_attention_reference``) across page sizes, ragged
    cache lengths, empty rows, int8 pools with scale planes, SWA page
    skipping and GQA group widths — so backend dispatch only ever trades
    bytes for bytes, never tokens.
  * the kernel's compiled HLO never materializes the gathered
    ``(B, NB*page, Hkv, dh)`` dequantized KV row — the whole point of
    walking the block table — while the gather oracle's HLO does
    (positive control for the shape probe).
  * packed prefill (several short prompts through one flash call with
    per-segment masking) retires token streams identical to unpacked
    chunked prefill, with strictly fewer prefill dispatches, and stays
    inside the AOT-warmed trace set.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.kernels.autotune import TuningCache, paged_attn_key
from repro.kernels.ops import (paged_attention, paged_attention_reference,
                               tune_paged_attention)
from repro.kernels.paged_attn import (TRASH_PAGE, paged_attention_tpu,
                                      pages_read_per_step)
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.engine import Engine
from repro.runtime.serving import generate


# ---------------------------------------------------------------------------
# fixtures: pools with a pinned all-zero trash page
# ---------------------------------------------------------------------------

def _pools(rng, n_pages, page, hkv, dh, quant):
    """K/V pools with row TRASH_PAGE zeroed (the engine invariant: page 0
    is reserved and never written)."""
    if quant:
        kp = rng.randint(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        vp = rng.randint(-127, 128, (n_pages, page, hkv, dh)).astype(np.int8)
        ks = np.abs(rng.randn(n_pages, page, hkv)).astype(np.float32) * 0.05
        vs = np.abs(rng.randn(n_pages, page, hkv)).astype(np.float32) * 0.05
        kp[TRASH_PAGE] = 0
        vp[TRASH_PAGE] = 0
        ks[TRASH_PAGE] = 0
        vs[TRASH_PAGE] = 0
        return (jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(ks, jnp.bfloat16), jnp.asarray(vs, jnp.bfloat16))
    kp = rng.randn(n_pages, page, hkv, dh).astype(np.float32)
    vp = rng.randn(n_pages, page, hkv, dh).astype(np.float32)
    kp[TRASH_PAGE] = 0
    vp[TRASH_PAGE] = 0
    return jnp.asarray(kp), jnp.asarray(vp), None, None


def _case(seed, B, page, nb, hkv, g, dh, quant):
    """One decode step: ragged cache_len per row (incl. a single-token
    row and an exact page boundary), live block entries distinct, dead
    entries deliberately garbage (they must never leak into the output).
    cache_len >= 1 throughout: decode never runs on an empty row, and a
    fully-masked softmax degenerates to a uniform average of whatever
    the backend staged — garbage either way."""
    rng = np.random.RandomState(seed)
    n_pages = 1 + B * nb
    kp, vp, ks, vs = _pools(rng, n_pages, page, hkv, dh, quant)
    q = jnp.asarray(rng.randn(B, 1, hkv * g, dh), jnp.float32)
    perm = 1 + rng.permutation(n_pages - 1)[:B * nb]
    block = jnp.asarray(perm.reshape(B, nb), jnp.int32)
    cl = np.minimum(
        np.array([1, page - 1, page, nb * page - 3][:B]), nb * page)
    if B > 4:
        cl = np.concatenate([cl, rng.randint(1, nb * page + 1, (B - 4,))])
    return q, kp, vp, block, jnp.asarray(cl, jnp.int32), ks, vs


# ---------------------------------------------------------------------------
# kernel == gather oracle, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page,quant,window,g", [
    (8, False, None, 1),    # MHA-per-kv-head, dense
    (8, False, None, 2),    # GQA
    (8, True, None, 2),     # int8 pools + bf16 scale planes
    (8, False, 12, 2),      # SWA: behind-window pages skipped
    (8, True, 12, 1),       # SWA + int8
    (16, True, None, 2),    # engine page size
    (16, False, 24, 2),     # engine page size + SWA
])
def test_kernel_matches_gather_bitwise(page, quant, window, g):
    q, kp, vp, block, cl, ks, vs = _case(
        seed=page + 7 * g + (13 if quant else 0), B=4, page=page, nb=3,
        hkv=2, g=g, dh=16, quant=quant)
    got = paged_attention_tpu(q, kp, vp, block, cl, window=window,
                              k_scale=ks, v_scale=vs, interpret=True)
    want = paged_attention_reference(q, kp, vp, block, cl, window=window,
                                     k_scale=ks, v_scale=vs)
    assert got.dtype == want.dtype == q.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("quant,window", [
    (False, None), (True, None), (False, 12), (True, 12),
])
def test_multipass_vmem_split_bitwise(quant, window):
    """Forced-tiny VMEM budgets push the kernel onto the multi-pass
    split (phase-A score streaming + phase-B dh-chunked V); every
    chunking the planner can pick stays bit-identical to the gather
    oracle — the whole point of splitting scores/chunks at einsum
    output boundaries instead of chunking the K reduction."""
    from repro.kernels.paged_attn import vmem_plan

    q, kp, vp, block, cl, ks, vs = _case(
        seed=11 + (3 if quant else 0), B=4, page=8, nb=3, hkv=2, g=2,
        dh=16, quant=quant)
    want = paged_attention_reference(q, kp, vp, block, cl, window=window,
                                     k_scale=ks, v_scale=vs)
    seen = set()
    for budget in (None, 2000, 1000, 700, 300):
        plan = vmem_plan(3, 8, 16, 2, quant=quant,
                         kv_itemsize=kp.dtype.itemsize, budget_bytes=budget)
        seen.add((plan["multipass"], plan["dchunk"]))
        got = paged_attention_tpu(q, kp, vp, block, cl, window=window,
                                  k_scale=ks, v_scale=vs, interpret=True,
                                  vmem_budget_bytes=budget)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want),
            err_msg=f"budget={budget} plan={plan}")
    # the sweep must actually exercise both layouts and >1 chunking
    assert (False, 16) in seen and any(m for m, _ in seen)
    assert len({dc for m, dc in seen if m}) > 1


def test_vmem_plan_properties():
    """Planner invariants: default budget keeps test-sized rows single-
    pass; shrinking budgets shrink the chunk monotonically; the chunk
    divides dh, never drops below 2 (width-1 einsums are not bit-stable
    against the oracle), and the multi-pass scratch actually fits the
    budget whenever any >= 2 chunk can."""
    from repro.kernels.paged_attn import vmem_plan

    p = vmem_plan(3, 8, 16, 2, quant=False, kv_itemsize=4)
    assert not p["multipass"]
    last = None
    for budget in (3000, 1500, 800, 400, 200):
        p = vmem_plan(3, 8, 16, 2, quant=False, kv_itemsize=4,
                      budget_bytes=budget)
        assert p["multipass"] and 16 % p["dchunk"] == 0
        assert p["dchunk"] >= 2 and p["nd"] == 16 // p["dchunk"]
        if last is not None:
            assert p["dchunk"] <= last
        last = p["dchunk"]
        fits_any = 4 * 2 * 3 * 8 + 3 * 8 * 2 * 4 <= budget
        if fits_any:
            assert p["multi_bytes"] <= budget


def test_dispatch_backends_agree():
    """ops.paged_attention routes both names to the same tokens-in,
    tokens-out function; "auto" with an empty cache takes the kernel."""
    q, kp, vp, block, cl, ks, vs = _case(
        seed=3, B=4, page=8, nb=2, hkv=1, g=2, dh=16, quant=False)
    outs = [paged_attention(q, kp, vp, block, cl, backend=b, interpret=True)
            for b in ("kernel", "gather", "auto")]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[2]))
    with pytest.raises(ValueError):
        paged_attention(q, kp, vp, block, cl, backend="nope")


_ktpu = jax.jit(functools.partial(paged_attention_tpu, interpret=True))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_dead_block_entries_never_leak(seed):
    """Property: block-table entries at or beyond cache_len are trash —
    pointing them at a poison page full of huge values changes nothing.
    (This is what lets the allocator recycle pages without scrubbing the
    tables of retired rows.)"""
    rng = np.random.RandomState(seed)
    page, nb, B = 8, 3, 2
    kp, vp, _, _ = _pools(rng, 1 + B * nb + 1, page, 1, 8, quant=False)
    poison = kp.shape[0] - 1
    kp = kp.at[poison].set(1e4)
    vp = vp.at[poison].set(-1e4)
    q = jnp.asarray(rng.randn(B, 1, 1, 8), jnp.float32)
    live = 1 + rng.permutation(B * nb).reshape(B, nb)
    cl = rng.randint(0, nb * page + 1, (B,))
    n_live = -(-cl // page)  # pages holding any pos < cache_len
    dead = np.arange(nb)[None, :] >= n_live[:, None]
    clean = np.where(dead, TRASH_PAGE, live).astype(np.int32)
    dirty = np.where(dead, poison, live).astype(np.int32)
    cl = jnp.asarray(cl, jnp.int32)
    a = _ktpu(q, kp, vp, jnp.asarray(clean), cl)
    b = _ktpu(q, kp, vp, jnp.asarray(dirty), cl)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# HLO: the kernel never materializes the gathered KV row
# ---------------------------------------------------------------------------

def test_hlo_never_materializes_kv_row():
    """The dequantized (B, NB*page, Hkv, dh) row is the bandwidth bill
    this kernel exists to avoid: the gather oracle's HLO carries it (as
    int8 gather + bf16 dequant), the kernel's HLO must not."""
    B, page, nb, hkv, dh = 2, 8, 4, 2, 16
    q, kp, vp, block, cl, ks, vs = _case(
        seed=11, B=B, page=page, nb=nb, hkv=hkv, g=2, dh=dh, quant=True)

    def lower(backend):
        def f(q, kp, vp, ks, vs, block, cl):
            return paged_attention(q, kp, vp, block, cl, k_scale=ks,
                                   v_scale=vs, backend=backend,
                                   interpret=True)

        return jax.jit(f).lower(q, kp, vp, ks, vs, block, cl).as_text()

    row = f"{B}x{nb * page}x{hkv}x{dh}"
    gather_txt = lower("gather")
    kernel_txt = lower("kernel")
    # positive control: the probe string is the right spelling
    assert f"tensor<{row}xbf16>" in gather_txt
    assert f"tensor<{row}xbf16>" not in kernel_txt
    assert f"tensor<{row}xi8>" not in kernel_txt


# ---------------------------------------------------------------------------
# bytes model the benches/CI gate on
# ---------------------------------------------------------------------------

def test_pages_read_model():
    # dense: live span ceil(cl/page), +1 trash page when any step is dead
    assert pages_read_per_step(0, 16, 4) == 1
    assert pages_read_per_step(1, 16, 4) == 2
    assert pages_read_per_step(40, 16, 4) == 4
    assert pages_read_per_step(64, 16, 4) == 4
    # SWA: only pages intersecting (cl-window, cl] are live
    assert pages_read_per_step(64, 16, 4, window=16) == 2
    assert pages_read_per_step(60, 16, 4, window=16) == 3
    # the model never exceeds the gather oracle's nb pages (+trash)
    for cl in range(0, 65, 7):
        assert pages_read_per_step(cl, 16, 4) <= 4 + 1
        assert (pages_read_per_step(cl, 16, 4, window=16)
                <= pages_read_per_step(cl, 16, 4))


def test_tune_paged_attention_records_winner():
    tc = TuningCache()
    key, tile, timings = tune_paged_attention(
        batch=2, page=8, pages_per_row=2, hkv=1, dh=8, g=1,
        interpret=True, reps=1, warmup=0, cache=tc)
    assert key == paged_attn_key(8, 2, 1, 8, jnp.float32, interpret=True)
    assert {k.rsplit("/", 1)[1] for k in timings} == {"kernel", "gather"}
    assert tc.get(key) == tile and tile.strategy in ("kernel", "gather")


# ---------------------------------------------------------------------------
# packed prefill: same tokens, fewer dispatches
# ---------------------------------------------------------------------------

def _fp_setup(arch):
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(params, cfg, toks, steps, max_len):
    return np.asarray(
        generate(params, cfg, {"tokens": jnp.asarray(toks[None])},
                 steps=steps, max_len=max_len))[0]


PACK_LENS = [5, 7, 6, 8]


def _run_pack(params, cfg, toks, pack, **ekw):
    eng = Engine(params, cfg, capacity=4, max_len=20, kv_pages=24,
                 page_size=16, prefill_pack=pack, **ekw)
    assert eng.paged and eng.prefill_pack == (pack and cfg.act_bits >= 32)
    traces = eng.paged_trace_counts()
    for i, L in enumerate(PACK_LENS):
        eng.submit(toks[i, :L], max_new=4)
    res = eng.run()
    assert eng.paged_trace_counts() == traces, "packing added jit traces"
    eng.pkv.alloc.check()
    return res, eng.stats()


@pytest.mark.slow
def test_packed_prefill_token_parity_and_fewer_calls():
    """Packing co-admitted prompts into one flash call is invisible in
    the tokens (segment masking + kv-block-aligned bases) and visible in
    the dispatch count: one packed call replaces N chunk calls."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 8),
                                         0, cfg.vocab), np.int32)
    packed, sp = _run_pack(params, cfg, toks, pack=True)
    plain, su = _run_pack(params, cfg, toks, pack=False)
    assert sp["packed_groups"] >= 1 and sp["packed_requests"] >= 2
    assert su["packed_groups"] == 0
    assert (sp["prefill_chunk_calls"] + sp["packed_groups"]
            < su["prefill_chunk_calls"])
    for i, L in enumerate(PACK_LENS):
        np.testing.assert_array_equal(
            packed[i]["tokens"], plain[i]["tokens"],
            err_msg=f"packed request {i} diverged")
        want = _solo(params, cfg, toks[i, :L], 4, 20)
        np.testing.assert_array_equal(packed[i]["tokens"], want,
                                      err_msg=f"solo parity, request {i}")


@pytest.mark.slow
def test_packed_prefill_parity_int8_kv():
    """int8 KV: packed segments quantize at the splice with per-token
    scales, identical to the chunked path. Prefix cache off — hit
    patterns depend on admission order and int8 hydrate is lossy, so
    sharing would compare different roundings, not packing itself."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    cfg = cfg.replace(kv_cache_bits=8)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (4, 8),
                                         0, cfg.vocab), np.int32)
    packed, sp = _run_pack(params, cfg, toks, pack=True, prefix_cache=False)
    plain, _ = _run_pack(params, cfg, toks, pack=False, prefix_cache=False)
    assert sp["packed_groups"] >= 1
    for i in range(len(PACK_LENS)):
        np.testing.assert_array_equal(
            packed[i]["tokens"], plain[i]["tokens"],
            err_msg=f"int8 packed request {i} diverged")


def test_packing_disabled_under_dynamic_act_quant():
    """Dynamic activation fake-quant scales are per-tensor maxima —
    batch-global state that couples co-packed rows — so the engine must
    refuse to pack when act_bits < 32."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    cfg = cfg.replace(act_bits=8)
    eng = Engine(params, cfg, capacity=4, max_len=20, kv_pages=24,
                 page_size=16, prefill_pack=True)
    assert eng.paged and not eng.prefill_pack
