"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import (
    kmeans_stats_ref,
    lutq_gemv_packed_ref,
    lutq_matmul_ref,
    pack4,
    unpack4,
)


def _mk(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape).astype(dtype)


class TestLutqMatmul:
    @pytest.mark.parametrize("M,Kin,N", [(8, 128, 128), (256, 512, 256),
                                         (64, 1024, 512), (128, 256, 384)])
    @pytest.mark.parametrize("K", [4, 16, 256])
    def test_matches_ref(self, M, Kin, N, K):
        x = _mk((M, Kin), 1)
        a = jax.random.randint(jax.random.PRNGKey(2), (Kin, N), 0, K, jnp.int8)
        d = jnp.sort(_mk((K,), 3))
        got = ops.lutq_matmul(x, a, d, bm=min(128, M), bn=128, bk=128,
                              interpret=True)
        want = lutq_matmul_ref(x, a, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = _mk((32, 256), 1, dtype)
        a = jax.random.randint(jax.random.PRNGKey(2), (256, 128), 0, 16, jnp.int8)
        d = jnp.sort(_mk((16,), 3))
        got = ops.lutq_matmul(x, a, d, bm=32, bn=128, bk=128, interpret=True)
        want = lutq_matmul_ref(x, a, d)
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)

    def test_take_decode_path(self):
        from repro.kernels.lutq_matmul import lutq_matmul as raw
        x = _mk((16, 128), 5)
        a = jax.random.randint(jax.random.PRNGKey(6), (128, 128), 0, 16, jnp.int8)
        d = jnp.sort(_mk((16,), 7))
        got = raw(x, a, d, bm=16, bn=128, bk=64, decode_onehot=False,
                  interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(lutq_matmul_ref(x, a, d)),
                                   rtol=1e-5, atol=1e-4)

    @given(st.integers(0, 50))
    @settings(max_examples=8, deadline=None)
    def test_property_random_blocks(self, seed):
        g = np.random.default_rng(seed)
        M = int(g.choice([16, 32, 64]))
        Kin = int(g.choice([128, 256]))
        N = int(g.choice([128, 256]))
        x = _mk((M, Kin), seed)
        a = jax.random.randint(jax.random.PRNGKey(seed), (Kin, N), 0, 16, jnp.int8)
        d = jnp.sort(_mk((16,), seed + 1))
        got = ops.lutq_matmul(x, a, d, bm=16, bn=128, bk=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(lutq_matmul_ref(x, a, d)),
                                   rtol=1e-5, atol=1e-4)


class TestPacking:
    def test_pack_unpack_roundtrip(self):
        a = jax.random.randint(jax.random.PRNGKey(0), (64, 32), 0, 16, jnp.int8)
        np.testing.assert_array_equal(np.asarray(unpack4(pack4(a))), np.asarray(a))


class TestGemvPacked:
    @pytest.mark.parametrize("B,Kin,N", [(1, 256, 256), (8, 512, 128),
                                         (16, 1024, 512)])
    def test_matches_ref(self, B, Kin, N):
        x = _mk((B, Kin), 1)
        a = jax.random.randint(jax.random.PRNGKey(2), (Kin, N), 0, 16, jnp.int8)
        packed = pack4(a)
        d = jnp.sort(_mk((16,), 3))
        got = ops.lutq_gemv_packed(x, packed, d, bn=128, bk=128, interpret=True)
        want = lutq_gemv_packed_ref(x, packed, d)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)
        # and the packed path equals the unpacked decode exactly
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(lutq_matmul_ref(x, a, d)),
                                   rtol=1e-5, atol=1e-4)

    def test_weight_bytes_are_quartered(self):
        Kin, N = 512, 256
        a = jax.random.randint(jax.random.PRNGKey(0), (Kin, N), 0, 16, jnp.int8)
        packed = pack4(a)
        bf16_bytes = Kin * N * 2
        assert packed.size * packed.dtype.itemsize == bf16_bytes // 4


class TestKmeansKernel:
    @pytest.mark.parametrize("N,K", [(4096, 4), (8192, 16), (16384, 256),
                                     (4096, 3)])
    def test_matches_ref(self, N, K):
        w = _mk((N,), 1)
        d = jnp.sort(_mk((K,), 2))
        a, sums, counts = ops.kmeans_stats(w, d, bn=2048, interpret=True)
        a_r, s_r, c_r = kmeans_stats_ref(w, d)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a_r))
        np.testing.assert_allclose(np.asarray(sums), np.asarray(s_r),
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(counts), np.asarray(c_r))

    def test_fused_step_matches_core_kmeans(self):
        from repro.core.lutq import kmeans_update
        from repro.core.spec import QuantSpec
        w = _mk((8192,), 5)
        d0 = jnp.sort(_mk((16,), 6))
        spec = QuantSpec(bits=4, kmeans_iters=1)
        d_core, a_core = kmeans_update(w, d0, spec)
        a_k, d_k = ops.kmeans_step_fused(w, d0, bn=2048, interpret=True)
        np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_core),
                                   rtol=1e-5, atol=1e-5)

    def test_counts_sum_to_n(self):
        w = _mk((4096,), 9)
        d = jnp.sort(_mk((8,), 10))
        _, _, counts = ops.kmeans_stats(w, d, bn=1024, interpret=True)
        assert float(counts.sum()) == 4096.0
