"""Hypothesis property tests: chunked flash attention == dense oracle
across random shapes, windows, prefixes, and GQA ratios."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.nn.attention import dense_attention, decode_attention, flash_attention


@given(
    seed=st.integers(0, 1000),
    s=st.sampled_from([17, 32, 48, 96]),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    window=st.sampled_from([None, 8, 24]),
    qb=st.sampled_from([16, 32]),
)
@settings(max_examples=25, deadline=None)
def test_property_flash_equals_dense(seed, s, hkv, g, window, qb):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, Dh = 2, 8
    q = jax.random.normal(ks[0], (B, s, hkv * g, Dh))
    k = jax.random.normal(ks[1], (B, s, hkv, Dh))
    v = jax.random.normal(ks[2], (B, s, hkv, Dh))
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_block=qb, kv_block=16)
    want = dense_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 500), prefix=st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_property_prefix_bidirectional(seed, prefix):
    """VLM prefix mask: prefix tokens attend bidirectionally."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, Dh = 1, 32, 2, 8
    q, k, v = (jax.random.normal(ks[i], (B, S, H, Dh)) for i in range(3))
    got = flash_attention(q, k, v, causal=True, prefix=prefix,
                          q_block=16, kv_block=16)
    want = dense_attention(q, k, v, causal=True, prefix=prefix)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # the first prefix query must actually see a later prefix key:
    # zeroing a later prefix value must change its output
    v2 = v.at[:, prefix - 1].set(0.0)
    out2 = dense_attention(q, k, v2, causal=True, prefix=prefix)
    assert not np.allclose(np.asarray(want[:, 0]), np.asarray(out2[:, 0]))


@given(seed=st.integers(0, 500), cache_len=st.sampled_from([5, 16, 31]))
@settings(max_examples=10, deadline=None)
def test_property_decode_is_last_row_of_dense(seed, cache_len):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, Hkv, G, Dh = 2, 32, 2, 2, 8
    q = jax.random.normal(ks[0], (B, S, Hkv * G, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    want = dense_attention(q[:, :cache_len], k[:, :cache_len],
                           v[:, :cache_len], causal=True)[:, -1:]
    got = decode_attention(q[:, cache_len - 1:cache_len], k, v,
                           jnp.full((B,), cache_len))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
