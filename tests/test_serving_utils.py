"""Tests for the serving bridge: ring conversion, cache growth, generate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.serving import adapt_prefill_cache, generate, ring_from_linear


class TestRingConversion:
    def test_ring_layout_matches_positions(self):
        B, S, D = 1, 10, 2
        lin = jnp.arange(B * S * D, dtype=jnp.float32).reshape(B, S, D)
        window = 4
        ring = ring_from_linear(lin, prompt_len=10, window=window)
        # live positions 6..9 -> slots 6%4=2, 7%4=3, 8%4=0, 9%4=1
        np.testing.assert_array_equal(np.asarray(ring[0, 2]), np.asarray(lin[0, 6]))
        np.testing.assert_array_equal(np.asarray(ring[0, 0]), np.asarray(lin[0, 8]))
        np.testing.assert_array_equal(np.asarray(ring[0, 1]), np.asarray(lin[0, 9]))

    def test_short_prompt_keeps_all(self):
        lin = jnp.arange(6, dtype=jnp.float32).reshape(1, 6, 1)
        ring = ring_from_linear(lin, prompt_len=3, window=4)
        np.testing.assert_array_equal(np.asarray(ring[0, :3, 0]), [0, 1, 2])

    def test_per_stream_lengths(self):
        """Ragged batches relay each stream at its own length — the
        ISSUE-3 bug was collapsing every stream to len[0]."""
        B, S, D, W = 3, 10, 2, 4
        lin = jnp.arange(B * S * D, dtype=jnp.float32).reshape(B, S, D)
        ring = ring_from_linear(lin, jnp.array([10, 3, 6]), W)
        # stream 0: positions 6..9 in slots 2,3,0,1
        np.testing.assert_array_equal(np.asarray(ring[0, 0]), np.asarray(lin[0, 8]))
        np.testing.assert_array_equal(np.asarray(ring[0, 2]), np.asarray(lin[0, 6]))
        # stream 1: only 3 live positions, slot 3 empty
        np.testing.assert_array_equal(np.asarray(ring[1, :3]), np.asarray(lin[1, :3]))
        assert (np.asarray(ring[1, 3]) == 0).all()
        # stream 2: positions 2..5 in slots 2,3,0,1
        np.testing.assert_array_equal(np.asarray(ring[2, 0]), np.asarray(lin[2, 4]))
        np.testing.assert_array_equal(np.asarray(ring[2, 3]), np.asarray(lin[2, 3]))

    def test_traces_without_host_sync(self):
        """The relay must run under jit (the engine's admission splice
        composes it) — a host sync inside would fail tracing."""
        lin = jnp.arange(16, dtype=jnp.float32).reshape(1, 8, 2)
        out = jax.jit(lambda x, n: ring_from_linear(x, n, 4))(
            lin, jnp.array([5]))
        np.testing.assert_array_equal(
            np.asarray(out[0, 0]), np.asarray(lin[0, 4]))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mistral-nemo-12b",
                                  "rwkv6-1.6b", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_generate_continues_prefill_exactly(arch):
    """Tokens produced by prefill+decode == tokens from repeated full
    forwards (greedy) — the strongest end-to-end serving correctness
    check, including the SWA ring conversion."""
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    B, P, G = 2, 12, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    got = generate(params, cfg, {"tokens": toks}, steps=G, max_len=P + G)

    # oracle: re-run full prefill each step (teacher-forcing growth)
    cur = toks
    want = []
    for _ in range(G):
        logits, _ = api.prefill(params, cfg, {"tokens": cur}, max_len=P + G)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        want.append(nxt)
        cur = jnp.concatenate([cur, nxt], axis=1)
    want = jnp.concatenate(want, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("arch", [
    "h2o-danube-1.8b",                                     # SWA ring relay
    "mistral-nemo-12b",                                    # dense, no window
    pytest.param("deepseek-v2-lite-16b",                   # MLA + MoE (routed
                 marks=pytest.mark.slow)])                 # via admission)
def test_generate_ragged_batch_matches_solo(arch):
    """ISSUE-3 bugfix: a right-padded mixed-length batch must decode
    every stream from its own last real token — before the fix, logits
    came from `logits[:, -1]` (padding) and the SWA ring was laid out
    with `len[0]` for all streams."""
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    P, G = 12, 4
    lens = [7, 12, 4]
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, P),
                                         0, cfg.vocab), np.int32)
    padded = np.zeros((3, P), np.int32)
    for i, L in enumerate(lens):
        padded[i, :L] = toks[i, :L]
    rag = np.asarray(generate(params, cfg, {"tokens": jnp.asarray(padded)},
                              steps=G, lengths=lens, max_len=P + G))
    for i, L in enumerate(lens):
        solo = np.asarray(generate(
            params, cfg, {"tokens": jnp.asarray(toks[i:i + 1, :L])},
            steps=G, max_len=P + G))[0]
        np.testing.assert_array_equal(rag[i], solo,
                                      err_msg=f"{arch} stream {i} (len {L})")


def test_adapt_prefill_cache_quantizes_int8_kv():
    """kv_cache_bits=8 through the real prefill path: adaptation must
    emit int8 K/V + scales matching the decode cache structure (it used
    to crash on a tree-structure mismatch)."""
    cfg = reduced(get_config("h2o-danube-1.8b")).replace(
        quant=None, act_bits=32, remat=False, kv_cache_bits=8)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, cfg.vocab)
    _, cache = api.prefill(params, cfg, {"tokens": toks})
    adapted = adapt_prefill_cache(cfg, cache, 2, 16)
    assert adapted["layers"]["k"].dtype == jnp.int8
    assert "k_scale" in adapted["layers"]
    gen = np.asarray(generate(params, cfg, {"tokens": toks}, steps=5,
                              max_len=16))
    assert gen.shape == (2, 5) and (gen >= 0).all() and (gen < cfg.vocab).all()
