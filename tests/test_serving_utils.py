"""Tests for the serving bridge: ring conversion, cache growth, generate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.serving import adapt_prefill_cache, generate, ring_from_linear


class TestRingConversion:
    def test_ring_layout_matches_positions(self):
        B, S, D = 1, 10, 2
        lin = jnp.arange(B * S * D, dtype=jnp.float32).reshape(B, S, D)
        window = 4
        ring = ring_from_linear(lin, prompt_len=10, window=window)
        # live positions 6..9 -> slots 6%4=2, 7%4=3, 8%4=0, 9%4=1
        np.testing.assert_array_equal(np.asarray(ring[0, 2]), np.asarray(lin[0, 6]))
        np.testing.assert_array_equal(np.asarray(ring[0, 0]), np.asarray(lin[0, 8]))
        np.testing.assert_array_equal(np.asarray(ring[0, 1]), np.asarray(lin[0, 9]))

    def test_short_prompt_keeps_all(self):
        lin = jnp.arange(6, dtype=jnp.float32).reshape(1, 6, 1)
        ring = ring_from_linear(lin, prompt_len=3, window=4)
        np.testing.assert_array_equal(np.asarray(ring[0, :3, 0]), [0, 1, 2])


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "mistral-nemo-12b",
                                  "rwkv6-1.6b", "zamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_generate_continues_prefill_exactly(arch):
    """Tokens produced by prefill+decode == tokens from repeated full
    forwards (greedy) — the strongest end-to-end serving correctness
    check, including the SWA ring conversion."""
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    B, P, G = 2, 12, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    got = generate(params, cfg, {"tokens": toks}, steps=G, max_len=P + G)

    # oracle: re-run full prefill each step (teacher-forcing growth)
    cur = toks
    want = []
    for _ in range(G):
        logits, _ = api.prefill(params, cfg, {"tokens": cur}, max_len=P + G)
        nxt = jnp.argmax(logits[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        want.append(nxt)
        cur = jnp.concatenate([cur, nxt], axis=1)
    want = jnp.concatenate(want, axis=1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
