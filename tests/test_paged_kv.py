"""Paged KV subsystem: allocator / prefix-trie properties + paged parity.

Two layers (see runtime/paged_kv.py and docs/serving.md):

  * host bookkeeping properties (hypothesis): the PageAllocator never
    double-frees and keeps refcounts balanced through random
    admit/retire interleavings; prefix-cache eviction never frees a
    page a live slot still references; copy-on-write ``fork_page``
    diverges shared pages and no-ops for sole holders.
  * the serving contract: requests through a paged engine — chunked
    bucketed prefill, block-table decode, prefix sharing, deferred
    admission under page pressure — produce tokens **identical** to a
    solo batch=1 ``generate``, across dense/SWA/GQA and encdec and the
    decode|fused|packed4 kernel backends; and serving hits only
    AOT-warmed jit traces (the trace set is closed at engine start).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime import paged_kv
from repro.runtime.engine import Engine
from repro.runtime.serving import generate

# ---------------------------------------------------------------------------
# chunk schedule
# ---------------------------------------------------------------------------


@given(st.integers(1, 400), st.integers(0, 399),
       st.sampled_from([16, 32, 64, 128]))
@settings(max_examples=200, deadline=None)
def test_chunk_plan_covers_exactly(length, start, max_chunk):
    if start >= length:
        start = 0
    plan = paged_kv.chunk_plan(length, start, max_chunk)
    buckets = set(paged_kv.prefill_buckets(max_chunk))
    pos = start
    for s, width, n_real in plan:
        assert s == pos and 1 <= n_real <= width
        assert width in buckets, (width, buckets)
        pos += n_real
    assert pos == length
    # every chunk fits the workspace envelope regardless of geometry
    for s, width, _ in plan:
        assert s + width <= 2 * paged_kv.next_pow2(length)


def test_workspace_len_covers_padded_tail():
    # regression: start=32, rem=90 pads to 128 -> start+width=160 > 128,
    # so a single next_pow2(max_len) workspace would overflow
    plan = paged_kv.chunk_plan(122, 32, 32)
    wws = paged_kv.workspace_len(122, -(-122 // 16), 16)
    assert all(s + w <= wws for s, w, _ in plan)


# ---------------------------------------------------------------------------
# allocator properties
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7)),
                min_size=1, max_size=200))
@settings(max_examples=100, deadline=None)
def test_allocator_refcount_balance(ops):
    """Random alloc/retain/release interleavings keep the free-list and
    refcounts consistent, and releasing everything returns every page."""
    alloc = paged_kv.PageAllocator(16)
    held = []  # one entry per outstanding reference
    for op, arg in ops:
        if op == 0:
            got = alloc.alloc(arg % 4)
            if got is not None:
                held.extend(got)
        elif op == 1 and held:
            p = held[arg % len(held)]
            alloc.retain(p)
            held.append(p)
        elif op == 2 and held:
            alloc.release(held.pop(arg % len(held)))
        alloc.check()
    for p in held:
        alloc.release(p)
    alloc.check()
    assert alloc.n_free == 15 and alloc.pages_in_use == 0


def test_allocator_double_free_and_trash_pinned():
    alloc = paged_kv.PageAllocator(4)
    (p,) = alloc.alloc(1)
    assert alloc.release(p) is True
    with pytest.raises(AssertionError):
        alloc.release(p)  # double free
    assert alloc.release(paged_kv.TRASH_PAGE) is False  # pinned forever
    assert alloc.alloc(99) is None  # all-or-nothing, no partial grab
    alloc.check()


def test_fork_page_cow_semantics():
    alloc = paged_kv.PageAllocator(8)
    (p,) = alloc.alloc(1)
    # sole holder: no copy needed, same page comes back
    assert alloc.fork_page(p) == p
    alloc.retain(p)  # second holder -> fork must diverge
    q = alloc.fork_page(p)
    assert q != p and alloc.refs[q] == 1 and alloc.refs[p] == 1
    alloc.check()
    # shortfall: fork fails cleanly without dropping the shared ref
    alloc2 = paged_kv.PageAllocator(2)
    (r,) = alloc2.alloc(1)
    alloc2.retain(r)
    assert alloc2.fork_page(r) is None
    assert alloc2.refs[r] == 2
    alloc2.check()


# ---------------------------------------------------------------------------
# prefix cache + PagedKV bookkeeping properties
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 99)),
                min_size=1, max_size=60), st.integers(6, 14))
@settings(max_examples=60, deadline=None)
def test_pagedkv_admit_retire_balance(ops, n_pages):
    """Random admit/retire interleavings with prefix sharing: refcounts
    stay consistent throughout; releasing every slot and clearing the
    cache returns the whole pool."""
    rng = np.random.default_rng(0)
    pkv = paged_kv.PagedKV(n_pages, 4, 8, capacity=4)
    prompts = [list(rng.integers(0, 3, size=rng.integers(2, 14)))
               for _ in range(6)]
    for op, arg in ops:
        slot = arg % 4
        if op == 0 and pkv.rows[slot] is None:
            toks = prompts[arg % len(prompts)]
            got = pkv.admit(slot, toks, len(toks) + 2)
            if got is not None:
                pkv.insert_prefix(slot, toks)
        elif op == 1:
            pkv.release_slot(slot)
        pkv.alloc.check()
        # eviction (inside admit) must never free a page a slot holds
        for row in pkv.rows:
            for p in row or []:
                assert pkv.alloc.refs[p] > 0
    for slot in range(4):
        pkv.release_slot(slot)
    if pkv.prefix is not None:
        pkv.prefix.clear()
    pkv.alloc.check()
    assert pkv.alloc.pages_in_use == 0


def test_fuzz_admit_retire_sweep_without_hypothesis():
    """Deterministic randomized sweep of the same invariants the
    hypothesis properties pin, so they are exercised even where
    hypothesis is not installed (see tests/hypothesis_compat.py)."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        n_pages = int(rng.integers(6, 15))
        pkv = paged_kv.PagedKV(n_pages, 4, 8, capacity=4)
        prompts = [list(rng.integers(0, 3, size=int(rng.integers(2, 14))))
                   for _ in range(6)]
        for _ in range(40):
            op, slot = int(rng.integers(0, 2)), int(rng.integers(0, 4))
            if op == 0 and pkv.rows[slot] is None:
                toks = prompts[int(rng.integers(0, len(prompts)))]
                if pkv.admit(slot, toks, len(toks) + 2) is not None:
                    pkv.insert_prefix(slot, toks)
            else:
                pkv.release_slot(slot)
            pkv.alloc.check()
            for row in pkv.rows:
                for p in row or []:
                    assert pkv.alloc.refs[p] > 0
        for slot in range(4):
            pkv.release_slot(slot)
        pkv.prefix.clear()
        pkv.alloc.check()
        assert pkv.alloc.pages_in_use == 0, trial


def test_eviction_never_frees_referenced_page():
    """A slot holds pages the prefix cache also holds; evicting the
    whole trie must only drop the cache's share — the slot's pages stay
    allocated and intact."""
    pkv = paged_kv.PagedKV(8, 4, 4, capacity=2)
    toks = list(range(8))  # two full pages
    row, hit = pkv.admit(0, toks, 8)
    assert hit == 0
    pkv.insert_prefix(0, toks)
    held = list(pkv.rows[0])
    pkv.prefix.evict(10 ** 9)  # force-evict everything evictable
    for p in held:
        assert pkv.alloc.refs[p] > 0  # slot's refs survived
    pkv.release_slot(0)
    pkv.alloc.check()


def test_prefix_match_never_serves_last_prompt_page():
    """The page holding the last prompt token must be recomputed (its
    logits seed sampling), so a full-prompt cache hit is capped."""
    pkv = paged_kv.PagedKV(16, 4, 4, capacity=2)
    toks = list(range(8))
    pkv.admit(0, toks, 8)
    pkv.insert_prefix(0, toks)
    _, hit = pkv.admit(1, toks, 8)  # identical prompt
    # 8 tokens / page 4 -> 2 full pages, but the hit stops at page 1
    assert hit == 4
    pkv.release_slot(0), pkv.release_slot(1)
    pkv.alloc.check()


# ---------------------------------------------------------------------------
# paged-vs-slot serving parity
# ---------------------------------------------------------------------------


def _fp_setup(arch):
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(params, cfg, toks, steps, max_len, **kw):
    return np.asarray(
        generate(params, cfg, {"tokens": jnp.asarray(toks[None])},
                 steps=steps, max_len=max_len, **kw))[0]


LENS = [6, 14, 9, 11]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b",    # GQA + SWA
                                  "mistral-nemo-12b"])  # GQA, no window
def test_paged_parity_and_trace_closure(arch):
    """Paged engine == solo generate, token-identical, with slot churn
    and mid-flight admission — and serving compiles nothing after the
    AOT warmup (all prefill shapes land on warmed buckets)."""
    cfg, params = _fp_setup(arch)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 14),
                                         0, cfg.vocab), np.int32)
    G, max_len = 4, 20
    eng = Engine(params, cfg, capacity=2, max_len=max_len,
                 kv_pages=12, page_size=16)
    assert eng.paged
    traces = eng.paged_trace_counts()
    for i, L in enumerate(LENS):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    assert eng.paged_trace_counts() == traces, "serving added jit traces"
    for i, L in enumerate(LENS):
        want = _solo(params, cfg, toks[i, :L], G, max_len)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"{arch} request {i}")
    eng.pkv.alloc.check()


@pytest.mark.slow
def test_paged_parity_int8_kv():
    """int8 KV pages: chunked prefill runs in an fp workspace and
    quantizes at the splice — exactly where the slot path quantizes —
    so int8 paged serving stays token-identical to int8 solo."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    cfg = cfg.replace(kv_cache_bits=8)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 14),
                                         0, cfg.vocab), np.int32)
    G, max_len = 4, 20
    eng = Engine(params, cfg, capacity=2, max_len=max_len,
                 kv_pages=12, page_size=16)
    for i, L in enumerate(LENS):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    for i, L in enumerate(LENS):
        want = _solo(params, cfg, toks[i, :L], G, max_len)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"int8 request {i}")


@pytest.mark.slow
def test_paged_parity_encdec():
    """encdec pages its growing self-attn KV (fp pages — the slot path
    never quantizes encdec); cross-attn memory stays dense per slot."""
    cfg, params = _fp_setup("seamless-m4t-medium")
    rng = jax.random.PRNGKey(7)
    frames = [np.asarray(jax.random.normal(jax.random.fold_in(rng, i),
                                           (13, cfg.d_model)), np.float32)
              for i in range(3)]
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 12),
                                         0, cfg.vocab), np.int32)
    lens, G, max_len = [5, 12, 8], 4, 18
    eng = Engine(params, cfg, capacity=2, max_len=max_len, src_len=13,
                 kv_pages=12, page_size=16)
    assert eng.paged
    traces = eng.paged_trace_counts()
    for i, L in enumerate(lens):
        eng.submit(toks[i, :L], max_new=G, frames=frames[i])
    res = eng.run()
    assert eng.paged_trace_counts() == traces
    for i, L in enumerate(lens):
        want = np.asarray(generate(
            params, cfg, {"tokens": jnp.asarray(toks[i:i + 1, :L]),
                          "frames": jnp.asarray(frames[i][None])},
            steps=G, max_len=max_len))[0]
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"encdec request {i}")


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["decode", "fused", "packed4"])
def test_paged_parity_kernel_backends(backend):
    """Parity holds on serve-form LUT-Q weights through every kernel
    execution backend — the deployment configuration."""
    cfg = reduced(get_config("h2o-danube-1.8b")).replace(
        quant=QuantSpec(bits=4, min_size=256), act_bits=8, remat=False)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    sv = serve_view(api.quantize(params, cfg, axes), pack4=backend == "packed4",
                    policy=api.resolved_policy(cfg))
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 14),
                                         0, cfg.vocab), np.int32)
    lens, G, max_len = [6, 14, 9], 4, 20
    eng = Engine(sv, cfg, capacity=2, max_len=max_len, backend=backend,
                 kv_pages=12, page_size=16)
    assert eng.paged and eng.stats()["backend"] == backend
    for i, L in enumerate(lens):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    for i, L in enumerate(lens):
        want = _solo(sv, cfg, toks[i, :L], G, max_len, backend=backend)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"{backend} request {i}")


@pytest.mark.slow
def test_paged_overbudget_demand_completes_exactly():
    """The ISSUE acceptance workload: summed prompt+max_new KV demand
    exceeds what capacity x max_len slot caches could ever hold at once
    relative to the pool — requests defer under page pressure and every
    one still completes token-identical to solo."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    G, max_len = 6, 32
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (8, 20),
                                         0, cfg.vocab), np.int32)
    lens = [18, 9, 14, 20, 7, 16, 11, 13]
    # 7 allocatable pages x 8 tokens vs ~150 tokens of summed demand
    eng = Engine(params, cfg, capacity=4, max_len=max_len,
                 kv_pages=8, page_size=8)
    assert sum(L + G for L in lens) > (eng.n_pages - 1) * eng.page_size
    for i, L in enumerate(lens):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    assert [r["rid"] for r in res] == list(range(8))  # FIFO preserved
    for i, L in enumerate(lens):
        want = _solo(params, cfg, toks[i, :L], G, max_len)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"deferred request {i}")
    eng.pkv.alloc.check()
    assert eng.stats()["pages_peak"] <= eng.n_pages - 1


@pytest.mark.slow
def test_paged_prefix_sharing_parity_and_hits():
    """Shared system prompts map the same physical pages: the second+
    requests hit the prefix cache (hit rate > 0) and still decode
    token-identical to solo runs."""
    cfg, params = _fp_setup("mistral-nemo-12b")
    G = 4
    sys_p = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (18,),
                                          0, cfg.vocab), np.int32)
    prompts = [np.concatenate([sys_p, np.asarray(
        jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(4), i),
                           (4,), 0, cfg.vocab), np.int32)]) for i in range(3)]
    eng = Engine(params, cfg, capacity=2, max_len=32,
                 kv_pages=16, page_size=8)
    for p in prompts:
        eng.submit(p, max_new=G)
    res = eng.run()
    st = eng.stats()
    assert st["prefix_hits"] > 0 and st["prefix_hit_rate"] > 0
    for i, p in enumerate(prompts):
        want = _solo(params, cfg, p, G, 32)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"shared-prefix request {i}")


@pytest.mark.slow
def test_paged_swa_behind_window_release():
    """Sliding-window decode frees pages behind the window; the
    allocator stays consistent and generation runs to completion."""
    cfg, params = _fp_setup("h2o-danube-1.8b")
    assert cfg.window is not None
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 30),
                                         0, cfg.vocab), np.int32)
    eng = Engine(params, cfg, capacity=2, max_len=48, kv_pages=20,
                 page_size=8)
    for i in range(2):
        eng.submit(toks[i], max_new=12)
    saw_freed = False
    while not eng.idle:
        eng.step()
        eng.pkv.alloc.check()
        # a live slot's early blocks turn into trash entries once its
        # length passes window + page_size (lengths reach 42 > 24+8)
        saw_freed = saw_freed or any(
            row is not None and paged_kv.TRASH_PAGE in row
            for row in eng.pkv.rows)
    assert saw_freed, "no page was released behind the window"
    res = [eng.results[rid] for rid in sorted(eng.results)]
    assert all(r["n_new"] == 12 for r in res)
    eng.pkv.alloc.check()


def test_unsupported_family_falls_back_to_slot_path():
    """ssm/hybrid/MLA keep the slot pool behind the same Engine API."""
    cfg, params = _fp_setup("rwkv6-1.6b")
    eng = Engine(params, cfg, capacity=2, max_len=16, kv_pages=8)
    assert not eng.paged and not eng.stats()["paged"]
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, 6),
                                         0, cfg.vocab), np.int32)
    eng.submit(toks[0], max_new=3)
    res = eng.run()
    want = _solo(params, cfg, toks[0], 3, 16)
    np.testing.assert_array_equal(res[0]["tokens"], want)


def test_paged_submit_rejects_impossible_reservation():
    cfg, params = _fp_setup("mistral-nemo-12b")
    eng = Engine(params, cfg, capacity=2, max_len=32, kv_pages=3,
                 page_size=8, warmup=False)
    with pytest.raises(ValueError):
        # needs 3 pages; pool only has 2 allocatable (page 0 is trash)
        eng.submit(np.arange(20, dtype=np.int32), max_new=4)
