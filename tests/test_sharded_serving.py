"""Sharded serving: tensor/data-parallel LUT-Q inference end-to-end.

Pins the PR-4 acceptance contract on a forced multi-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
``tier1-sharded`` job):

  * ``Engine`` + ``generate`` on a 2x4 ("data", "model") mesh are
    **token-identical** to single-device for lm, encdec and moe archs
    through the decode, fused and packed4 backends;
  * no dense weight materialization on any device — quantized leaves
    stay dictionary + index *shards*;
  * ``lutq_dot_spmd`` runs the fused Pallas kernels on local index
    shards under shard_map (N/K/transposed/expert-stacked layouts);
  * serve pspecs respect the packed4 row-pair axis in the divisibility
    fallback and replicate dictionaries;
  * checkpoint restore places leaves directly onto NamedShardings and
    manifests record the save-time mesh;
  * the serving jit lru-caches key on mesh identity (no stale traces
    when one process switches meshes).

Everything here skips on a single-device process (plain tier-1 runs).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.lutq import LutqState, init_state
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.kernels.ops import lutq_dot, lutq_dot_spmd
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.serving import generate

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.slow,
    pytest.mark.skipif(
        len(jax.devices()) < 8,
        reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"),
]

ARCHS = {
    "lm": "mistral-nemo-12b",
    "encdec": "seamless-m4t-medium",
    "moe": "qwen3-moe-235b-a22b",
}


@functools.lru_cache(maxsize=None)
def _mesh():
    return make_host_mesh(2, 4)


@functools.lru_cache(maxsize=None)
def _serve_tree(arch: str, pack: bool):
    cfg = reduced(get_config(arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    qparams = api.quantize(params, cfg, axes)
    sv = serve_view(qparams, pack4=pack, policy=api.resolved_policy(cfg))
    # freeze axes as a hashable-safe capture (plain dict tree)
    return cfg, sv, axes


def _sharded(arch: str, pack: bool):
    from repro.distributed.sharding import shard_serve_params

    cfg, sv, axes = _serve_tree(arch, pack)
    sh, pspecs = shard_serve_params(sv, axes, _mesh())
    return cfg, sv, sh, axes, pspecs


def _batch(cfg, B, Pl):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, Pl), 0,
                                          cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, Pl, cfg.d_model), cfg.dtype)
    return batch


# ---------------------------------------------------------------------------
# acceptance: generate parity, 2x4 mesh vs single device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(ARCHS))
@pytest.mark.parametrize("backend", ["decode", "fused", "packed4"])
class TestGenerateParity:
    def test_token_identical(self, family, backend):
        arch = ARCHS[family]
        pack = backend == "packed4"
        cfg, sv, sh, _, _ = _sharded(arch, pack)
        cfg = cfg.replace(kernel_backend=backend)
        B, Pl, steps = 4, 12, 6
        batch = _batch(cfg, B, Pl)
        solo = generate(sv, cfg, batch, steps=steps)
        mesh = generate(sh, cfg, batch, steps=steps, mesh=_mesh())
        assert bool(jnp.all(solo == mesh)), (
            f"{arch}/{backend}: sharded generate diverged from solo")


def test_generate_parity_temperature():
    """Per-slot rng chains are placement-independent: sampled streams
    match solo at temperature > 0 too."""
    cfg, sv, sh, _, _ = _sharded(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    batch = _batch(cfg, 4, 12)
    rng = jax.random.PRNGKey(7)
    solo = generate(sv, cfg, batch, steps=6, temperature=0.8, rng=rng)
    mesh = generate(sh, cfg, batch, steps=6, temperature=0.8, rng=rng,
                    mesh=_mesh())
    assert bool(jnp.all(solo == mesh))


def test_generate_parity_ragged_lengths():
    cfg, sv, sh, _, _ = _sharded(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    batch = _batch(cfg, 4, 12)
    lengths = np.array([12, 7, 9, 3], np.int32)
    solo = generate(sv, cfg, batch, steps=6, lengths=lengths)
    mesh = generate(sh, cfg, batch, steps=6, lengths=lengths, mesh=_mesh())
    assert bool(jnp.all(solo == mesh))


# ---------------------------------------------------------------------------
# acceptance: continuous-batching engine parity on the mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,backend", [
    ("lm", "fused"), ("moe", "fused"), ("lm", "packed4"), ("encdec", "fused"),
])
def test_engine_ragged_parity(family, backend):
    """A ragged FIFO queue served by the meshed slot pool retires every
    request with exactly the tokens the single-device engine produces."""
    from repro.runtime.engine import Engine, synthetic_requests

    arch = ARCHS[family]
    pack = backend == "packed4"
    cfg, sv, sh, _, _ = _sharded(arch, pack)
    cfg = cfg.replace(kernel_backend=backend)
    src_len = 10 if cfg.family == "encdec" else 0
    reqs = synthetic_requests(cfg, 6, max_prompt=10, max_new=6, seed=3,
                              src_len=src_len)

    def run(params, mesh):
        eng = Engine(params, cfg, capacity=3, max_len=16, src_len=src_len,
                     rng=jax.random.PRNGKey(0), mesh=mesh)
        for r in reqs:
            r = dict(r)
            r.pop("arrival_s")
            eng.submit(**r)
        return eng.run()

    solo = run(sv, None)
    mesh = run(sh, _mesh())
    assert len(solo) == len(mesh) == 6
    for a, b in zip(solo, mesh):
        assert a["rid"] == b["rid"] and a["finish"] == b["finish"]
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_engine_paged_parity():
    """The paged engine (block-table decode + chunked prefill) on the
    mesh retires tokens identical to the single-device paged engine.
    With 1 reduced KV head the Hkv axis does not divide "model"=4, so
    decode takes the GSPMD-partitioned gather oracle — the dispatch
    contract, not a weaker fallback."""
    from repro.runtime.engine import Engine, synthetic_requests

    cfg, sv, sh, _, _ = _sharded(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    reqs = synthetic_requests(cfg, 6, max_prompt=10, max_new=6, seed=3)

    def run(params, mesh):
        eng = Engine(params, cfg, capacity=3, max_len=16, kv_pages=14,
                     page_size=8, rng=jax.random.PRNGKey(0), mesh=mesh,
                     backend="fused")
        assert eng.paged
        for r in reqs:
            r = dict(r)
            r.pop("arrival_s")
            eng.submit(**r)
        return eng.run()

    solo = run(sv, None)
    mesh = run(sh, _mesh())
    assert len(solo) == len(mesh) == 6
    for a, b in zip(solo, mesh):
        assert a["rid"] == b["rid"]
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_paged_attention_shardmap_matches_reference():
    """When Hkv divides "model", the block-table kernel runs shard-local
    under shard_map — bit-identical to the gather oracle that GSPMD
    partitions on its own."""
    from repro.kernels import ops

    rng = np.random.RandomState(0)
    B, page, NB, hkv, g, dh = 4, 8, 3, 4, 2, 16
    n_pages = 1 + B * NB
    kp = rng.randn(n_pages, page, hkv, dh).astype(np.float32)
    vp = rng.randn(n_pages, page, hkv, dh).astype(np.float32)
    kp[0] = 0
    vp[0] = 0
    kp, vp = jnp.asarray(kp), jnp.asarray(vp)
    q = jnp.asarray(rng.randn(B, 1, hkv * g, dh), jnp.float32)
    blk = jnp.asarray(
        1 + rng.permutation(B * NB).reshape(B, NB), jnp.int32)
    cl = jnp.asarray(rng.randint(1, NB * page + 1, (B,)), jnp.int32)
    got = ops.paged_attention(q, kp, vp, blk, cl, backend="kernel",
                              interpret=True, mesh=_mesh())
    want = ops.paged_attention(q, kp, vp, blk, cl, backend="gather")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# acceptance: no dense weight materialization on any device
# ---------------------------------------------------------------------------

def test_no_dense_materialize_and_real_shards(monkeypatch):
    """Fused serving on the mesh decodes nothing but the embedding
    gather, and each device holds an index *shard*, not the full
    assignment tensor."""
    import repro.kernels.ops as ops_mod
    import repro.nn.linear as lin_mod
    from repro.core.lutq import decode_any

    calls = []
    real = decode_any

    def counting(d, a):
        calls.append(d.shape)
        return real(d, a)

    monkeypatch.setattr(lin_mod, "decode_any", counting)
    monkeypatch.setattr(ops_mod, "decode_any", counting)

    cfg, _, sh, _, pspecs = _sharded(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    calls.clear()
    api.prefill(sh, cfg, _batch(cfg, 4, 12))
    assert len(calls) == 1, calls  # the embedding gather only

    # at least one quantized leaf is genuinely partitioned: its
    # per-device shard is a strict subset of the global index tensor
    found = 0
    for leaf in jax.tree.leaves(sh, is_leaf=lambda x: isinstance(x, LutqState)):
        if not isinstance(leaf, LutqState):
            continue
        shards = leaf.a.addressable_shards
        if shards[0].data.size < leaf.a.size:
            found += 1
            assert len({s.device for s in shards}) == 8
    assert found >= 2, "expected model-sharded assignment leaves"


# ---------------------------------------------------------------------------
# shard_map kernel path
# ---------------------------------------------------------------------------

class TestLutqDotSpmd:
    def _leaf(self, shape, pack=False):
        w = jax.random.normal(jax.random.PRNGKey(0), shape)
        return serve_view({"k": init_state(w, QuantSpec(bits=4))},
                          pack4=pack)["k"]

    def test_n_sharded_bit_exact(self):
        sv = self._leaf((32, 64))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="fused")
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P(None, "model"),
                          backend="fused")
        assert bool(jnp.all(y == ref))

    def test_k_sharded_psum(self):
        sv = self._leaf((32, 64))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="fused")
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P("model", None),
                          backend="fused")
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_batch_and_n_sharded(self):
        sv = self._leaf((32, 64))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="fused")
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P(None, "model"),
                          x_spec=P("data", None), backend="fused")
        assert bool(jnp.all(y == ref))

    def test_transposed_tied_logits(self):
        sv = self._leaf((64, 32))  # (vocab, d_model) table layout
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="fused", transpose_rhs=True)
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P("model", None),
                          transpose_rhs=True, backend="fused")
        assert bool(jnp.all(y == ref))

    def test_packed4_row_pairs_local(self):
        sv = self._leaf((32, 64), pack=True)
        assert sv.a.dtype == jnp.uint8
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="packed4")
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P(None, "model"),
                          backend="packed4")
        assert bool(jnp.all(y == ref))
        yk = lutq_dot_spmd(x, sv, _mesh(), a_spec=P("model", None),
                           backend="packed4")
        np.testing.assert_allclose(np.asarray(yk), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_expert_parallel_stack(self):
        E = 4
        we = jax.random.normal(jax.random.PRNGKey(0), (E, 16, 24))
        sve = serve_view({"k": jax.vmap(
            lambda w: init_state(w, QuantSpec(bits=4)))(we)})["k"]
        xe = jax.random.normal(jax.random.PRNGKey(3), (E, 5, 16))
        ref = jax.vmap(lambda xx, d, a: lutq_dot(
            xx, LutqState(w=None, d=d, a=a), backend="fused"))(xe, sve.d, sve.a)
        y = lutq_dot_spmd(xe, sve, _mesh(), a_spec=P("model", None, None),
                          backend="fused")
        assert bool(jnp.all(y == ref))

    def _pow2_leaf(self, shape, act=None):
        from repro.core.lutq import pow2_encode
        w = jax.random.normal(jax.random.PRNGKey(0), shape)
        st = init_state(w, QuantSpec(bits=4, constraint="pow2", min_size=1))
        return LutqState(w=None, d=pow2_encode(st.d), a=st.a, act=act)

    @pytest.mark.parametrize("use_act", [False, True])
    def test_pow2_n_and_k_sharded_bit_exact(self, use_act):
        """The shift-add path is bitwise under BOTH shardings: integer
        accumulation means the K-shard psum commutes exactly (unlike the
        fp backends, which only get allclose on the K shard)."""
        act = jnp.array([0.03, 127.0], jnp.float32) if use_act else None
        sv = self._pow2_leaf((32, 64), act=act)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="decode")  # integer oracle
        for a_spec in (P(None, "model"), P("model", None)):
            y = lutq_dot_spmd(x, sv, _mesh(), a_spec=a_spec, backend="auto")
            assert bool(jnp.all(y == ref)), (a_spec, use_act)

    def test_pow2_transposed_tied_logits(self):
        sv = self._pow2_leaf((64, 32))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        ref = lutq_dot(x, sv, backend="decode", transpose_rhs=True)
        y = lutq_dot_spmd(x, sv, _mesh(), a_spec=P("model", None),
                          transpose_rhs=True, backend="auto")
        assert bool(jnp.all(y == ref))

    def test_pow2_expert_parallel_stack(self):
        from repro.core.lutq import pow2_encode
        E = 4
        we = jax.random.normal(jax.random.PRNGKey(0), (E, 16, 24))
        base = serve_view({"k": jax.vmap(lambda w: init_state(
            w, QuantSpec(bits=4, constraint="pow2")))(we)})["k"]
        act = jnp.broadcast_to(jnp.array([0.05, 127.0], jnp.float32),
                               (E, 2)) + 0.0
        sve = LutqState(w=None, d=pow2_encode(base.d), a=base.a, act=act)
        xe = jax.random.normal(jax.random.PRNGKey(3), (E, 5, 16))
        ref = jax.vmap(lambda xx, d, a, c: lutq_dot(
            xx, LutqState(w=None, d=d, a=a, act=c),
            backend="decode"))(xe, sve.d, sve.a, sve.act)
        y = lutq_dot_spmd(xe, sve, _mesh(), a_spec=P("model", None, None),
                          backend="auto")
        assert bool(jnp.all(y == ref))
        # K-sharded stack with dynamic (pmax'd) activation scales
        sve2 = LutqState(w=None, d=sve.d, a=sve.a)
        ref2 = jax.vmap(lambda xx, d, a: lutq_dot(
            xx, LutqState(w=None, d=d, a=a), backend="decode"))(
                xe, sve2.d, sve2.a)
        y2 = lutq_dot_spmd(xe, sve2, _mesh(), a_spec=P(None, "model", None),
                           backend="auto")
        assert bool(jnp.all(y2 == ref2))


# ---------------------------------------------------------------------------
# serve pspecs: packed row-pair fallback, replicated dictionaries
# ---------------------------------------------------------------------------

class TestServePspecs:
    def test_packed_row_pair_divisibility_fallback(self):
        """Kin=12 divides a 4-way model axis, but the packed row count
        (6) does not — the packed leaf must replicate where the int8
        leaf shards, so no row pair is ever split across devices."""
        from repro.distributed.sharding import serve_pspecs

        w = jax.random.normal(jax.random.PRNGKey(0), (12, 64))
        st = init_state(w, QuantSpec(bits=4))
        axes = {"k": ("mlp", "embed")}  # dim0 -> "model" under SERVE_RULES
        plain = serve_view({"k": st})
        packed = serve_view({"k": st}, pack4=True)
        sp_plain = serve_pspecs(axes, _mesh(), plain)["k"]
        sp_packed = serve_pspecs(axes, _mesh(), packed)["k"]
        assert tuple(sp_plain.a) == ("model",)
        assert tuple(sp_packed.a) == ()  # replicated: 6 % 4 != 0

    def test_packed_row_pairs_shard_when_divisible(self):
        from repro.distributed.sharding import serve_pspecs

        w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        st = init_state(w, QuantSpec(bits=4))
        axes = {"k": ("mlp", "embed")}
        packed = serve_view({"k": st}, pack4=True)
        sp = serve_pspecs(axes, _mesh(), packed)["k"]
        assert tuple(sp.a) == ("model",)  # 32 packed rows / 4 devices

    def test_dictionaries_and_sids_replicated(self):
        cfg, sv, _ = _serve_tree(ARCHS["lm"], False)
        from repro.distributed.sharding import serve_pspecs

        _, _, axes = _serve_tree(ARCHS["lm"], False)
        pspecs = serve_pspecs(axes, _mesh(), sv)
        n_lutq = 0
        for leaf in jax.tree.leaves(
                pspecs, is_leaf=lambda x: isinstance(x, LutqState)):
            if not isinstance(leaf, LutqState):
                continue
            n_lutq += 1
            dparts = tuple(leaf.d)
            assert not dparts or dparts[-1] is None  # K axis replicated
            assert "data" not in jax.tree.leaves(tuple(leaf.a))  # serve rules
        assert n_lutq > 0

    def test_sharded_serve_view_places_leaves(self):
        cfg, _, axes = _serve_tree(ARCHS["lm"], False)
        params, _ = api.init(jax.random.PRNGKey(0), cfg)
        qparams = api.quantize(params, cfg, axes)
        placed = serve_view(qparams, policy=api.resolved_policy(cfg),
                            mesh=_mesh(), axes=axes)
        leaves = [l for l in jax.tree.leaves(
            placed, is_leaf=lambda x: isinstance(x, LutqState))
            if isinstance(l, LutqState)]
        assert all(isinstance(l.a.sharding, NamedSharding) for l in leaves)
        with pytest.raises(ValueError):
            serve_view(qparams, mesh=_mesh())  # axes required

    def test_serve_state_one_call(self):
        cfg, sv, _ = _serve_tree(ARCHS["lm"], False)
        placed, axes2 = api.serve_state(jax.random.PRNGKey(0), cfg,
                                        mesh=_mesh())
        for a, b in zip(jax.tree.leaves(sv), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        leaf = next(l for l in jax.tree.leaves(
            placed, is_leaf=lambda x: isinstance(x, LutqState))
            if isinstance(l, LutqState))
        assert isinstance(leaf.a.sharding, NamedSharding)


# ---------------------------------------------------------------------------
# jit cache keys + checkpoint
# ---------------------------------------------------------------------------

def test_jit_cache_keys_include_mesh():
    from repro.runtime import serving
    from repro.runtime.engine import _step_fn

    cfg, _, _ = _serve_tree(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    solo = serving.decode_fn(cfg)
    meshed = serving.decode_fn(cfg, _mesh(), batch=4, max_len=18)
    assert solo is not meshed
    assert serving.decode_fn(cfg, _mesh(), batch=4, max_len=18) is meshed
    assert serving.prefill_fn(cfg, 18) is not serving.prefill_fn(
        cfg, 18, _mesh())
    assert _step_fn(cfg, True) is not _step_fn(cfg, True, _mesh(), 4, 18, 0)


def test_ckpt_sharded_restore(tmp_path):
    from repro.checkpoint import ckpt
    from repro.distributed.sharding import serve_pspecs

    cfg, sv, axes = _serve_tree(ARCHS["lm"], False)
    mesh = _mesh()
    ckpt.save(sv, str(tmp_path), 3, mesh=mesh)
    rec = ckpt.load_mesh(str(tmp_path))
    assert rec == {"axes": ["data", "model"], "shape": [2, 4]}

    pspecs = serve_pspecs(axes, mesh, sv)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                             is_leaf=lambda x: isinstance(x, P))
    restored, step = ckpt.load(str(tmp_path), shardings=shardings)
    assert step == 3
    flat_a, flat_b = jax.tree.leaves(sv), jax.tree.leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # quantized leaves landed committed on their serving shardings
    for path_leaf in jax.tree.leaves(
            restored, is_leaf=lambda x: isinstance(x, LutqState)):
        if isinstance(path_leaf, LutqState):
            assert isinstance(path_leaf.a.sharding, NamedSharding)
    # a shardings tree that doesn't line up with the stored structure
    # fails loudly instead of silently loading unsharded
    with pytest.raises(ValueError, match="does not match checkpoint"):
        ckpt.load(str(tmp_path),
                  shardings={"nonexistent": NamedSharding(mesh, P())})


def test_hlo_no_all_gather_of_index_leaves(monkeypatch):
    """The compiled meshed decode must not all-gather quantized index
    tensors (s8/u8): assignments stay shard-local and the shard_map
    kernel path computes on local index shards.

    The HLO assertion alone would be vacuous on CPU — interpret-mode
    Pallas lowers to plain HLO that GSPMD partitions natively, so even
    an unannotated trace shows no index all-gathers. The dispatch count
    is the non-vacuous half: it proves ``annotate_spmd`` routed the
    nn-layer dots through ``lutq_dot_spmd`` during this trace.
    """
    import repro.kernels.ops as ops_mod
    from repro.runtime import serving

    calls = []
    real = ops_mod.lutq_dot_spmd

    def counting(*a, **kw):
        calls.append(kw.get("backend", a[4] if len(a) > 4 else None))
        return real(*a, **kw)

    monkeypatch.setattr(ops_mod, "lutq_dot_spmd", counting)

    cfg, _, sh, _, _ = _sharded(ARCHS["lm"], False)
    cfg = cfg.replace(kernel_backend="fused")
    B, L = 4, 16
    token = jnp.zeros((B, 1), jnp.int32)
    cache = api.init_cache(cfg, B, L)
    fn = serving.decode_fn(cfg, _mesh(), batch=B, max_len=L)
    lowered = fn.lower(sh, token, cache)
    assert len(calls) >= cfg.n_layers, (
        f"lutq_dot_spmd dispatched {len(calls)} times during the meshed "
        f"decode trace; expected at least one per layer — annotate_spmd "
        f"is not routing sharded index leaves to the shard_map path")

    hlo = lowered.compile().as_text()
    bad = [ln.strip() for ln in hlo.splitlines()
           if "all-gather(" in ln and ("s8[" in ln or "u8[" in ln)]
    assert not bad, (
        "compiled decode all-gathers quantized index leaves:\n"
        + "\n".join(bad[:5]))


def test_serve_cli_mesh_smoke(capsys):
    from repro.launch.serve import main

    rc = main(["--arch", "mistral-nemo-12b", "--reduced", "--batch", "4",
               "--prompt-len", "8", "--gen", "4", "--kernel-backend",
               "fused", "--mesh", "2x4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "mesh 2x4" in out and "per-device weights quantized" in out
    assert "PartitionSpec" in out
