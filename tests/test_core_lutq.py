"""Unit + property tests for the core LUT-Q algorithm (paper Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import (
    BINARY,
    TERNARY,
    LutqState,
    QuantSpec,
    apply_constraint,
    assign,
    decode,
    init_state,
    kmeans_update,
    kmeans_update_segsum,
    pow2_round,
    quantize_ste,
    update_state,
)

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


# ---------------------------------------------------------------------------
# assignment correctness: bucketize == naive argmin
# ---------------------------------------------------------------------------

class TestAssign:
    @pytest.mark.parametrize("bits", [1, 2, 3, 4, 6, 8])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_naive_argmin(self, bits, seed):
        w = _rand((64, 32), seed)
        spec = QuantSpec(bits=bits)
        state = init_state(w, spec)
        dist = jnp.abs(w.ravel()[:, None] - state.d[None, :])
        naive = jnp.argmin(dist, axis=1)
        # at exact ties argmin takes the first; our bucketize does too,
        # but dictionary duplicates can differ in *index* while the
        # decoded *value* is identical — compare decoded values.
        assert jnp.allclose(state.d[naive], state.d[state.a.ravel().astype(jnp.int32)])

    @given(st.integers(2, 8), st.integers(0, 5))
    @settings(max_examples=20, deadline=None)
    def test_property_assign_minimizes_distance(self, bits, seed):
        w = np.asarray(_rand((257,), seed))
        spec = QuantSpec(bits=bits)
        state = init_state(jnp.asarray(w), spec)
        d = np.asarray(state.d)
        q = np.asarray(decode(state.d, state.a))
        best = np.min(np.abs(w[:, None] - d[None, :]), axis=1)
        np.testing.assert_allclose(np.abs(w - q), best, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# k-means update (paper step 4)
# ---------------------------------------------------------------------------

class TestKmeans:
    def test_monotone_quantization_error(self):
        """Each k-means iteration must not increase the quantization MSE."""
        w = _rand((512,), 3)
        spec1 = QuantSpec(bits=3, kmeans_iters=1)
        d = jnp.linspace(-2, 2, 8)
        errs = []
        for _ in range(6):
            d, a = kmeans_update(w, d, spec1)
            errs.append(float(jnp.mean((decode(d, a) - w) ** 2)))
        assert all(e2 <= e1 + 1e-7 for e1, e2 in zip(errs, errs[1:])), errs

    def test_dictionary_stays_sorted(self):
        w = _rand((1024,), 4)
        d = jnp.linspace(-1, 1, 16)
        for spec in [QuantSpec(bits=4), QuantSpec(bits=4, constraint="pow2")]:
            nd, _ = kmeans_update(w, d, spec)
            assert bool(jnp.all(jnp.diff(nd) >= 0))

    def test_segsum_matches_onehot(self):
        w = _rand((2048,), 5)
        d = jnp.linspace(-2, 2, 16)
        spec = QuantSpec(bits=4, kmeans_iters=3)
        d1, a1 = kmeans_update(w, d, spec)
        d2, a2 = kmeans_update_segsum(w, d, spec)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5, atol=1e-6)
        assert jnp.all(a1 == a2)

    def test_empty_cluster_keeps_centroid(self):
        w = jnp.asarray([0.9, 1.0, 1.1])  # all mass near 1.0
        d = jnp.asarray([-5.0, 0.0, 1.0, 5.0])
        spec = QuantSpec(bits=2, kmeans_iters=1)
        nd, _ = kmeans_update(w, d, spec)
        assert float(nd[0]) == -5.0  # empty cluster untouched
        assert float(nd[3]) == 5.0

    def test_centroid_is_cluster_mean(self):
        w = jnp.asarray([-1.0, -0.9, 0.9, 1.0])
        d = jnp.asarray([-1.5, 1.5])
        spec = QuantSpec(bits=1, kmeans_iters=1)
        nd, a = kmeans_update(w, d, spec)
        np.testing.assert_allclose(np.asarray(nd), [-0.95, 0.95], rtol=1e-6)

    @given(st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_property_fixed_point(self, seed):
        """Running k-means to convergence then once more changes nothing."""
        w = _rand((300,), seed)
        spec = QuantSpec(bits=2, kmeans_iters=25)
        st_ = init_state(w, spec)
        d2, a2 = kmeans_update(w, st_.d, QuantSpec(bits=2, kmeans_iters=1))
        np.testing.assert_allclose(np.asarray(d2), np.asarray(st_.d), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# STE (paper steps 2/3)
# ---------------------------------------------------------------------------

class TestSTE:
    def test_forward_is_decoded(self):
        w = _rand((32, 16))
        state = init_state(w, QuantSpec(bits=4))
        q = quantize_ste(state.w, state.d, state.a)
        np.testing.assert_allclose(np.asarray(q), np.asarray(decode(state.d, state.a)))

    def test_gradient_is_straight_through(self):
        w = _rand((32, 16))
        state = init_state(w, QuantSpec(bits=2))
        g = jax.grad(lambda w_: jnp.sum(jnp.sin(quantize_ste(w_, state.d, state.a))))(w)
        q = decode(state.d, state.a)
        np.testing.assert_allclose(np.asarray(g), np.asarray(jnp.cos(q)), rtol=1e-6)


# ---------------------------------------------------------------------------
# constraints: pow2 / binary / ternary / pruning
# ---------------------------------------------------------------------------

class TestConstraints:
    def test_pow2_round_values(self):
        x = jnp.asarray([0.0, 0.1, -0.3, 1.5, -7.9, 1024.0])
        p = np.asarray(pow2_round(x))
        np.testing.assert_allclose(p, [0.0, 0.125, -0.25, 2.0, -8.0, 1024.0])

    @given(st.floats(-100.0, 100.0, allow_nan=False))
    @settings(max_examples=50, deadline=None)
    def test_property_pow2_is_nearest_in_log(self, v):
        if v != 0.0 and abs(v) < 2.0 ** -13:
            return  # below the exponent clamp (min_exp=-14): clamps, not nearest
        p = float(pow2_round(jnp.asarray(v)))
        if v == 0.0:
            assert p == 0.0
        else:
            assert p != 0.0 and np.sign(p) == np.sign(v)
            e = np.log2(abs(p))
            assert abs(e - round(e)) < 1e-6
            # nearest-in-log: |log2|v|| within 0.5 of chosen exponent
            assert abs(np.log2(abs(v)) - e) <= 0.5 + 1e-6

    def test_binary_dictionary(self):
        w = _rand((128,), 7)
        state = init_state(w, BINARY)
        vals = np.unique(np.asarray(decode(state.d, state.a)))
        assert set(vals.tolist()) <= {-1.0, 1.0}
        # sign must be preserved
        assert bool(jnp.all(jnp.sign(decode(state.d, state.a)) == jnp.where(w > 0, 1, -1)))

    def test_ternary_dictionary(self):
        w = _rand((128,), 8)
        state = init_state(w, TERNARY)
        vals = np.unique(np.asarray(decode(state.d, state.a)))
        assert set(vals.tolist()) <= {-1.0, 0.0, 1.0}

    def test_ternary_scaled_twn_rule(self):
        """fixed_scale ternary follows TWN: Delta=0.7E|w|,
        alpha=E{|w| : |w|>Delta}, values = alpha*{-1,0,1}."""
        w = _rand((4096,), 11, scale=0.05)
        spec = QuantSpec(bits=2, constraint="ternary", fixed_scale=True,
                         kmeans_iters=3)
        state = init_state(w, spec)
        d = np.asarray(state.d)
        assert d[1] == 0.0 and d[2] == -d[0] and d[2] > 0
        aw = np.abs(np.asarray(w))
        delta = 0.7 * aw.mean()
        alpha = aw[aw > delta].mean()
        np.testing.assert_allclose(d[2], alpha, rtol=1e-4)
        q = np.asarray(decode(state.d, state.a))
        assert 0.2 < (q == 0).mean() < 0.8  # meaningful sparsity

    def test_binary_scaled_bwn_rule(self):
        w = _rand((4096,), 12, scale=0.1)
        spec = QuantSpec(bits=1, constraint="binary", fixed_scale=True)
        state = init_state(w, spec)
        d = np.asarray(state.d)
        np.testing.assert_allclose(d[1], np.abs(np.asarray(w)).mean(), rtol=1e-4)

    @pytest.mark.parametrize("frac", [0.25, 0.5, 0.7])
    def test_prune_fraction_exact(self, frac):
        w = _rand((100, 100), 9)
        state = init_state(w, QuantSpec(bits=4, prune_frac=frac, kmeans_iters=2))
        q = decode(state.d, state.a)
        assert abs(float(jnp.mean(q == 0.0)) - frac) < 0.02
        # pruned entries must be the smallest-magnitude ones
        zero_mask = np.asarray(q == 0.0).ravel()
        wm = np.abs(np.asarray(w).ravel())
        assert wm[zero_mask].max() <= wm[~zero_mask].min() + 1e-6

    def test_pruned_pow2_combination(self):
        w = _rand((4096,), 10)
        state = init_state(w, QuantSpec(bits=4, constraint="pow2", prune_frac=0.5))
        d = np.asarray(state.d)
        nz = d[d != 0]
        assert np.allclose(np.log2(np.abs(nz)), np.round(np.log2(np.abs(nz))))
        assert (d == 0).any()


# ---------------------------------------------------------------------------
# full minibatch cycle: quantize -> grad -> sgd -> kmeans (Table 1)
# ---------------------------------------------------------------------------

class TestTrainingCycle:
    def test_lutq_learns_least_squares(self):
        """A quantized linear regression must reduce loss over steps."""
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (256, 32))
        true_w = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        y = x @ true_w
        spec = QuantSpec(bits=4, kmeans_iters=1, min_size=0)
        state = init_state(jnp.zeros((32, 8)), spec)

        @jax.jit
        def step(state):
            def loss_fn(w):
                q = quantize_ste(w, state.d, state.a)
                return jnp.mean((x @ q - y) ** 2)

            l, g = jax.value_and_grad(loss_fn)(state.w)
            w = state.w - 0.1 * g                         # step 3
            return l, update_state(LutqState(w, state.d, state.a), spec)  # step 4

        losses = []
        for _ in range(250):
            l, state = step(state)
            losses.append(float(l))
        assert losses[-1] < 0.05 * losses[0], losses[::25]

    def test_update_state_is_jittable(self):
        spec = QuantSpec(bits=4, kmeans_iters=2)
        w = _rand((64, 64))
        state = init_state(w, spec)
        f = jax.jit(lambda s: update_state(s, spec))
        out = f(state)
        assert out.d.shape == (16,) and out.a.dtype == jnp.int8


# ---------------------------------------------------------------------------
# step-4 implementations: dense one-hot vs segsum vs Pallas stats kernel
# ---------------------------------------------------------------------------

class TestKmeansThreeWayParity:
    """kmeans_update == kmeans_update_segsum == kmeans_update_stats.

    The three formulations of the paper's step 4 (dense one-hot; the
    sharding-friendly masked reductions; the fused Pallas assign+stats
    kernel) must agree across every dictionary constraint, including
    prune masks — the train step picks among them structurally
    (resolve_kmeans_impl), so any drift would silently change training.
    Dictionaries are compared to f32 accumulation order; assignments
    must match exactly (ties resolve identically in all three).
    """

    CASES = [
        ("none", 4, 0.0), ("none", 4, 0.25), ("pow2", 4, 0.0),
        ("pow2", 3, 0.3), ("binary", 1, 0.0), ("ternary", 2, 0.0),
        ("ternary", 2, 0.25),
    ]

    @pytest.mark.parametrize("constraint,bits,prune", CASES)
    def test_three_way(self, constraint, bits, prune):
        from repro.core.lutq import kmeans_update_stats
        from repro.core import init_dictionary

        spec = QuantSpec(bits=bits, constraint=constraint, prune_frac=prune,
                         kmeans_iters=2,
                         fixed_scale=constraint in ("binary", "ternary"))
        w = _rand((70, 61), seed=bits + int(prune * 100))
        d0 = init_dictionary(w, spec)
        d1, a1 = kmeans_update(w, d0, spec)
        d2, a2 = kmeans_update_segsum(w, d0, spec)
        # bn=512 with 4270 elements: exercises the kernel's ragged tail
        d3, a3 = kmeans_update_stats(w, d0, spec, bn=512, interpret=True)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d3),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a3))

    @given(st.integers(2, 6), st.integers(0, 4),
           st.integers(100, 3000))
    @settings(max_examples=10, deadline=None)
    def test_property_three_way_free_dict(self, bits, seed, n):
        from repro.core.lutq import kmeans_update_stats
        from repro.core import init_dictionary

        spec = QuantSpec(bits=bits, kmeans_iters=1)
        w = _rand((n,), seed)
        d0 = init_dictionary(w, spec)
        d1, a1 = kmeans_update(w, d0, spec)
        d3, a3 = kmeans_update_stats(w, d0, spec, bn=256, interpret=True)
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d3),
                                   rtol=1e-5, atol=1e-6)
        # quantization error per element matches even if an exact-tie
        # assignment differs in index (decoded value identical then)
        e1 = np.abs(np.asarray(w) - np.asarray(d1)[np.asarray(a1)])
        e3 = np.abs(np.asarray(w) - np.asarray(d3)[np.asarray(a3)])
        np.testing.assert_allclose(e1, e3, atol=1e-5)

    def test_resolve_impl_structural(self):
        from repro.core import resolve_kmeans_impl

        assert resolve_kmeans_impl(100) == "dense"
        big = 1 << 17
        expect = "stats" if jax.default_backend() == "tpu" else "segsum"
        assert resolve_kmeans_impl(big) == expect
        assert resolve_kmeans_impl(big, "stats") == "stats"
        with pytest.raises(ValueError):
            resolve_kmeans_impl(big, "nope")

    def test_update_state_forced_stats(self):
        from repro.core.lutq import update_state as us

        spec = QuantSpec(bits=4, kmeans_iters=1)
        w = _rand((64, 64))
        state = init_state(w, spec)
        ref = us(state, spec, impl="dense")
        out = us(state, spec, impl="stats")
        np.testing.assert_allclose(np.asarray(ref.d), np.asarray(out.d),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(ref.a), np.asarray(out.a))

    def test_kmeans_tree_impl_threads_through(self):
        from repro.core.policy import kmeans_tree

        spec = QuantSpec(bits=4, kmeans_iters=1, min_size=0)
        tree = {"k": init_state(_rand((32, 48)), spec)}
        ref = kmeans_tree(tree, spec, impl="dense")["k"]
        out = kmeans_tree(tree, spec, impl="stats")["k"]
        np.testing.assert_allclose(np.asarray(ref.d), np.asarray(out.d),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(ref.a), np.asarray(out.a))
