"""Shape-keyed kernel autotuning: cache round-trips, tuned-tile parity
with the default tiles (interpret-mode candidates may never change
numerics), persistence through serve_view manifests and checkpoint
manifests, and the fingerprint salt that keys the serving jit caches.

Also the honesty guards bench-smoke relies on: `_default_interpret()`
and platform detection must agree with `jax.default_backend()` so a
BENCH record can never label interpret-mode numbers as real-hardware
ones.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lutq import LutqState, decode_any, init_state
from repro.core.policy import quantize_tree, serve_view
from repro.core.spec import QuantSpec
from repro.kernels import autotune, ops
from repro.kernels.autotune import TileConfig, TuningCache
from repro.kernels.ref import pack4_kin


@pytest.fixture(autouse=True)
def _fresh_tuning_cache():
    """Every test starts and ends with an empty process tuning cache."""
    ops.tuning_cache().clear()
    yield
    ops.tuning_cache().clear()


def _serve_state(Kin, N, bits=4, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (Kin, N))
    st = init_state(w, QuantSpec(bits=bits, min_size=1))
    return LutqState(w=None, d=st.d, a=st.a)


# the test_kernel_backends parity matrix (M, Kin, N)
SHAPES = [(1, 34, 50), (5, 96, 72), (33, 130, 57), (8, 64, 211)]


class TestCacheRoundTrip:
    def test_json_round_trip(self, tmp_path):
        tc = TuningCache()
        k1 = autotune.make_key("matmul", 8, 2048, 2048, 16, jnp.float32,
                               "fused", "cpu")
        k2 = autotune.make_key("gemv_packed", 1, 512, 1024, 16, jnp.bfloat16,
                               "packed4", "tpu")
        tc.put(k1, TileConfig(bm=8, bn=256, bk=512, strategy="gather"))
        tc.put(k2, TileConfig(bm=256, bn=128, bk=1024))
        back = TuningCache.from_json_dict(
            json.loads(json.dumps(tc.to_json_dict())))
        assert back.items() == tc.items()

        p = tmp_path / "tuning.json"
        tc.save(p)
        assert TuningCache.load(p).items() == tc.items()

    def test_version_bumps_on_every_mutation(self):
        tc = TuningCache()
        v0 = tc.version
        tc.put("k", TileConfig(bm=8, bn=8, bk=8))
        assert tc.version == v0 + 1
        tc.update({"k2": TileConfig(bm=8, bn=8, bk=8)})
        assert tc.version == v0 + 2
        tc.clear()
        assert tc.version == v0 + 3 and len(tc) == 0

    def test_key_carries_every_tuning_axis(self):
        base = dict(kernel="matmul", M=8, N=64, Kin=128, K=16,
                    dtype=jnp.float32, backend="fused", plat="cpu")
        k0 = autotune.make_key(**base)
        for field, val in [("M", 9), ("N", 65), ("Kin", 130), ("K", 4),
                           ("dtype", jnp.bfloat16), ("backend", "packed4"),
                           ("plat", "tpu"), ("kernel", "gemv_packed")]:
            assert autotune.make_key(**{**base, field: val}) != k0, field


class TestTuneSearch:
    def test_injected_measure_picks_strict_minimum(self):
        """Deterministic winner: candidate order is sorted, ties keep the
        first; the winner lands in the cache under the canonical key."""
        cands = autotune.candidates("matmul", 8, 72, 96, 16, interpret=True)
        assert cands == sorted(
            cands, key=lambda t: (t.bm, t.bn, t.bk, t.strategy))
        target = cands[3]

        def measure(tile):
            return 1.0 if tile == target else 2.0

        tc = TuningCache()
        key, best, timings = autotune.tune(
            "matmul", M=8, N=72, Kin=96, K=16, interpret=True,
            cache=tc, measure=measure)
        assert best == target
        assert tc.get(key) == target
        assert len(timings) == len(cands)
        assert key == autotune.make_key(
            "matmul", 8, 72, 96, 16, jnp.float32, "fused",
            autotune.platform_key(True))

    def test_all_infeasible_keeps_defaults(self):
        _, best, _ = autotune.tune(
            "matmul", M=8, N=72, Kin=96, K=16, interpret=True,
            measure=lambda tile: float("inf"))
        assert best == TileConfig(bm=256, bn=256, bk=512)

    def test_interpret_candidates_pin_single_k_step(self):
        """The bit-identity precondition: every interpret candidate keeps
        the whole reduction axis in one k step (bk >= Kin), so the f32
        accumulation grouping matches the default tile exactly."""
        for kernel in ("matmul", "gemv_packed"):
            for M, Kin, N in SHAPES:
                for t in autotune.candidates(kernel, M, N, Kin, 16,
                                             interpret=True):
                    assert t.bk >= Kin, (kernel, M, Kin, N, t)


class TestTunedTileParity:
    def test_tuned_fused_tile_is_bit_identical(self):
        """A non-default tuned tile (gather strategy, small bn) must not
        change lutq_dot's output bits in interpret mode."""
        M, Kin, N = 5, 96, 72
        st = _serve_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        default = np.asarray(ops.lutq_dot(x, st, backend="fused"))

        key = autotune.make_key("matmul", M, N, Kin, 16, x.dtype, "fused",
                                autotune.platform_key(ops._default_interpret()))
        ops.tuning_cache().put(
            key, TileConfig(bm=8, bn=32, bk=512, strategy="gather"))
        tuned = np.asarray(ops.lutq_dot(x, st, backend="fused"))
        np.testing.assert_array_equal(tuned, default)
        np.testing.assert_allclose(tuned, np.asarray(x @ decode_any(st.d,
                                                                    st.a)),
                                   rtol=2e-4, atol=2e-4)

    def test_explicit_args_override_tuned_tile(self):
        """Caller-passed tile args win over the cache (escape hatch)."""
        M, Kin, N = 5, 96, 72
        st = _serve_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        key = autotune.make_key("matmul", M, N, Kin, 16, x.dtype, "fused",
                                autotune.platform_key(ops._default_interpret()))
        ops.tuning_cache().put(
            key, TileConfig(bm=8, bn=32, bk=512, strategy="gather"))
        got = ops.lutq_dot(x, st, backend="fused", bm=256, bn=256, bk=512,
                           strategy="onehot")
        np.testing.assert_array_equal(
            np.asarray(got),
            np.asarray(ops.lutq_dot(x, st, backend="fused", bm=256, bn=256,
                                    bk=512, strategy="onehot")))

    @pytest.mark.slow
    @pytest.mark.parametrize("M,Kin,N", SHAPES)
    def test_every_interpret_candidate_is_bit_identical(self, M, Kin, N):
        """Exhaustive: each candidate the interpret tuner may pick equals
        the default-tile output bit-for-bit, for both kernels."""
        st = _serve_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        default = np.asarray(ops.lutq_dot(x, st, backend="fused"))
        for t in autotune.candidates("matmul", M, N, Kin, 16, interpret=True):
            got = ops.lutq_dot(x, st, backend="fused", bm=t.bm, bn=t.bn,
                               bk=t.bk, strategy=t.strategy)
            np.testing.assert_array_equal(np.asarray(got), default, str(t))
        if Kin % 2:
            return
        packed = LutqState(w=None, d=st.d, a=pack4_kin(st.a))
        pdefault = np.asarray(ops.lutq_dot(x, packed, backend="packed4"))
        for t in autotune.candidates("gemv_packed", M, N, Kin, 16,
                                     interpret=True):
            got = ops.lutq_dot(x, packed, backend="packed4", bm=t.bm,
                               bn=t.bn, bk=t.bk, strategy=t.strategy)
            np.testing.assert_array_equal(np.asarray(got), pdefault, str(t))

    @pytest.mark.slow
    def test_real_search_round_trips_through_lutq_dot(self):
        """End-to-end: tune() with the real timing loop records a tile
        that lutq_dot then picks up, output unchanged."""
        M, Kin, N = 8, 64, 211
        st = _serve_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        default = np.asarray(ops.lutq_dot(x, st, backend="fused"))
        key, best, timings = autotune.tune(
            "matmul", M=M, N=N, Kin=Kin, K=16, reps=1, warmup=0,
            cache=ops.tuning_cache())
        assert ops.tuning_cache().get(key) == best
        assert any(np.isfinite(v) for v in timings.values())
        np.testing.assert_array_equal(
            np.asarray(ops.lutq_dot(x, st, backend="fused")), default)


def _tree():
    k = jax.random.PRNGKey(0)
    return {"layers": {"mlp": {"wi": {"kernel": jax.random.normal(
        k, (64, 128))}}}}


class TestPersistence:
    def test_serve_view_manifest_carries_tuning_cache(self):
        q = quantize_tree(_tree(), QuantSpec(bits=4, min_size=1))
        _, man = serve_view(q, with_manifest=True)
        assert "__tuning_cache__" not in man  # empty cache -> no entry

        key = autotune.make_key("matmul", 8, 128, 64, 16, jnp.float32,
                                "fused", "cpu")
        tile = TileConfig(bm=8, bn=128, bk=512, strategy="gather")
        ops.tuning_cache().put(key, tile)
        _, man = serve_view(q, with_manifest=True)
        carried = TuningCache.from_json_dict(man["__tuning_cache__"])
        assert carried.get(key) == tile
        # and the whole manifest (tiles included) survives JSON
        assert json.loads(json.dumps(man)) == man

    def test_checkpoint_manifest_round_trip(self, tmp_path):
        from repro.checkpoint.ckpt import load_tuning, save

        q = quantize_tree(_tree(), QuantSpec(bits=4, min_size=1))
        key = autotune.make_key("matmul", 8, 128, 64, 16, jnp.float32,
                                "fused", "cpu")
        tile = TileConfig(bm=8, bn=128, bk=512, strategy="gather")
        tc = TuningCache()
        tc.put(key, tile)
        save(q, str(tmp_path), 3, tuning=tc)
        back = load_tuning(str(tmp_path))
        assert back.get(key) == tile
        # untuned save -> no record, load_tuning -> None
        save(q, str(tmp_path / "plain"), 1)
        assert load_tuning(str(tmp_path / "plain")) is None

    def test_async_checkpointer_snapshots_live_cache(self, tmp_path):
        from repro.checkpoint.ckpt import AsyncCheckpointer, load_tuning

        tc = TuningCache()
        tc.put("k", TileConfig(bm=8, bn=8, bk=8))
        ck = AsyncCheckpointer(str(tmp_path), tuning=tc)
        ck.save(_tree(), 5)
        ck.wait()
        assert load_tuning(str(tmp_path)).get("k") == TileConfig(bm=8, bn=8,
                                                                 bk=8)


class TestJitCacheSalting:
    def test_fingerprint_tracks_process_cache(self):
        f0 = ops.tuning_fingerprint()
        ops.tuning_cache().put("k", TileConfig(bm=8, bn=8, bk=8))
        assert ops.tuning_fingerprint() == f0 + 1

    def test_decode_fn_retraces_on_tuning_update(self):
        """A tuning-cache mutation must invalidate the cached serving
        jits — otherwise a tuned tile lands after the first generate and
        silently never applies."""
        from repro.configs import get_config
        from repro.models.reduce import reduced
        from repro.runtime.serving import decode_fn, prefill_fn

        cfg = reduced(get_config("h2o-danube-1.8b"))
        f1 = decode_fn(cfg)
        p1 = prefill_fn(cfg, 32)
        assert decode_fn(cfg) is f1  # stable while the cache is quiet
        ops.tuning_cache().put("k", TileConfig(bm=8, bn=8, bk=8))
        assert decode_fn(cfg) is not f1
        assert prefill_fn(cfg, 32) is not p1


class TestPlatformGuards:
    """bench-smoke honesty: BENCH records label platform/interpret from
    these helpers, so they must track jax.default_backend exactly."""

    def test_default_interpret_matches_backend(self):
        assert ops._default_interpret() == (jax.default_backend() != "tpu")
        assert autotune.default_interpret() == ops._default_interpret()

    def test_platform_key_never_masquerades(self):
        plat = jax.default_backend()
        assert autotune.platform() == plat
        # not forcing interpret keys as the real platform
        assert autotune.platform_key(False) == plat
        # forcing interpret on a real TPU must NOT key (or report) as tpu
        if plat == "tpu":
            assert autotune.platform_key(True) == "interpret"
        else:
            assert autotune.platform_key(True) == plat

    def test_bench_record_is_honest(self):
        """The BENCH writer stamps platform/interpret from the same
        helpers the kernels dispatch on."""
        import importlib.util
        from pathlib import Path

        root = Path(__file__).resolve().parent.parent
        spec = importlib.util.spec_from_file_location(
            "kernel_bench", root / "benchmarks" / "kernel_bench.py")
        kb = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(kb)
        rec = kb.bench_backends(quick=True, reps=1, warmup=0, tune=False)
        assert rec["platform"] == jax.default_backend()
        assert rec["interpret"] == ops._default_interpret()
        assert rec["reps"] == 1
        for b in rec["backends"].values():
            assert b["measured_over_model"] == pytest.approx(
                b["us"] / b["v5e_model_us"])


class TestLeafShapes:
    def test_tree_shapes_cover_fused_and_transposed(self):
        pol = QuantSpec(bits=4, min_size=1)
        tree = {"layers": {"mlp": {"wi": {"kernel": jax.random.normal(
                    jax.random.PRNGKey(0), (64, 128))}}},
                "embed": {"table": jax.random.normal(
                    jax.random.PRNGKey(1), (96, 64))}}
        sv = serve_view(quantize_tree(tree, pol))
        recs = autotune.leaf_shapes_for_tree(sv, batch_m=4)
        by_shape = {(r["M"], r["Kin"], r["N"]): r for r in recs}
        assert (4, 64, 128) in by_shape          # the mlp kernel
        assert (4, 96, 64) in by_shape           # embed.table forward
        assert (4, 64, 96) in by_shape           # tied-logits transpose
        assert any(p.endswith(".T")
                   for p in by_shape[(4, 64, 96)]["paths"])

    def test_tune_tree_fills_cache_with_injected_speed(self, monkeypatch):
        # patch the timing loop so tune_tree is instant
        monkeypatch.setattr(autotune, "measure_call",
                            lambda fn, *a, **k: 1.0)
        pol = QuantSpec(bits=4, min_size=1)
        sv = serve_view(quantize_tree(_tree(), pol))
        lines = []
        tc = autotune.tune_tree(sv, batch_m=8, cache=TuningCache(),
                                emit=lines.append)
        assert len(tc) == len(autotune.leaf_shapes_for_tree(sv, batch_m=8))
        assert len(lines) == len(tc)
        for key, tile in tc.items():
            assert isinstance(tile, TileConfig)
            assert key.split("|")[0] in ("matmul", "gemv_packed")
