"""Tests for distributed/compress.py: error-feedback int8 gradient
compression and the explicit ppermute ring all-reduce.

The EF tests are pure single-device numerics. The ring tests run under
``shard_map`` over however many devices the process exposes — they skip
below 2 devices; the CI ``tier1-sharded`` job runs them on the 8-way
forced host platform (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.compress import (
    compressed_allreduce,
    ef_compress_leaf,
    ef_int8_transform,
    init_ef_state,
    ring_allreduce,
)
from repro.launch.mesh import compat_make_mesh

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="ring all-reduce needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------------------
# error-feedback int8
# ---------------------------------------------------------------------------

class TestEfInt8:
    def test_single_step_error_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        deq, err = ef_compress_leaf(g, jnp.zeros_like(g))
        # int8 symmetric quant: per-element error <= scale/2 = amax/254
        amax = float(jnp.max(jnp.abs(g)))
        assert float(jnp.max(jnp.abs(deq - g))) <= amax / 254 + 1e-6
        np.testing.assert_allclose(np.asarray(g - deq), np.asarray(err),
                                   atol=1e-6)

    def test_error_feedback_accumulates(self):
        """Sum of compressed gradients tracks the sum of true gradients:
        the EF residual is carried, not dropped (Seide et al.)."""
        key = jax.random.PRNGKey(1)
        e = jnp.zeros((128,), jnp.float32)
        total_true = jnp.zeros((128,))
        total_sent = jnp.zeros((128,))
        for i in range(20):
            key, sub = jax.random.split(key)
            g = jax.random.normal(sub, (128,))
            deq, e = ef_compress_leaf(g, e)
            total_true += g
            total_sent += deq
        # residual bounds the drift: |sum_true - sum_sent| == |e|, which
        # is at most one quantization step of the *last* compressed value
        drift = jnp.max(jnp.abs(total_true - total_sent))
        assert float(drift) == pytest.approx(float(jnp.max(jnp.abs(e))),
                                             abs=1e-5)
        assert float(drift) < 0.1

    def test_tree_transform_and_none_leaves(self):
        grads = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 8)),
                 "b": {"c": jnp.ones((4,)), "d": None}}
        ef = init_ef_state(grads)
        assert ef["b"]["d"] is None
        out_g, out_e = ef_int8_transform(grads, ef)
        assert out_g["b"]["d"] is None and out_e["b"]["d"] is None
        np.testing.assert_allclose(np.asarray(out_g["a"]),
                                   np.asarray(grads["a"]), atol=0.03)
        # second application with the carried error reduces the bias
        g2, e2 = ef_int8_transform(grads, out_e)
        two_step = np.asarray(out_g["a"] + g2["a"])
        np.testing.assert_allclose(two_step, np.asarray(2 * grads["a"]),
                                   atol=0.03)

    def test_zero_gradient_stable(self):
        g = jnp.zeros((16,))
        deq, err = ef_compress_leaf(g, jnp.zeros_like(g))
        assert float(jnp.max(jnp.abs(deq))) == 0.0
        assert float(jnp.max(jnp.abs(err))) == 0.0


# ---------------------------------------------------------------------------
# ring all-reduce
# ---------------------------------------------------------------------------

def _ring_mesh():
    n = len(jax.devices())
    return compat_make_mesh((n,), ("data",)), n


@multi_device
class TestRingAllreduce:
    def test_matches_global_sum(self):
        mesh, n = _ring_mesh()
        x = jax.random.normal(jax.random.PRNGKey(0), (n * 4, 16))
        out = shard_map(lambda v: ring_allreduce(v, "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P("data"))(x)
        # every device's local output block equals the sum of all blocks
        want = x.reshape(n, 4, 16).sum(0)
        got = np.asarray(out).reshape(n, 4, 16)
        for dev in range(n):
            np.testing.assert_allclose(got[dev], np.asarray(want),
                                       rtol=1e-5, atol=1e-5)

    def test_unaligned_chunking_pads(self):
        """Local leading dim not divisible by the ring size exercises the
        internal pad/unpad."""
        mesh, n = _ring_mesh()
        x = jax.random.normal(jax.random.PRNGKey(1), (n * 3, 7))
        out = shard_map(lambda v: ring_allreduce(v, "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P("data"))(x)
        want = x.reshape(n, 3, 7).sum(0)
        got = np.asarray(out).reshape(n, 3, 7)
        np.testing.assert_allclose(got[0], np.asarray(want), rtol=1e-5,
                                   atol=1e-5)

    def test_matches_psum(self):
        mesh, n = _ring_mesh()
        x = jax.random.normal(jax.random.PRNGKey(2), (n * 2, 8))
        ring = shard_map(lambda v: ring_allreduce(v, "data"), mesh=mesh,
                         in_specs=P("data"), out_specs=P("data"))(x)
        ps = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                       in_specs=P("data"), out_specs=P("data"))(x)
        np.testing.assert_allclose(np.asarray(ring), np.asarray(ps),
                                   rtol=1e-5, atol=1e-5)

    def test_compressed_allreduce_approximates_sum(self):
        mesh, n = _ring_mesh()
        x = jax.random.normal(jax.random.PRNGKey(3), (n * 4, 16))
        out = shard_map(lambda v: compressed_allreduce(v, "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P("data"))(x)
        want = np.asarray(x.reshape(n, 4, 16).sum(0))
        got = np.asarray(out).reshape(n, 4, 16)[0]
        # int8-on-the-wire: error per element <= n * (scale/2 + f16 eps)
        tol = n * (float(np.abs(x).max()) / 254 + 2e-3)
        np.testing.assert_allclose(got, want, atol=tol)
