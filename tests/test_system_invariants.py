"""System-level LUT-Q invariants, property-tested across training.

The paper's central structural claim: at every point during training the
*effective* network weights take at most K distinct values per tensor
(d[A]), and under the pow2 constraint every value is +-2^b (or 0) — the
multiplier-less property. These must hold after real train steps, not
just at init.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.lutq import LutqState, decode_any
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.models.reduce import reduced
from repro.nn.tree import tree_paths
from repro.optim.optimizers import adamw
from repro.optim.train_state import init_train_state, make_train_step, state_flat
from repro.core.policy import merge_trainable


def _train_some(arch, spec, steps=5, seed=0):
    cfg = reduced(get_config(arch)).replace(vocab=32, quant=spec, act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(seed), cfg)
    params = api.quantize(params, cfg, axes)
    opt = adamw(1e-3)
    state = state_flat(init_train_state(params, opt))
    step = jax.jit(make_train_step(cfg, api.loss_fn, opt))
    lm = MarkovLM(32, seed=seed)
    for n in range(steps):
        batch = {k: jnp.asarray(v) for k, v in lm.batch(0, n, 2, 16).items()}
        state, _ = step(state, batch)
    return merge_trainable(state["trainable"], state["static"])


@pytest.mark.slow
class TestMultiplierLessInvariant:
    def test_at_most_K_distinct_values_after_training(self):
        spec = QuantSpec(bits=2, min_size=512)
        params = _train_some("h2o-danube-1.8b", spec)
        checked = 0
        for path, leaf in tree_paths(params):
            if isinstance(leaf, LutqState):
                q = np.asarray(decode_any(leaf.d, leaf.a))
                per_slice = q.reshape(-1, q.shape[-2] * q.shape[-1]) \
                    if leaf.d.ndim > 1 else q.reshape(1, -1)
                for row in per_slice:
                    assert len(np.unique(row)) <= spec.K
                checked += 1
        assert checked >= 3

    def test_pow2_weights_after_training(self):
        spec = QuantSpec(bits=4, constraint="pow2", min_size=512)
        params = _train_some("rwkv6-1.6b", spec, steps=4)
        checked = 0
        for path, leaf in tree_paths(params):
            if isinstance(leaf, LutqState):
                q = np.abs(np.asarray(decode_any(leaf.d, leaf.a), np.float64))
                nz = q[q > 0]
                e = np.log2(nz)
                assert np.allclose(e, np.round(e), atol=1e-6), path
                checked += 1
        assert checked >= 3

    @given(st.sampled_from(["paligemma-3b", "deepseek-v2-lite-16b",
                            "zamba2-2.7b"]))
    @settings(max_examples=3, deadline=None)
    def test_property_assignments_stay_int8_in_range(self, arch):
        spec = QuantSpec(bits=2, min_size=512)
        params = _train_some(arch, spec, steps=2)
        for path, leaf in tree_paths(params):
            if isinstance(leaf, LutqState):
                a = np.asarray(leaf.a)
                assert a.dtype == np.int8
                assert a.min() >= 0 and a.max() < spec.K
                assert bool(np.all(np.diff(np.asarray(leaf.d), axis=-1) >= 0))
