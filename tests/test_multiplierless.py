"""Multiplier-less serving: pow2 sign+exponent planes, the shift-add
kernel backend, frozen activation scales, and the compiled-HLO multiply
audit.

The load-bearing claims:
  * every pow2-encoded serve leaf decodes to exactly ±2^k or 0;
  * the Pallas shift-add kernel is token-identical (bitwise) to the
    pure-XLA integer oracle — same quantization, same int32
    accumulation — under every tiling, so ``backend="pow2"`` and
    ``backend="decode"`` on an encoded leaf agree exactly;
  * a compiled ``serving_pow2`` forward contains no fp multiplies in
    the quantized matmul path (StableHLO audit, kernels/audit.py);
  * calibration freezes per-leaf activation scales that persist through
    serve views and checkpoints.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core.lutq import (
    LutqState,
    decode_any,
    init_state,
    pow2_decode,
    pow2_encode,
)
from repro.core.policy import backend_manifest, quantize_tree, serve_view
from repro.core.rules import serving_pow2
from repro.core.spec import SERVING_POW2, QuantSpec
from repro.kernels import audit, ops
from repro.kernels.ref import lutq_shift_ref, pow2_shift_scale, pow2_shift_weights


def _pow2_state(Kin, N, bits=4, seed=0, act=None):
    w = jax.random.normal(jax.random.PRNGKey(seed), (Kin, N))
    st_ = init_state(w, QuantSpec(bits=bits, constraint="pow2", min_size=1))
    return LutqState(w=None, d=pow2_encode(st_.d), a=st_.a, act=act)


# same odd-shape matrix as test_kernel_backends (gemv row, non-tile
# multiples) — the kernel pads, the oracle does not, parity is bitwise
SHAPES = [(1, 34, 50), (5, 96, 72), (33, 130, 57), (8, 64, 211)]


class TestPow2Encoding:
    @settings(deadline=None, max_examples=50)
    @given(st.lists(st.floats(min_value=-64.0, max_value=64.0,
                              allow_nan=False), min_size=2, max_size=16))
    def test_every_decoded_entry_is_pow2_or_zero(self, vals):
        code = pow2_encode(jnp.asarray(vals, jnp.float32))
        assert code.dtype == jnp.int8
        dec = np.asarray(pow2_decode(code), np.float64)
        nz = dec[dec != 0]
        assert np.all(np.log2(np.abs(nz)) == np.round(np.log2(np.abs(nz))))

    def test_serve_leaf_decodes_to_pow2(self):
        """Acceptance: every pow2 serve leaf is exactly ±2^k or 0."""
        st_ = _pow2_state(64, 48)
        q = np.asarray(decode_any(st_.d, st_.a), np.float64)
        nz = np.abs(q[q != 0])
        assert np.all(np.log2(nz) == np.round(np.log2(nz)))

    def test_shift_plane_reconstructs_decode(self):
        """wsh * scale == pow2_decode(code): the kernel's int32 shifted
        weights plus one fp scale are a lossless factorization."""
        st_ = _pow2_state(64, 48)
        wsh = pow2_shift_weights(st_.d)
        scale = pow2_shift_scale(st_.d)
        np.testing.assert_array_equal(
            np.asarray(wsh.astype(jnp.float32) * scale),
            np.asarray(pow2_decode(st_.d)))

    def test_encode_roundtrip_on_pow2_constrained_dict(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (128, 32))
        st_ = init_state(w, QuantSpec(bits=4, constraint="pow2", min_size=1))
        np.testing.assert_array_equal(
            np.asarray(pow2_decode(pow2_encode(st_.d))), np.asarray(st_.d))


class TestShiftKernelParity:
    @pytest.mark.parametrize("M,Kin,N", SHAPES)
    def test_kernel_bitwise_matches_oracle(self, M, Kin, N):
        st_ = _pow2_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        want = ops.lutq_dot(x, st_, backend="decode")  # integer oracle
        got = ops.lutq_dot(x, st_, backend="pow2")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_transpose_rhs_bitwise(self):
        st_ = _pow2_state(96, 211)
        x = jax.random.normal(jax.random.PRNGKey(2), (7, 211))
        want = ops.lutq_dot(x, st_, backend="decode", transpose_rhs=True)
        got = ops.lutq_dot(x, st_, backend="pow2", transpose_rhs=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_frozen_act_pair_bitwise(self):
        act = jnp.array([0.021, 127.0], jnp.float32)
        st_ = _pow2_state(64, 48, act=act)
        x = jax.random.normal(jax.random.PRNGKey(4), (5, 64))
        want = ops.lutq_dot(x, st_, backend="decode")
        got = ops.lutq_dot(x, st_, backend="pow2")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_raw_kernel_matches_ref(self):
        """lutq_shift (Pallas) == lutq_shift_ref on tile-exact shapes."""
        st_ = _pow2_state(512, 256)
        wsh = pow2_shift_weights(st_.d)
        xq = jax.random.randint(jax.random.PRNGKey(5), (256, 512), -127, 128,
                                dtype=jnp.int8)
        want = lutq_shift_ref(xq, st_.a, wsh)
        for strategy in ("onehot", "gather"):
            got = ops.lutq_shift(xq, st_.a, wsh, bm=256, bn=256, bk=512,
                                 strategy=strategy)
            assert got.dtype == jnp.int32
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=strategy)

    def test_quant_noise_bounded(self):
        """pow2 output error vs the unquantized-activation product is
        bounded by the int8 step (sanity that int8 act quant is sane)."""
        st_ = _pow2_state(64, 48)
        x = jax.random.normal(jax.random.PRNGKey(6), (5, 64))
        exact = x @ decode_any(st_.d, st_.a)
        got = ops.lutq_dot(x, st_, backend="pow2")
        rel = np.abs(np.asarray(got - exact)).max() / (
            np.abs(np.asarray(exact)).max() + 1e-9)
        assert rel < 0.05, rel


class TestResolution:
    def test_pow2_rung(self):
        st_ = _pow2_state(64, 48)
        assert ops.resolve_backend(st_, "auto") == "pow2"
        assert ops.resolve_backend(st_, "pow2") == "pow2"
        assert ops.resolve_backend(st_, "decode") == "decode"
        # transposed readout stays on the shift kernel
        assert ops.resolve_backend(st_, "auto", transpose_rhs=True) == "pow2"

    def test_pow2_on_float_leaf_degrades(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        st_ = init_state(w, QuantSpec(bits=4, min_size=1))
        serve = LutqState(w=None, d=st_.d, a=st_.a)
        # float dictionary: the shift kernel does not apply -> fused
        assert ops.resolve_backend(serve, "pow2") == "fused"

    def test_stacked_pow2_slices_dispatch(self):
        st_ = _pow2_state(64, 48)
        stk = LutqState(w=None, d=jnp.stack([st_.d] * 3),
                        a=jnp.stack([st_.a] * 3))
        assert ops.resolve_backend(stk, "auto") == "decode"
        assert ops.resolve_backend(stk, "auto", sliced=True) == "pow2"

    def test_overflow_guard_keeps_float_dict(self):
        """A dictionary spanning the full exponent range cannot promise
        an int32-safe accumulator at Kin=1024 -> serve_view keeps the
        float dictionary (degrades to the fused ladder, still correct)."""
        d = jnp.array([2.0 ** -14, 2.0 ** -3, 2.0 ** 3, 2.0 ** 15])
        a = jax.random.randint(jax.random.PRNGKey(0), (1024, 8), 0, 4,
                               dtype=jnp.int8)
        tree = {"x": {"kernel": LutqState(w=None, d=d, a=a)}}
        pol = serving_pow2()
        sv = serve_view(tree, policy=pol)
        assert sv["x"]["kernel"].d.dtype != jnp.int8


class TestMultiplyAudit:
    def test_oracle_lowering_is_integer(self):
        """Acceptance: zero fp multiplies in the quantized matmul path of
        a compiled pow2 forward; the s32 accumulation is present."""
        st_ = _pow2_state(64, 48)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        rep = audit.audit_multiplierless(
            lambda x, s: ops.lutq_dot(x, s, backend="decode"), x, st_,
            weight_shapes=[(64, 48)])
        assert not rep["fp_dots"]
        assert rep["int_dots"]
        # fp multiplies only at the boundary: quant (M,Kin) / epilogue (M,N)
        for m in rep["fp_multiplies"]:
            assert m["elems"] <= 8 * 64, m

    def test_kernel_lowering_is_integer(self):
        st_ = _pow2_state(64, 48)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        rep = audit.audit_multiplierless(
            lambda x, s: ops.lutq_dot(x, s, backend="pow2"), x, st_,
            weight_shapes=[(64, 48)])
        assert rep["int_dots"]

    def test_float_decode_fails_audit(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        st_ = init_state(w, QuantSpec(bits=4, min_size=1))
        serve = LutqState(w=None, d=st_.d, a=st_.a)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
        with pytest.raises(AssertionError, match="decoded-weight matmul"):
            audit.audit_multiplierless(
                lambda x, s: ops.lutq_dot(x, s, backend="decode"), x, serve,
                weight_shapes=[(64, 48)])

    def test_weight_dims_collected_from_params(self):
        st_ = _pow2_state(64, 48)
        dims = audit.quantized_weight_dims({"a": {"kernel": st_}})
        assert (64, 48) in dims and (48, 64) in dims


class TestActRegime:
    def test_dot_kernel_dynamic_act_matches_old_placement(self):
        """act_bits at the boundary == historical fake_quant-then-call."""
        from repro.core.actquant import fake_quant
        from repro.nn.linear import dot_kernel
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 64))
        np.testing.assert_array_equal(
            np.asarray(dot_kernel(x, w, act_bits=8)),
            np.asarray(dot_kernel(fake_quant(x, 8), w)))

    def test_frozen_fake_quant_matches_pow2_internal_quant(self):
        """fake_quant_frozen(x)@decoded == dequantized pow2 path: the
        fused-with-frozen-scales forward and the shift-add forward
        quantize activations identically."""
        from repro.core.actquant import fake_quant_frozen
        act = jnp.array([0.03, 127.0], jnp.float32)
        st_ = _pow2_state(64, 48, act=act)
        x = jax.random.normal(jax.random.PRNGKey(2), (5, 64))
        want = fake_quant_frozen(x, act) @ decode_any(st_.d, st_.a)
        got = ops.lutq_dot(x, st_, backend="pow2")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_fake_quant_frozen_ste_gradient(self):
        from repro.core.actquant import fake_quant_frozen
        act = jnp.array([0.1, 127.0], jnp.float32)
        x = jnp.linspace(-1, 1, 64)
        g = jax.grad(lambda x: jnp.sum(fake_quant_frozen(x, act) ** 2))(x)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(2 * fake_quant_frozen(x, act)),
            atol=1e-6)

    def test_capture_and_apply_scales(self):
        from repro.core.actquant import (
            apply_act_scales,
            capture_act_scales,
            tag_act_capture,
        )
        from repro.nn.linear import dot_kernel
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        st_ = init_state(w, QuantSpec(bits=4, constraint="pow2", min_size=1))
        tree = {"layers": {"mlp": {"wi": {
            "kernel": LutqState(w=None, d=st_.d, a=st_.a)}}}}
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 64)) * 3.0
        tagged = tag_act_capture(tree)

        @jax.jit
        def fwd(t, x):
            return dot_kernel(x, t["layers"]["mlp"]["wi"]["kernel"])

        with capture_act_scales() as rec:
            jax.block_until_ready(fwd(tagged, x))
        assert rec["layers/mlp/wi/kernel"] == pytest.approx(
            float(jnp.max(jnp.abs(x))), rel=1e-6)
        out = apply_act_scales(tree, rec, quant=serving_pow2())
        act = out["layers"]["mlp"]["wi"]["kernel"].act
        assert act is not None and act.shape == (2,)
        assert float(act[1]) == 127.0
        assert float(act[0]) == pytest.approx(
            float(jnp.max(jnp.abs(x))) / 127.0, rel=1e-6)

    def test_unmatched_rules_left_alone(self):
        from repro.core.actquant import apply_act_scales
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
        st_ = init_state(w, QuantSpec(bits=4, min_size=1))
        tree = {"x": {"kernel": LutqState(w=None, d=st_.d, a=st_.a)}}
        # act_frozen=False spec: no pair installed even with a record
        out = apply_act_scales(tree, {"x/kernel": 3.0},
                               quant=QuantSpec(bits=4, min_size=1))
        assert out["x"]["kernel"].act is None


class TestCheckpointAndManifest:
    def test_ckpt_roundtrips_act_and_pow2_plane(self, tmp_path):
        from repro.checkpoint import ckpt
        act = jnp.array([0.05, 127.0], jnp.float32)
        st_ = _pow2_state(64, 48, act=act)
        tree = {"layers": {"wi": {"kernel": st_}}}
        ckpt.save(tree, str(tmp_path), 0)
        back = ckpt.restore(str(tmp_path))[0]
        leaf = back["layers"]["wi"]["kernel"]
        assert leaf.d.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(leaf.d), np.asarray(st_.d))
        np.testing.assert_array_equal(np.asarray(leaf.act), np.asarray(act))

    def test_serve_view_manifest_records_encoding(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 96))  # >= min_size
        tree = {"layers": {"mlp": {"wi": {"kernel": w}}}}
        pol = serving_pow2()
        q = quantize_tree(tree, pol)
        sv, man = serve_view(q, policy=pol, with_manifest=True)
        rec = man["layers/mlp/wi/kernel"]
        assert rec["backend"] == "pow2"
        assert rec["encoding"] == "pow2"
        assert rec["act_frozen"] is False  # not calibrated
        assert sv["layers"]["mlp"]["wi"]["kernel"].d.dtype == jnp.int8
        # standalone manifest of the tree agrees (policy for `requested`)
        assert backend_manifest(sv, policy=pol) == man

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            QuantSpec(bits=4, backend="pow2")  # needs pow2 constraint
        with pytest.raises(ValueError):
            QuantSpec(bits=4, act_bits=0)
        assert SERVING_POW2.act_frozen and SERVING_POW2.act_bits == 8


class TestShiftAutotune:
    def test_tune_shift_kernel_records_pow2_backend(self):
        from repro.kernels import autotune
        ops.tuning_cache().clear()
        try:
            key, tile, timings = autotune.tune(
                "shift", M=8, N=128, Kin=128, K=16, interpret=True,
                cache=ops.tuning_cache(),
                measure=lambda t: float(t.bm + t.bn + t.bk))
            assert "pow2" in key and "int8" in key
            assert ops.tuning_cache().get(key) == tile
        finally:
            ops.tuning_cache().clear()


# -- full-model serving_pow2 path ------------------------------------------

def _pow2_setup(arch="h2o-danube-1.8b", calibrate=True):
    from repro.configs import get_config
    from repro.models import api
    from repro.models.reduce import reduced
    cfg = reduced(get_config(arch)).replace(
        quant=serving_pow2(), act_bits=8, remat=False)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    q = api.quantize(params, cfg, axes)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    if calibrate:
        q = api.calibrate(q, cfg, {"tokens": toks})
    pol = api.resolved_policy(cfg)
    sv, man = serve_view(q, policy=pol, with_manifest=True)
    return cfg, sv, man, {"tokens": toks}


class TestServingPow2EndToEnd:
    def test_prefill_kernel_bitwise_matches_oracle(self):
        from repro.models import api
        cfg, sv, man, batch = _pow2_setup()
        body = {k: v for k, v in man.items()
                if not k.startswith("__") and v["encoding"] == "pow2"}
        assert body and all(v["act_frozen"] for v in body.values())
        outs = {}
        for be in ("decode", "auto"):
            logits, _ = api.prefill(sv, cfg.replace(kernel_backend=be), batch)
            outs[be] = np.asarray(logits, np.float32)
        np.testing.assert_array_equal(outs["auto"], outs["decode"])

    def test_generate_token_identical(self):
        from repro.runtime.serving import generate
        cfg, sv, _, batch = _pow2_setup()
        out_d = generate(sv, cfg, batch, steps=4, backend="decode")
        out_p = generate(sv, cfg, batch, steps=4, backend="auto")
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))

    def test_forward_audit_no_fp_multiply_on_quantized_path(self):
        """Acceptance: the compiled serve forward's quantized matmuls are
        integer — audited on the lowered StableHLO of the real jit."""
        from repro.models import api
        cfg, sv, _, batch = _pow2_setup()
        cfg = cfg.replace(kernel_backend="decode")  # oracle: pure XLA
        rep = audit.audit_multiplierless(
            lambda p, t: api.prefill(p, cfg, {"tokens": t})[0],
            sv, batch["tokens"], params=sv)
        assert rep["int_dots"]

    @pytest.mark.slow
    def test_engine_parity(self):
        """Ragged requests through a 2-slot engine decode
        token-identically on the shift kernel vs the integer oracle."""
        from repro.runtime.engine import Engine
        cfg, sv, _, _ = _pow2_setup()
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (3, 10),
                                             0, cfg.vocab), np.int32)
        outs = {}
        for be in ("decode", "auto"):
            eng = Engine(sv, cfg.replace(kernel_backend=be), capacity=2,
                         max_len=20)
            for i, L in enumerate((10, 6, 8)):
                eng.submit(toks[i, :L], max_new=4)
            outs[be] = eng.run()
        for a, b in zip(outs["decode"], outs["auto"]):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])
