"""Pallas flash-attention kernel vs dense oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_tpu
from repro.nn.attention import dense_attention


def _mk(BH, S, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (BH, S, D)),
            jax.random.normal(ks[1], (BH, S, D)),
            jax.random.normal(ks[2], (BH, S, D)))


def _oracle(q, k, v, causal):
    # dense_attention expects (B, S, H, D); use H=1 per flattened head
    o = dense_attention(q[:, :, None, :], k[:, :, None, :], v[:, :, None, :],
                        causal=causal)
    return o[:, :, 0, :]


class TestFlashKernel:
    @pytest.mark.parametrize("S,D,bq,bk", [(256, 64, 128, 128),
                                           (512, 128, 128, 128),
                                           (256, 64, 64, 128),
                                           (384, 32, 128, 128)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, S, D, bq, bk, causal):
        q, k, v = _mk(3, S, D)
        got = flash_attention_tpu(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=True)
        want = _oracle(q, k, v, causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q, k, v = (t.astype(jnp.bfloat16) for t in _mk(2, 256, 64, 1))
        got = flash_attention_tpu(q, k, v, interpret=True)
        want = _oracle(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want), rtol=3e-2, atol=3e-2)

    def test_causal_blocks_skipped(self):
        """Poison strictly-upper kv blocks with NaN: a skipped block never
        touches them, a masked-but-computed one would propagate NaN."""
        S, D, b = 256, 32, 128
        q, k, v = _mk(1, S, D, seed=7)
        # last kv block is strictly above the diagonal for q block 0 only;
        # poison kv rows in [128, 256) and ask only for q rows [0, 128).
        k_poison = k.at[:, b:, :].set(jnp.nan)
        v_poison = v.at[:, b:, :].set(jnp.nan)
        out = flash_attention_tpu(q, k_poison, v_poison, causal=True,
                                  bq=b, bk=b, interpret=True)
        first = np.asarray(out[:, :b])
        assert np.isfinite(first).all(), "skipped block was executed"

    def test_gqa_grouped_layout(self):
        """Feeding G query-head blocks against shared KV == GQA."""
        B, S, Hkv, G, D = 2, 256, 2, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (B, S, Hkv * G, D))
        k = jax.random.normal(ks[1], (B, S, Hkv, D))
        v = jax.random.normal(ks[2], (B, S, Hkv, D))
        want = dense_attention(q, k, v, causal=True)
        # flatten: (B, S, Hkv, G, D) -> (B*Hkv*G, S, D) with kv repeated
        qf = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4).reshape(-1, S, D)
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(-1, S, D)
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(-1, S, D)
        got = flash_attention_tpu(qf, kf, vf, causal=True, interpret=True)
        got = got.reshape(B, Hkv, G, S, D).transpose(0, 3, 1, 2, 4).reshape(B, S, -1, D)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
