"""Self-speculative decoding acceptance suite.

The parity contract (ISSUE 10): greedy speculative serving emits tokens
**bitwise identical** to non-speculative ``generate``/``Engine`` runs of
the same prompts — across dense/SWA/encdec families, the kernel-backend
ladder (decode/fused/packed4), int8 KV caches and both slot and paged
pools. Drafts come from a genuinely coarse view (``draft_bits`` below
the stored dictionary's log2 K) so rejection, rewind and the SWA ring
snapshot/restore paths are actually exercised — a draft at the target's
own width would accept everything and prove nothing.

Also pinned here: the Leviathan rejection sampler's output marginal
under temperature (distributional, via hypothesis), nested-dictionary
coarsening invariants, the draft-view roundtrip through checkpoints and
serve manifests, the engine's refusal gates (activation quant, MoE,
recurrent/MLA families, SPMD meshes, ring-width floor), EOS-inside-an-
accepted-block retirement, and the paged engine's closed trace set with
the speculative round warmed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.lutq import LutqState, coarsen_dictionary
from repro.core.policy import backend_manifest
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.engine import Engine
from repro.runtime.serving import generate
from repro.runtime.speculative import (greedy_accept, rejection_accept,
                                       spec_step_fn)


def _q_setup(arch, pack4=False, **over):
    """Quantized serve tree: 4-bit LUT-Q (K=16) so draft_bits<4 gives a
    real nested coarsening with real rejections."""
    cfg = reduced(get_config(arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=32, remat=False,
        **over)
    params, _ = api.serve_state(jax.random.PRNGKey(0), cfg, pack4=pack4)
    return cfg, params


def _batch(cfg, B, P, seed=1):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, P)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, P, cfg.d_model)), jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# greedy parity: generate, across families x backends x KV quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,over,backend,pack4", [
    ("h2o-danube-1.8b", {"kv_cache_bits": 8}, "auto", False),  # SWA ring+int8
    ("mistral-nemo-12b", {}, "decode", False),
    ("mistral-nemo-12b", {}, "fused", False),
    ("mistral-nemo-12b", {}, "packed4", True),
    ("seamless-m4t-medium", {}, "auto", False),                # encdec
])
def test_generate_speculative_token_parity(arch, over, backend, pack4):
    cfg, params = _q_setup(arch, pack4=pack4, **over)
    batch = _batch(cfg, B=2, P=9)
    lengths = jnp.asarray([9, 6], jnp.int32)
    base = generate(params, cfg, batch, steps=8, lengths=lengths,
                    backend=backend)
    spec, stats = generate(params, cfg, batch, steps=8, lengths=lengths,
                           backend=backend, speculative=2, draft_bits=2,
                           return_stats=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec),
                                  err_msg=f"{arch}/{backend}")
    # draft_bits=2 on a K=16 dictionary must reject sometimes AND accept
    # sometimes — otherwise the round machinery was not really exercised
    assert 0.0 < stats["acceptance_rate"] < 1.0
    assert stats["spec_tokens_per_round"] > 1.0


@pytest.mark.slow
def test_generate_speculative_parity_fp_draft_is_target():
    """Unquantized params pass through draft_view unchanged (nothing to
    coarsen), so the draft IS the target and every round fully accepts —
    the degenerate end of the protocol stays exact too."""
    cfg = reduced(get_config("mistral-nemo-12b")).replace(
        quant=None, act_bits=32, remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=2, P=7)
    base = generate(params, cfg, batch, steps=6)
    spec, stats = generate(params, cfg, batch, steps=6, speculative=3,
                           return_stats=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(spec))
    assert stats["acceptance_rate"] == 1.0


# ---------------------------------------------------------------------------
# greedy parity: Engine, slot + paged pools, trace closure, EOS-in-block
# ---------------------------------------------------------------------------

LENS = [6, 11, 9, 7]


def _run_engine(cfg, params, prompts, spec, *, paged, max_new=12, eos=None):
    kw = dict(kv_pages=64, page_size=8) if paged else {}
    eng = Engine(params, cfg, capacity=3, max_len=40, speculative=spec,
                 draft_bits=2, **kw)
    tc0 = eng.paged_trace_counts() if paged else None
    for p in prompts:
        eng.submit(p, max_new=max_new, eos_id=eos)
    res = eng.run()
    if paged:
        assert eng.paged_trace_counts() == tc0, "serving grew the trace set"
    return [r["tokens"].tolist() for r in res], eng.stats()


@pytest.mark.parametrize("paged", [False, True])
def test_engine_speculative_parity(paged):
    """Ragged requests through a 3-slot speculative engine (slot reuse +
    mid-flight admission) match the non-speculative engine token-for-
    token, in fewer engine steps; paged engines additionally keep the
    AOT-warmed trace set closed across the speculative serve."""
    cfg, params = _q_setup("mistral-nemo-12b")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in LENS]
    base, st0 = _run_engine(cfg, params, prompts, 0, paged=paged)
    spec, st1 = _run_engine(cfg, params, prompts, 2, paged=paged)
    assert base == spec
    assert st1["decode_steps"] <= st0["decode_steps"]
    assert st1["spec_rounds"] == st1["decode_steps"]
    assert 0.0 < st1["acceptance_rate"] < 1.0
    if paged:
        # the spec round is part of the warmed trace set
        eng = Engine(params, cfg, capacity=3, max_len=40, speculative=2,
                     draft_bits=2, kv_pages=64, page_size=8)
        assert eng.paged_trace_counts()["spec"] == 1


@pytest.mark.slow
def test_engine_speculative_parity_swa_ring_int8():
    """The hard case: a full SWA ring attends every filled column, so a
    speculative round must snapshot/restore the columns it clobbers.
    Long enough generations wrap the ring several times."""
    cfg, params = _q_setup("h2o-danube-1.8b", kv_cache_bits=8)
    assert cfg.window is not None
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, (L,)).astype(np.int32)
               for L in (6, 9)]
    # max_len > window => ring cache; max_new wraps it
    base, _ = _run_engine(cfg, params, prompts, 0, paged=False, max_new=22)
    spec, _ = _run_engine(cfg, params, prompts, 2, paged=False, max_new=22)
    assert base == spec


def test_eos_inside_accepted_block_retires_same_step():
    """EOS landing mid-block truncates the block at EOS and retires the
    request the same engine step — trailing accepted tokens are dropped
    exactly as sequential decode would never have emitted them."""
    cfg, params = _q_setup("mistral-nemo-12b")
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, (7,)).astype(np.int32)
    base, _ = _run_engine(cfg, params, [prompt], 0, paged=False, max_new=14)
    eos = base[0][5]  # a token known to appear mid-stream
    want = base[0][:base[0].index(eos) + 1]
    got, _ = _run_engine(cfg, params, [prompt], 3, paged=False, max_new=14,
                         eos=int(eos))
    assert got[0] == want
    assert got[0][-1] == eos


# ---------------------------------------------------------------------------
# accept rules
# ---------------------------------------------------------------------------

def test_greedy_accept_longest_prefix():
    V = 11
    d = jnp.asarray([[3, 5, 7], [1, 2, 9]], jnp.int32)
    # row 0: target argmax agrees at positions 0,1 then diverges (-> 4);
    # row 1: disagrees immediately (-> 8)
    p = np.full((2, 4, V), -10.0, np.float32)
    for j, t in enumerate([3, 5, 2, 6]):
        p[0, j, t] = 0.0
    for j, t in enumerate([8, 2, 9, 0]):
        p[1, j, t] = 0.0
    out, n_acc = greedy_accept(d, jnp.asarray(p))
    np.testing.assert_array_equal(np.asarray(n_acc), [3, 1])
    np.testing.assert_array_equal(np.asarray(out), [[3, 5, 2, 6],
                                                    [8, 2, 9, 0]])


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_rejection_sampler_marginal_matches_target(seed):
    """Leviathan guarantee: whatever the draft distribution q, the first
    emitted token of a round is distributed exactly as softmax(p_0/T) —
    the accept/resample mixture reconstructs the target marginal. TV
    distance against the exact target over many i.i.d. rounds."""
    V, k, temp, N = 12, 3, 0.9, 4000
    rng = np.random.default_rng(seed)
    q_log = rng.standard_normal((k, V)).astype(np.float32) * 1.5
    p_log = rng.standard_normal((k + 1, V)).astype(np.float32) * 1.5
    qt = jnp.asarray(np.broadcast_to(q_log, (N, k, V)))
    pt = jnp.asarray(np.broadcast_to(p_log, (N, k + 1, V)))
    key = jax.random.PRNGKey(seed % (2**31 - 1))
    kd, kr = jax.random.split(key)
    # drafts sampled from q at the same temperature, per trial
    d = jax.vmap(lambda kk: jax.vmap(jax.random.categorical)(
        jax.random.split(kk, k), jnp.asarray(q_log) / temp))(
        jax.random.split(kd, N)).astype(jnp.int32)
    _, out, n_acc = rejection_accept(
        jax.random.split(kr, N), d, qt, pt, jnp.float32(temp))
    emp = np.bincount(np.asarray(out[:, 0]), minlength=V) / N
    target = np.asarray(jax.nn.softmax(jnp.asarray(p_log[0]) / temp))
    tv = 0.5 * np.abs(emp - target).sum()
    assert tv < 0.08, f"TV(empirical, target) = {tv:.3f}"
    assert int(n_acc.min()) >= 1 and int(n_acc.max()) <= k + 1


# ---------------------------------------------------------------------------
# nested dictionaries: coarsening + draft view + ckpt/manifest roundtrip
# ---------------------------------------------------------------------------

def test_coarsen_dictionary_invariants():
    rng = np.random.default_rng(0)
    d = jnp.asarray(np.sort(rng.standard_normal(16)).astype(np.float32))
    a = jnp.asarray(rng.integers(0, 16, (64, 32)).astype(np.int32))
    dc, fmap = coarsen_dictionary(d, a, 8)
    dc, fmap = np.asarray(dc), np.asarray(fmap)
    assert dc.shape == (8,) and fmap.shape == (16,)
    assert (np.diff(dc) >= 0).all(), "coarse dictionary must stay sorted"
    assert (np.diff(fmap) >= 0).all(), "fine->coarse map must be monotone"
    assert fmap.min() >= 0 and fmap.max() <= 7, "map must be total"
    with pytest.raises(ValueError):
        coarsen_dictionary(d, a, 32)


def test_draft_view_nesting_and_bytes():
    cfg, params = _q_setup("mistral-nemo-12b")
    draft, report = api.draft_view(params, draft_bits=2, with_report=True)
    n_coarse = 0
    flatp = {"/".join(p): l for p, l in _walk(params)}
    for path, leaf in _walk(draft):
        if not isinstance(leaf, LutqState):
            continue
        rec = report["/".join(path)]
        src = flatp["/".join(path)]
        if rec["shared"]:
            assert leaf is src and rec["draft_bytes"] == 0
            continue
        n_coarse += 1
        assert leaf.d.shape[-1] == 4 and rec["draft_K"] == 4
        assert rec["draft_bytes"] == int(leaf.d.nbytes) + int(leaf.a.nbytes)
        assert leaf.sid is src.sid  # rule ids carried by reference
    assert n_coarse > 0
    # draft_bits at/above the stored width shares everything: 0 bytes
    shared, rep4 = api.draft_view(params, draft_bits=4, with_report=True)
    assert all(v["shared"] and v["draft_bytes"] == 0 for v in rep4.values())


def _walk(tree, prefix=()):
    if isinstance(tree, dict):
        for k in tree:
            yield from _walk(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def test_draft_view_k256_twos_complement_wrap():
    """K=256 assignments live in int8 two's-complement (the kernels
    reinterpret the plane); the coarsen path must undo the wrap or the
    upper half of the dictionary remaps through garbage. A 4-bit view
    of an 8-bit leaf reconstructs within ordinary 4-bit error."""
    cfg, params = _q_setup("mistral-nemo-12b")
    cfg8 = cfg.replace(quant=QuantSpec(bits=8, min_size=1024))
    p8, _ = api.serve_state(jax.random.PRNGKey(0), cfg8)
    leaf = p8["embed"]["table"]
    assert leaf.d.shape[-1] == 256 and int(leaf.a.min()) < 0
    d4 = api.draft_view(p8, draft_bits=4)["embed"]["table"]
    a = np.asarray(leaf.a).astype(np.int64) % 256
    ad = np.asarray(d4.a).astype(np.int64) % 256
    wt = np.asarray(leaf.d)[a]
    wd = np.asarray(d4.d)[ad]
    rel = np.abs(wt - wd).mean() / (np.abs(wt).mean() + 1e-9)
    assert rel < 0.25, f"coarse view decorrelated from target: {rel:.3f}"


def test_draft_view_roundtrip_ckpt_and_manifest(tmp_path):
    """The nested draft dictionary survives a checkpoint save/restore
    bit-for-bit, and the serve manifest assigns the coarse leaves a
    kernel backend exactly like first-class serve leaves."""
    from repro.checkpoint import ckpt

    cfg, params = _q_setup("mistral-nemo-12b")
    draft = api.draft_view(params, draft_bits=3)
    ckpt.save(draft, str(tmp_path), step=0)
    back, step = ckpt.restore(str(tmp_path))
    assert step == 0
    orig = dict(_walk(draft))
    rest = dict(_walk(back))
    n_lutq = 0
    for path, leaf in orig.items():
        if not isinstance(leaf, LutqState):
            continue
        n_lutq += 1
        got = rest[path]
        np.testing.assert_array_equal(np.asarray(leaf.d), np.asarray(got.d))
        np.testing.assert_array_equal(np.asarray(leaf.a), np.asarray(got.a))
    assert n_lutq > 0
    man = backend_manifest(draft, api.resolved_policy(cfg))
    assert man and all("backend" in m for m in man.values())
    # serve_state can emit the draft view alongside the serve tree
    out = api.serve_state(jax.random.PRNGKey(0), cfg, draft_bits=3)
    assert len(out) == 3  # (tree, axes, draft_view)


# ---------------------------------------------------------------------------
# refusal gates
# ---------------------------------------------------------------------------

def test_refuses_dynamic_activation_quant():
    cfg = reduced(get_config("mistral-nemo-12b")).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8, remat=False)
    ok, why = api.speculative_supported(cfg)
    assert not ok and "act" in why
    params, _ = api.serve_state(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="activation"):
        Engine(params, cfg, capacity=2, max_len=32, speculative=2)


@pytest.mark.parametrize("arch,frag", [
    ("rwkv6-1.6b", "rewind"),
    ("zamba2-2.7b", "rewind"),
    ("qwen3-moe-235b-a22b", "MoE"),
    ("deepseek-v2-lite-16b", "MoE"),
])
def test_refuses_unrewindable_families(arch, frag):
    cfg = reduced(get_config(arch)).replace(act_bits=32)
    ok, why = api.speculative_supported(cfg)
    assert not ok and frag in why


def test_refuses_mla_mesh_and_bad_k():
    cfg = reduced(get_config("mistral-nemo-12b")).replace(
        act_bits=32, use_mla=True)
    ok, why = api.speculative_supported(cfg)
    assert not ok and "MLA" in why
    cfg = reduced(get_config("mistral-nemo-12b")).replace(act_bits=32)
    with pytest.raises(ValueError, match="mesh"):
        spec_step_fn(cfg, k=2, greedy=True, mesh="fake-mesh")
    with pytest.raises(ValueError, match="k must be"):
        spec_step_fn(cfg, k=0, greedy=True)


def test_ring_width_floor_and_headroom():
    """k+1 must fit the SWA ring, and submit must hold k tokens of
    cache headroom for the verify window."""
    cfg, params = _q_setup("h2o-danube-1.8b")
    eff = min(40, cfg.window)
    with pytest.raises(ValueError, match="ring"):
        Engine(params, cfg, capacity=2, max_len=40, speculative=eff)
    eng = Engine(params, cfg, capacity=2, max_len=20, speculative=3,
                 draft_bits=2)
    with pytest.raises(ValueError, match="headroom"):
        eng.submit(np.arange(1, 10, dtype=np.int32), max_new=9)
    eng.submit(np.arange(1, 9, dtype=np.int32), max_new=9)  # 8+9+3 fits
