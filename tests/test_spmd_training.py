"""SPMD LUT-Q training: mesh-parallel train step end-to-end.

Pins the PR-5 acceptance contract on the forced 8-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the CI
``tier1-sharded`` job):

  * the 2x4 ("data", "model") train step matches the solo loss
    trajectory — near-bitwise on the first step, bounded tracking over
    50 steps (backward psums over sharded weight dims make strict
    bitwise impossible; their ~1e-7 reduction-order noise is amplified
    by training chaos, so the trajectory contract is initial
    near-exactness + tight tracking + matched convergence);
  * masters/moments/EF state genuinely FSDP/TP-shard (real shards, not
    replicas) while LUT-Q dictionaries replicate, so the step-4 recenter
    is exact on shards;
  * compressed-DP gradients (ef / explicit ring) converge with the
    uncompressed run, and the ring mode ships real ppermute traffic;
  * TrainLoop syncs metrics only on the log/checkpoint cadence and
    resumes through ckpt.restore(shardings=) — including elastic resume
    onto a different mesh;
  * a sharded train checkpoint restores straight into the PR 4 sharded
    serving path with token-identical generation vs the solo-trained
    checkpoint.

Everything here skips on a single-device process (plain tier-1 runs).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.core.lutq import LutqState
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.distributed.compress import (dp_grad_transform, dp_wire_bytes,
                                        trainable_pspecs)
from repro.launch import partition
from repro.launch.mesh import make_host_mesh
from repro.models import api
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw
from repro.optim.train_state import init_train_state, make_train_step, state_flat
from repro.runtime.loop import TrainLoop

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.slow,
    pytest.mark.skipif(
        len(jax.devices()) < 8,
        reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"),
]

ARCH = "h2o-danube-1.8b"
B, S = 4, 16


@functools.lru_cache(maxsize=None)
def _mesh(d=2, m=4):
    return make_host_mesh(d, m)


@functools.lru_cache(maxsize=None)
def _cfg(arch=ARCH):
    return reduced(get_config(arch)).replace(
        vocab=48, act_bits=8,
        quant=QuantSpec(bits=4, kmeans_iters=1, min_size=4096,
                        constraint="pow2"))


@functools.lru_cache(maxsize=None)
def _init_params(arch=ARCH):
    cfg = _cfg(arch)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    return api.quantize(params, cfg, axes), axes


def _build(mesh=None, *, compress=None, arch=ARCH, lr=1e-3):
    cfg = _cfg(arch)
    params, _ = _init_params(arch)
    opt = adamw(lr)
    state = state_flat(init_train_state(params, opt,
                                        grad_compress=bool(compress)))
    sh = None
    if mesh is not None:
        sh = partition.train_shardings(cfg, mesh, batch=B, seq=S,
                                       grad_compress=bool(compress))
        state = partition.place_state(state, sh["state"])
    gt = (dp_grad_transform(mesh, mode=compress,
                            pspecs=None if sh is None
                            else trainable_pspecs(sh["state"]))
          if compress else None)
    step_fn = make_train_step(cfg, api.loss_fn, opt, grad_transform=gt,
                              shardings=sh)
    if mesh is None:
        step_fn = jax.jit(step_fn)
    return cfg, state, step_fn, sh


def _run(mesh=None, *, steps=20, compress=None, ckpt_dir=None, arch=ARCH):
    cfg, state, step_fn, sh = _build(mesh, compress=compress, arch=arch)
    lm = MarkovLM(cfg.vocab, seed=0)

    def make_batch(n):
        return {k: jnp.asarray(v) for k, v in lm.batch(0, n, B, S).items()}

    loop = TrainLoop(step_fn, make_batch, ckpt_dir=ckpt_dir, ckpt_every=1000,
                     log_every=10, log_fn=lambda *_: None,
                     shardings=None if sh is None else sh["state"], mesh=mesh)
    state, step = loop.run(state, steps, handle_signals=False)
    return cfg, state, [h["loss"] for h in loop.history], loop


# ---------------------------------------------------------------------------
# acceptance: loss-trajectory parity solo vs 2x4
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [
    ARCH,
    # deepseek exercises MLA under the 4-way model axis: its rope-half
    # q/k assembly is the concat-along-sharded-dim partitioner hazard
    # fixed in nn/mla.py (host 0/1 einsum assembly) — this param pins it
    "deepseek-v2-lite-16b",
])
def test_loss_trajectory_parity_solo_vs_mesh(arch):
    steps = 50
    _, _, solo, _ = _run(None, steps=steps, arch=arch)
    _, _, mesh, _ = _run(_mesh(), steps=steps, arch=arch)
    assert len(solo) == len(mesh) == steps
    rels = [abs(a - b) / abs(a) for a, b in zip(solo, mesh)]
    # first step: reduction-order noise only (no chaos amplification yet)
    assert rels[0] < 1e-5, rels[0]
    # whole trajectory tracks tightly and converges to the same level
    assert max(rels) < 0.03, (max(rels), rels)
    assert sum(rels) / len(rels) < 0.01, rels
    assert mesh[-1] < mesh[0] * 0.9 and solo[-1] < solo[0] * 0.9


def test_state_actually_sharded_and_dicts_replicated():
    mesh = _mesh()
    _, state, step_fn, sh = _build(mesh)
    lm = MarkovLM(48, seed=0)
    batch = {k: jnp.asarray(v) for k, v in lm.batch(0, 0, B, S).items()}
    state, _ = step_fn(state, batch)

    def shard_frac(x):
        return x.addressable_shards[0].data.size / x.size

    from repro.nn.tree import tree_paths
    sharded_masters = [p for p, l in tree_paths(state["trainable"])
                       if l is not None and hasattr(l, "addressable_shards")
                       and shard_frac(l) < 1.0]
    assert len(sharded_masters) >= 3, sharded_masters
    # optimizer moments mirror the masters' placement
    sharded_moments = [p for p, l in tree_paths(state["opt_state"]["m"])
                       if l is not None and hasattr(l, "addressable_shards")
                       and shard_frac(l) < 1.0]
    assert len(sharded_moments) >= 3
    # every LUT-Q dictionary (and sid) is fully replicated after step 4
    for p, l in tree_paths(state["static"]):
        if l is None or not hasattr(l, "sharding"):
            continue
        name = p[-1]
        if name in ("__lutq_d", "__lutq_sid"):
            assert shard_frac(l) == 1.0, (p, l.sharding)


def test_kmeans_exact_on_shards():
    """segsum step 4 on a sharded master == the solo dense result: the
    per-shard sums/counts are combined by the partitioner's psum, so the
    dictionary update is exact (clusters partition elements)."""
    from repro.core.lutq import kmeans_update, kmeans_update_segsum
    from repro.core import init_dictionary

    mesh = _mesh()
    spec = QuantSpec(bits=4, kmeans_iters=2)
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 128))
    d0 = init_dictionary(w, spec)
    d_ref, a_ref = kmeans_update(w, d0, spec)
    ws = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    d_sh, a_sh = jax.jit(lambda w, d: kmeans_update_segsum(w, d, spec))(ws, d0)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_sh),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(a_ref), np.asarray(a_sh))


def test_train_shardings_lru_cache_and_fsdp():
    mesh = _mesh()
    cfg = _cfg()
    sh1 = partition.train_shardings(cfg, mesh, batch=B, seq=S)
    sh2 = partition.train_shardings(cfg, mesh, batch=B, seq=S)
    assert sh1 is sh2  # cached per (cfg, mesh, batch geometry)
    assert partition.train_shardings(cfg, mesh, batch=B, seq=S,
                                     grad_compress=True) is not sh1
    assert "ef" not in sh1["state"]
    # at least one master sharded over the FSDP "data" axis
    specs = [s.spec for s in jax.tree.leaves(
        sh1["state"]["trainable"], is_leaf=lambda x: x is None)
        if s is not None]
    assert any("data" in jax.tree.leaves(tuple(sp)) for sp in specs)


# ---------------------------------------------------------------------------
# compressed-DP gradients
# ---------------------------------------------------------------------------

def test_compressed_mesh_tracks_uncompressed():
    steps = 40
    _, _, base, _ = _run(_mesh(), steps=steps)
    _, _, comp, _ = _run(_mesh(), steps=steps, compress="ef")
    assert comp[-1] < comp[0] * 0.8, comp[::10]
    assert abs(comp[-1] - base[-1]) / base[-1] < 0.15, (base[-1], comp[-1])


def test_ring_mode_ships_ppermute_and_tracks_ef():
    mesh = _mesh()
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (48, 32)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (32,)),
             "none": None}
    from repro.distributed.compress import init_ef_state
    ef = init_ef_state(grads)
    t_ef = dp_grad_transform(mesh, mode="ef")
    t_ring = dp_grad_transform(mesh, mode="ring")
    g_ef, e_ef = jax.jit(t_ef)(grads, ef)
    g_ring, e_ring = jax.jit(t_ring)(grads, ef)
    for a, b in zip(jax.tree.leaves(g_ef), jax.tree.leaves(g_ring)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)
    hlo = jax.jit(t_ring).lower(grads, ef).compile().as_text()
    assert "collective-permute" in hlo  # the explicit ring is on the wire
    assert "collective-permute" not in jax.jit(t_ef).lower(
        grads, ef).compile().as_text()


def test_ring_mode_gather_free_on_shards():
    """With pspecs threaded, the ring operates on local shards: FSDP
    (data-sharded) leaves take the EF path, model-sharded leaves ring
    as-is — the compiled exchange inserts no all-gather of gradients."""
    from repro.distributed.compress import init_ef_state

    mesh = _mesh()
    grads = {"fsdp": jax.device_put(
                 jax.random.normal(jax.random.PRNGKey(0), (64, 32)),
                 NamedSharding(mesh, P("data", "model"))),
             "tp": jax.device_put(
                 jax.random.normal(jax.random.PRNGKey(1), (48, 32)),
                 NamedSharding(mesh, P(None, "model")))}
    ef = jax.device_put(init_ef_state(grads),
                        {"fsdp": NamedSharding(mesh, P("data", "model")),
                         "tp": NamedSharding(mesh, P(None, "model"))})
    pspecs = {"fsdp": P("data", "model"), "tp": P(None, "model")}
    t = dp_grad_transform(mesh, mode="ring", pspecs=pspecs)
    g, e = jax.jit(t)(grads, ef)
    hlo = jax.jit(t).lower(grads, ef).compile().as_text()
    assert "collective-permute" in hlo  # tp leaf rings
    assert "all-gather" not in hlo      # nothing replicated to ring
    # every leaf stays within int8-quantization distance of the input
    # (the sharded ring quantizes per *shard* scale — finer than ef's
    # per-tensor scale, so not bitwise-comparable to it)
    for k in ("fsdp", "tp"):
        raw, out = np.asarray(grads[k]), np.asarray(g[k])
        bound = 1.5 * np.abs(raw).max() / 127.0
        np.testing.assert_allclose(out, raw, atol=bound)
        assert float(np.abs(np.asarray(e[k])).sum()) > 0  # EF carries


def test_ring_mode_trains():
    _, _, losses, _ = _run(_mesh(), steps=20, compress="ring")
    assert losses[-1] < losses[0], losses


def test_dp_wire_bytes_model():
    grads = {"w": jnp.zeros((1000, 100), jnp.float32), "n": None}
    un = dp_wire_bytes(grads, 2, None)
    ef = dp_wire_bytes(grads, 2, "ef")
    ring = dp_wire_bytes(grads, 2, "ring")
    assert un == 100000 * 4  # 2*(n-1)/n == 1 at n=2
    assert ef < ring < un
    assert dp_wire_bytes(grads, 1, "ef") == 0


def test_grad_compress_requires_ef_state():
    t = dp_grad_transform(_mesh(), mode="ef")
    with pytest.raises(ValueError, match="error-feedback"):
        t({"w": jnp.zeros((4,))}, None)
    with pytest.raises(ValueError, match="unknown grad-compress"):
        dp_grad_transform(_mesh(), mode="zip")


# ---------------------------------------------------------------------------
# TrainLoop: deferred metric sync + sharded/elastic resume
# ---------------------------------------------------------------------------

def test_trainloop_syncs_only_on_cadence(monkeypatch):
    calls = []
    real = jax.block_until_ready

    def counting(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(jax, "block_until_ready", counting)
    cfg, state, step_fn, _ = _build(None)
    lm = MarkovLM(cfg.vocab, seed=0)
    loop = TrainLoop(step_fn, lambda n: {k: jnp.asarray(v) for k, v in
                                         lm.batch(0, n, B, S).items()},
                     log_every=10, log_fn=lambda *_: None)
    loop.run(state, 30, handle_signals=False)
    # 30 steps / log_every=10 -> 3 cadence syncs (+1 final drain at most)
    assert len(calls) <= 4, len(calls)
    assert len(loop.history) == 30
    assert all(np.isfinite(h["loss"]) for h in loop.history)


def test_sharded_ckpt_resume_in_place(tmp_path):
    mesh = _mesh()
    _, state, _, _ = _run(mesh, steps=6, ckpt_dir=str(tmp_path))
    from repro.checkpoint.ckpt import load_mesh
    assert load_mesh(str(tmp_path)) == {"axes": ["data", "model"],
                                        "shape": [2, 4]}
    cfg, state2, step_fn, sh = _build(mesh)
    loop = TrainLoop(step_fn, lambda n: None, ckpt_dir=str(tmp_path),
                     log_fn=lambda *_: None, shardings=sh["state"], mesh=mesh)
    resumed, start = loop.maybe_resume(state2)
    assert start == 6
    # leaves land already committed to their NamedShardings, not host
    leaf = resumed["step"]
    assert int(leaf) == 6
    for l, s in zip(jax.tree.leaves(resumed["trainable"]),
                    jax.tree.leaves(sh["state"]["trainable"],
                                    is_leaf=lambda x: x is None)):
        if s is not None:
            assert isinstance(l.sharding, NamedSharding)
    # grafted values equal the trained state's
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_resume_with_newly_enabled_compression(tmp_path):
    """Turning --grad-compress on mid-run: the checkpoint predates the
    EF residuals, so their shardings are pruned before restore and the
    fresh zero residuals keep their live (placed) value."""
    mesh = _mesh()
    _run(mesh, steps=6, ckpt_dir=str(tmp_path))  # saved WITHOUT ef
    cfg, state, step_fn, sh = _build(mesh, compress="ef")
    assert "ef" in state
    loop = TrainLoop(step_fn, lambda n: None, ckpt_dir=str(tmp_path),
                     log_fn=lambda *_: None, shardings=sh["state"], mesh=mesh)
    resumed, start = loop.maybe_resume(state)  # must not raise
    assert start == 6 and "ef" in resumed
    for l in jax.tree.leaves(resumed["ef"], is_leaf=lambda x: x is None):
        if l is not None:
            assert float(jnp.sum(jnp.abs(l))) == 0.0  # fresh residuals


def test_elastic_resume_onto_different_mesh(tmp_path):
    """Train on 2x4, resume on 8x1 (and solo) — the stored global arrays
    land on whatever mesh the new job runs with."""
    _, state, losses, _ = _run(_mesh(), steps=6, ckpt_dir=str(tmp_path))
    mesh81 = _mesh(8, 1)
    cfg, state2, step_fn, sh = _build(mesh81)
    lm = MarkovLM(cfg.vocab, seed=0)
    loop = TrainLoop(step_fn, lambda n: {k: jnp.asarray(v) for k, v in
                                         lm.batch(0, n, B, S).items()},
                     ckpt_dir=str(tmp_path), log_fn=lambda *_: None,
                     shardings=sh["state"], mesh=mesh81)
    state3, step = loop.run(state2, 10, handle_signals=False)
    assert step == 10 and len(loop.history) == 4  # resumed at 6
    assert all(np.isfinite(h["loss"]) for h in loop.history)
    # and a solo resume of the same sharded checkpoint
    cfgs, states, stepfns, _ = _build(None)
    loops = TrainLoop(stepfns, lambda n: {k: jnp.asarray(v) for k, v in
                                          lm.batch(0, n, B, S).items()},
                      ckpt_dir=str(tmp_path), log_fn=lambda *_: None)
    _, steps_ = loops.run(states, 12, handle_signals=False)
    assert steps_ == 12 and len(loops.history) == 2  # resumed at 10


# ---------------------------------------------------------------------------
# acceptance: train -> serve handoff (sharded ckpt into sharded serving)
# ---------------------------------------------------------------------------

def test_train_to_serve_handoff_token_identical(tmp_path):
    """One mesh-trained checkpoint, served solo and through the PR 4
    sharded serving path: generation must be token-identical (the serve
    parity contract, now fed by *trained* (d, A) instead of init)."""
    from repro.checkpoint.ckpt import restore
    from repro.core.policy import merge_trainable, serve_view
    from repro.runtime.serving import generate

    mesh_dir = str(tmp_path / "mesh")
    cfg, _, _, _ = _run(_mesh(), steps=8, ckpt_dir=mesh_dir)

    scfg = cfg.replace(kernel_backend="fused")
    _, axes = _init_params()
    state, step = restore(mesh_dir)
    assert step == 8
    params = merge_trainable(state["trainable"], state["static"])
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (4, 8), 0, scfg.vocab)}
    outs = {}
    for tag, mesh in [("solo", None), ("mesh", _mesh())]:
        sv = serve_view(params, policy=api.resolved_policy(scfg),
                        mesh=mesh, axes=axes)
        outs[tag] = np.asarray(generate(sv, scfg, batch, steps=6, mesh=mesh))
    np.testing.assert_array_equal(outs["solo"], outs["mesh"])


def test_serve_cli_restores_train_ckpt(tmp_path, capsys):
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main

    rc = train_main(["--arch", ARCH, "--reduced", "--steps", "6",
                     "--batch", "4", "--seq", "16", "--vocab", "48",
                     "--mesh", "2x4", "--ckpt-dir", str(tmp_path)])
    assert rc == 0
    rc = serve_main(["--arch", ARCH, "--reduced", "--vocab", "48",
                     "--batch", "2", "--prompt-len", "8", "--gen", "4",
                     "--kernel-backend", "fused", "--mesh", "2x4",
                     "--ckpt-dir", str(tmp_path)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "restored train checkpoint step 6" in out
    assert "mesh 2x4" in out


def test_serve_cli_rejects_mismatched_ckpt(tmp_path):
    """A checkpoint trained at one vocab served at another must fail
    loudly — out-of-bounds embedding gathers clamp silently under jit."""
    from repro.launch.serve import main as serve_main
    from repro.launch.train import main as train_main

    rc = train_main(["--arch", ARCH, "--reduced", "--steps", "2",
                     "--batch", "2", "--seq", "8", "--vocab", "48",
                     "--ckpt-dir", str(tmp_path)])
    assert rc == 0
    with pytest.raises(SystemExit, match="does not fit the serve config"):
        serve_main(["--arch", ARCH, "--reduced", "--vocab", "96",
                    "--batch", "2", "--prompt-len", "8", "--gen", "2",
                    "--ckpt-dir", str(tmp_path)])


def test_train_cli_mesh_smoke(capsys):
    from repro.launch.train import main as train_main

    rc = train_main(["--arch", ARCH, "--reduced", "--steps", "8",
                     "--batch", "4", "--seq", "16", "--vocab", "48",
                     "--mesh", "2x4", "--grad-compress", "ef"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "mesh 2x4" in out and "per-device masters" in out
