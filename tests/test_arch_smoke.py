"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill->decode parity and a
quantized (LUT-Q) train step for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced

ARCHS = [
    "h2o-danube-1.8b",
    "qwen1.5-110b",
    "mistral-nemo-12b",
    "mistral-large-123b",
    "paligemma-3b",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b",
    "seamless-m4t-medium",
    "zamba2-2.7b",
    "rwkv6-1.6b",
]

S = 32
B = 2


def _batch(cfg, kind="train"):
    key = jax.random.PRNGKey(42)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, S, cfg.d_model))
        d = {"frames": frames, "tokens": toks}
        if kind == "train":
            d["labels"] = toks
        return d
    if cfg.family == "vlm":
        pe = jax.random.normal(key, (B, cfg.n_prefix_tokens, cfg.d_model))
        d = {"tokens": toks, "prefix_embeds": pe}
        if kind == "train":
            d["labels"] = toks
        return d
    d = {"tokens": toks}
    if kind == "train":
        d["labels"] = toks
    return d


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_archs())


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = api.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # roughly ln(V) at init
    assert float(loss) < np.log(cfg.vocab) * 2.0
    g = jax.grad(lambda p: api.loss_fn(p, cfg, batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.slow
def test_quantized_train_step_smoke(arch):
    """LUT-Q applied (or explicitly inapplicable-free) for every arch."""
    cfg = reduced(get_config(arch)).replace(
        quant=QuantSpec(bits=2, kmeans_iters=1, min_size=1024), act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    qparams = api.quantize(params, cfg, axes)
    from repro.core.policy import quantized_fraction
    assert quantized_fraction(qparams) > 0.5, "most params should be LUT-Q"
    batch = _batch(cfg)
    loss, _ = api.loss_fn(qparams, cfg, batch)
    assert np.isfinite(float(loss))
    from repro.core.policy import merge_trainable, split_trainable
    trainable, static = split_trainable(qparams)
    g = jax.grad(lambda t: api.loss_fn(
        merge_trainable(t, static), cfg, batch)[0])(trainable)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_parity(arch):
    """decode_step(t) after prefill(:t) == forward logits at t."""
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32, remat=False)
    params, _ = api.init(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg, kind="prefill")
    toks = batch["tokens"]
    P = 16

    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :P]
    logits_pre, cache = api.prefill(params, cfg, pre_batch, max_len=S)

    # grow caches to max_len for the decode step where needed
    if cfg.family in ("dense", "moe", "vlm"):
        full = api.init_cache(cfg, B, S)
        def merge(big, small):
            if big.shape == small.shape:
                return small
            return jax.vmap(lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                b, s.astype(b.dtype), 0, 0))(big, small)
        full["layers"] = jax.tree.map(merge, full["layers"], cache["layers"])
        if "prefix_layers" in cache:
            full["prefix_layers"] = jax.tree.map(
                lambda b, s: b.at[:, :s.shape[1]].set(s.astype(b.dtype)) if b.shape != s.shape else s,
                full["prefix_layers"], cache["prefix_layers"])
        full["len"] = cache["len"]
        cache = full
    elif cfg.family == "encdec":
        full = api.init_cache(cfg, B, S, src_len=S)
        def merge2(big, small):
            if big.shape == small.shape:
                return small
            return jax.vmap(lambda b, s: jax.lax.dynamic_update_slice_in_dim(
                b, s.astype(b.dtype), 0, 0))(big, small)
        full["layers"] = jax.tree.map(merge2, full["layers"], cache["layers"])
        full["len"] = cache["len"]
        cache = full
    # hybrid zamba prefill already pads to max_len; ssm has O(1) state

    next_tok = toks[:, P:P + 1]
    logits_dec, _ = api.decode_step(params, cfg, next_tok, cache)

    # oracle: full forward over P+1 tokens
    if cfg.family == "encdec":
        from repro.models.encdec import encode, cross_kv, _dec_layer
        # run prefill again over P+1 and take last logits
        b2 = dict(batch)
        b2["tokens"] = toks[:, :P + 1]
        oracle, _ = api.prefill(params, cfg, b2, max_len=S)
    else:
        b2 = dict(batch)
        b2["tokens"] = toks[:, :P + 1]
        oracle, _ = api.prefill(params, cfg, b2, max_len=S)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(oracle[:, 0]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "rwkv6-1.6b", "h2o-danube-1.8b"])
def test_subquadratic_state_is_bounded(arch):
    """The long_500k-eligible archs must have O(1)/O(window) decode state."""
    cfg = reduced(get_config(arch))
    c_small = api.init_cache(cfg, 1, 64)
    c_big = api.init_cache(cfg, 1, 4096)
    def nbytes(c):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    if cfg.family == "ssm":
        assert nbytes(c_small) == nbytes(c_big)  # O(1)
    elif cfg.window is not None:
        # ring buffer clamps the KV cache to the window width
        assert nbytes(c_big) == nbytes(c_small)


def test_full_configs_match_assignment():
    """Exact numbers from the assignment table."""
    c = get_config("qwen1.5-110b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (80, 8192, 64, 8, 49152, 152064) and c.qkv_bias
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (88, 12288, 96, 8, 28672, 32768)
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_experts, c.top_k, c.d_ff, c.n_layers) == (128, 8, 1536, 94)
    c = get_config("deepseek-v2-lite-16b")
    assert c.use_mla and c.kv_lora == 512 and c.n_experts == 64 and c.top_k == 6
    c = get_config("zamba2-2.7b")
    assert c.family == "hybrid" and c.ssm_state == 64 and c.n_layers == 54
    c = get_config("rwkv6-1.6b")
    assert c.family == "ssm" and c.d_ff == 7168 and c.vocab == 65536
    c = get_config("h2o-danube-1.8b")
    assert c.window is not None
    c = get_config("paligemma-3b")
    assert c.n_kv_heads == 1 and c.vocab == 257216 and c.n_prefix_tokens == 256
    c = get_config("seamless-m4t-medium")
    assert c.family == "encdec" and c.vocab == 256206
    c = get_config("mistral-nemo-12b")
    assert c.vocab == 131072 and c.n_kv_heads == 8
